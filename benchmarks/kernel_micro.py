"""Kernel microbenchmarks across the implementation-variant axis.

Times every ``<name>_op`` wrapper (see :mod:`repro.kernels.ops`) at each
implementation variant — ``xla`` (the jit-compiled jnp oracle), ``ref``
(the eager oracle) and, where it actually compiles, ``pallas``. Off-TPU
the Pallas bodies only run in interpret mode, which times the
interpreter rather than the kernel, so the full-size profile skips them
there; the ``--smoke`` profile shrinks every case enough that the
interpret-mode row is still measured (every wrapper × every impl stays
exercised in CI). Prints ``name,us_per_call,derived`` rows; the
``kernels`` suite in ``benchmarks.run`` also serializes the structured
rows as the schema-tagged ``BENCH_kernels.json`` artifact.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import (KERNEL_IMPLS, demo_spheres, flash_attention_op,
                           gaussian_op, linear_attention_op, mandelbrot_op,
                           matmul_op, rap_op, raytrace_op, taylor_op)


def _time(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def timed_impls(smoke: bool = False) -> tuple[str, ...]:
    """The impl variants worth timing on this backend.

    On TPU all of :data:`~repro.kernels.KERNEL_IMPLS`; elsewhere the
    Pallas bodies only run in interpret mode, so they are timed only at
    smoke sizes (where the interpreter cost is bounded) and skipped from
    the full-size profile.
    """
    if jax.default_backend() == "tpu" or smoke:
        return KERNEL_IMPLS
    return ("xla", "ref")


def _cases(smoke: bool) -> list:
    """(name, label, op, args, size) per wrapper, sized per profile."""
    rng = np.random.default_rng(0)
    f32 = jnp.float32

    mm = 64 if smoke else 512
    a = jnp.asarray(rng.normal(size=(mm, mm)), f32)
    b = jnp.asarray(rng.normal(size=(mm, mm)), f32)

    gh = 128 if smoke else 1024
    img = jnp.asarray(rng.normal(size=(gh, gh)), f32)

    tn = 1 << (12 if smoke else 20)
    x = jnp.asarray(rng.uniform(-3, 3, size=(tn,)), f32)

    side = 64 if smoke else 512
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = [jnp.asarray(g) for g in np.meshgrid(re_, im)]

    rn = 1 << (12 if smoke else 18)
    dx, dy = rng.uniform(-.4, .4, (2, rn)).astype(np.float32)
    dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, .5)).astype(np.float32)
    sph = demo_spheres()

    rap_n, rap_l = (256, 64) if smoke else (1 << 14, 128)
    vals = jnp.asarray(rng.normal(size=(rap_n, rap_l)), f32)
    lens = jnp.asarray(rng.integers(0, rap_l, size=(rap_n,)), jnp.int32)

    B, H, Hkv, T, D = (1, 4, 2, 128, 32) if smoke else (1, 8, 4, 1024, 64)
    fa_dt = jnp.float32 if smoke else jnp.bfloat16
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), fa_dt)
    k = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), fa_dt)
    v = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), fa_dt)

    BH, T2, Dk = (2, 128, 16) if smoke else (8, 2048, 64)
    q2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)), f32)
    k2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)) * .2, f32)
    v2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)), f32)
    ld = jnp.asarray(-np.abs(rng.normal(size=(BH, T2)) * .1), f32)

    return [
        ("matmul", f"{mm}", matmul_op, (a, b), mm * mm),
        ("gaussian", f"{gh}", gaussian_op, (img,), gh * gh),
        ("taylor", f"{tn >> 10}k", taylor_op, (x,), tn),
        ("mandelbrot", f"{side}", mandelbrot_op, (cre, cim), side * side),
        ("ray", f"{rn >> 10}k", raytrace_op,
         (jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz), sph), rn),
        ("rap", f"{rap_n}", rap_op, (vals, lens), rap_n),
        ("flash_attention", f"T{T}", flash_attention_op, (q, k, v), T),
        ("linear_attention", f"T{T2}", linear_attention_op,
         (q2, k2, v2, ld), T2),
    ]


def structured_rows(*, smoke: bool = False) -> list[dict]:
    """One measurement dict per (wrapper, impl) pair.

    Row contract (checked by ``scripts/check_bench_schema.py``): kind,
    kernel, impl, size (index-space items), iters, us_per_call.
    """
    rows = []
    for name, label, op, args, size in _cases(smoke):
        for impl in timed_impls(smoke):
            # eager ref rows re-dispatch per op — cap their iteration
            # budget so the oracle baseline doesn't dominate the suite
            warmup, iters = (1, 3) if (smoke or impl == "ref") else (2, 10)

            def fn(*a, _op=op, _impl=impl):
                return _op(*a, impl=_impl)

            us = _time(fn, *args, warmup=warmup, iters=iters)
            rows.append(dict(kind="kernel", kernel=name, impl=impl,
                             label=label, size=size, iters=iters,
                             us_per_call=round(us, 2)))
    return rows


def run(structured: list | None = None, *, smoke: bool = False):
    """Human CSV rows for the driver; reuses prebuilt structured rows."""
    if structured is None:
        structured = structured_rows(smoke=smoke)
    return [(f"kernel/{r['kernel']}_{r['label']}[{r['impl']}]",
             round(r["us_per_call"], 1),
             f"size={r['size']}") for r in structured]
