"""Kernel microbenchmarks: real wall-time of the jitted production paths
(XLA oracles on CPU; the Pallas kernels are TPU-target, validated in
interpret mode — timing interpret mode would measure the interpreter).
Prints name,us_per_call,derived rows.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import demo_spheres, ref


def _time(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    a = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(512, 512)), jnp.float32)
    f = jax.jit(ref.matmul)
    us = _time(f, a, b)
    rows.append(("kernel/matmul_512", round(us, 1),
                 f"gflops={2 * 512**3 / us / 1e3:.1f}"))

    img = jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)
    f = jax.jit(ref.gaussian_blur)
    rows.append(("kernel/gaussian_1024", round(_time(f, img), 1),
                 "5x5 separable"))

    x = jnp.asarray(rng.uniform(-3, 3, size=(1 << 20,)), jnp.float32)
    f = jax.jit(ref.taylor_sin)
    rows.append(("kernel/taylor_1M", round(_time(f, x), 1), "12 terms"))

    side = 512
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = [jnp.asarray(g) for g in np.meshgrid(re_, im)]
    f = jax.jit(lambda a, b: ref.mandelbrot(a, b, max_iter=64))
    rows.append(("kernel/mandelbrot_512", round(_time(f, cre, cim), 1),
                 "64 iters"))

    n = 1 << 18
    dx, dy = rng.uniform(-.4, .4, (2, n)).astype(np.float32)
    dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, .5)).astype(np.float32)
    sph = demo_spheres()
    f = jax.jit(ref.raytrace)
    rows.append(("kernel/ray_256k", round(
        _time(f, jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz), sph),
        1), "8 spheres"))

    vals = jnp.asarray(rng.normal(size=(1 << 14, 128)), jnp.float32)
    lens = jnp.asarray(rng.integers(0, 128, size=(1 << 14,)), jnp.int32)
    f = jax.jit(ref.rap)
    rows.append(("kernel/rap_16k", round(_time(f, vals, lens), 1),
                 "L=128"))

    B, H, T, D = 1, 8, 1024, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, 4, T, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, 4, T, D)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: ref.attention(q, k, v))
    rows.append(("kernel/attention_1k", round(_time(f, q, k, v), 1),
                 "causal GQA"))

    BH, T2, Dk = 8, 2048, 64
    q2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)), jnp.float32)
    k2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)) * .2, jnp.float32)
    v2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)), jnp.float32)
    ld = jnp.asarray(-np.abs(rng.normal(size=(BH, T2)) * .1), jnp.float32)
    f = jax.jit(lambda *a: ref.chunked_linear_attention(*a))
    rows.append(("kernel/linattn_2k", round(_time(f, q2, k2, v2, ld), 1),
                 "chunked SSD"))
    return rows
