"""Open-loop SLO serving benchmark: deadline-aware admission under load.

Replays seeded Poisson/burst traces against the multi-tenant DES at
offered loads below and above capacity, comparing plain preemptive WFQ
with EDF credit boosts and with EDF + bounded load shedding. The rows
pin the PR's headline claim: at 32 tenants under >= 1.2x capacity,
``edf+preempt+shed`` improves admitted-launch p99 latency and
deadline-miss rate over ``wfq+preempt`` (which, without shedding, lets
the backlog — and therefore every latency percentile — grow without
bound). Deterministic (seeded traces, DES virtual time): safe as a
CI-tracked artifact.
"""
from __future__ import annotations

# Admission modes swept per (arrival process, load): the unprotected
# baseline, deadline-aware credit only, and the full SLO stack.
MODES = (
    {"policy": "wfq", "preempt": True},
    {"policy": "edf", "preempt": True},
    {"policy": "edf", "preempt": True, "shed": True, "shed_budget": 0.5},
)

ITEMS = 512           # work-items per launch
TENANTS = 32
SLO_SERVICE_MULT = 16  # SLO = this many ideal per-launch service times


def base_spec(spec=None, *, smoke: bool = False):
    """The sweep's resolved spec: taylor units, 32 tenants, scaled SLO."""
    from repro.core import capacity_items_per_s, paper_workload
    from repro.launch.serve import default_serve_spec

    base = spec if spec is not None else default_serve_spec()
    _, cpu, gpu = paper_workload("taylor")
    cap = capacity_items_per_s([cpu, gpu])
    slo_ms = SLO_SERVICE_MULT * ITEMS / cap * 1e3
    return base.replace(
        workload=base.workload.replace(name="taylor", items=ITEMS,
                                       tenants=TENANTS),
        admission=base.admission.replace(slo_ms=slo_ms),
        traffic=base.traffic.replace(
            arrival="poisson", arrivals=800 if smoke else 2000, seed=11))


def structured_rows(spec=None, *, smoke: bool = False) -> list[dict]:
    """The traffic sweep as machine-readable dicts (JSON artifact).

    One dict per (arrival process, offered-load multiple, admission
    mode); ``smoke`` keeps the 32-tenant >=1.2x-capacity rows the
    acceptance claim is pinned on while shrinking the trace and the
    sweep for CI.
    """
    from repro.launch.serve import traffic_rows

    resolved = base_spec(spec, smoke=smoke)
    loads = (0.8, 1.2) if smoke else (0.8, 1.2, 1.6)
    kinds = ("poisson",) if smoke else ("poisson", "burst")
    return traffic_rows(resolved, loads=loads, admissions=MODES,
                        arrival_kinds=kinds, tenants=TENANTS)


def run(spec=None, *, smoke: bool = False, structured=None):
    """Open-loop SLO sweep: arrival x load x admission mode.

    Rows are ``traffic/<arrival>/<Nt>/load<L>/<admission>[+preempt]
    [+shed]`` with the admitted-launch p99 latency (ms) as the value and
    p50/miss-rate/shed/packages derived (pass ``structured`` to format
    pre-measured rows instead of re-running).
    """
    if structured is None:
        structured = structured_rows(spec, smoke=smoke)
    rows = []
    for r in structured:
        tag = (f"{r['admission']}"
               f"{'+preempt' if r['preempt'] else ''}"
               f"{'+shed' if r['shed'] else ''}")
        rows.append((f"traffic/{r['arrival']}/{r['tenants']}t"
                     f"/load{r['load']:.1f}/{tag}",
                     round(r["p99_ms"], 2),
                     f"p50_ms={r['p50_ms']:.2f};"
                     f"miss_rate={r['miss_rate']:.3f};"
                     f"shed={r['shed_count']}/{r['arrivals']};"
                     f"packages={r['packages']};"
                     f"fused_batches={r['fused_batches']}"))
    return rows
