"""Step-level co-execution benchmark: policies vs heterogeneous groups,
plus the package-level scheduler sweep on both execution paths.

`run()` is the training-loop analogue of Fig. 5: three simulated pod
groups with 1.0/0.5/0.25 relative speeds train the same tiny LM; each
policy's mean step time (barrier = slowest group) and its final assignment
are reported. HGuided should approach the optimal 4:2:1 split; Static
(equal hints) stays at the imbalanced 1:1:1.

`run_coexec()` sweeps all four package schedulers — static / dynamic /
hguided / work_stealing — against each other on the DES (paper workload
profiles, virtual time) AND on the real persistent CoexecEngine (concurrent
`launch_async` requests, wall time), so a regression in either path shows
up in the same CSV.

`run_coexec_multi()` sweeps the *admission layer*: 1–32 concurrent
tenants, FIFO vs weighted-fair queueing, fused vs unfused, preemptive
pull-capping on vs off, reporting p50/p99 latency, Jain fairness over
per-tenant throughput, the time-sampled service fairness curve and
dispatched package counts on the deterministic multi-launch DES (which
drives the same `repro.core.exec.ExecutionLoop` as the real engine).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.configs import get_config
from repro.data import DataPipeline
from repro.hetero import HeteroTrainer, make_policy
from repro.models import build_model
from repro.optim import AdamW

SPEEDS = {"podA": 1.0, "podB": 0.5, "podC": 0.25}
STEPS = 24
MICROBATCHES = 14

def coexec_structured_rows(spec=None, *, smoke: bool = False) -> list[dict]:
    """The coexec suite as machine-readable dicts (the JSON artifact).

    One dict per (substrate, workload/kernel, policy, memory model) with
    throughput plus the data plane's dispatch and staging-copy counters —
    what `benchmarks.run` serializes into ``BENCH_coexec.json`` so the
    perf trajectory is tracked across PRs. The real path sweeps both
    memory models; ``smoke`` shrinks sizes for CI.
    """
    from repro.launch.serve import (coexec_real_rows, coexec_sim_rows,
                                    default_serve_spec)

    base = spec if spec is not None else default_serve_spec()
    rows: list[dict] = []
    # serial vs pipelined per-unit dispatch is always part of the sweep
    # (depth 1 vs 2, plus the spec's own depth if deeper) so the JSON
    # artifact tracks what overlap buys across PRs
    depths = tuple(sorted({1, 2, int(base.units.pipeline_depth)}))
    # simulated path: one regular + one irregular paper workload, both
    # memory cost models (USM vs BUFFERS is now an end-to-end axis)
    for wl_name in ("taylor", "mandelbrot"):
        for mem in ("usm", "buffers"):
            for depth in depths:
                wl_spec = base.replace(
                    workload=base.workload.replace(name=wl_name),
                    memory=base.memory.replace(model=mem),
                    units=base.units.replace(pipeline_depth=depth))
                for r in coexec_sim_rows(wl_spec):
                    rows.append(dict(
                        kind="sim", workload=wl_name, memory=mem,
                        pipeline_depth=depth,
                        **{k: r[k] for k in
                           ("policy", "seconds", "packages",
                            "balance", "steals", "dispatches",
                            "h2d_copies", "d2h_copies",
                            "device_idle_frac", "host_overhead_frac")}))
    # real path: concurrent launch_async requests on the engine, both
    # data planes × pipeline depths, serving the workload's registered
    # kernel. Units are shared across the sweep so each kernel
    # jit-compiles once (depth is an engine property, not a unit one).
    items, requests = (1 << 12, 4) if smoke else (1 << 14, 8)
    units = base.build_units()
    for mem in ("usm", "buffers"):
        for depth in depths:
            real_spec = base.replace(
                workload=base.workload.replace(
                    name="taylor", items=items, requests=requests,
                    concurrent=requests),
                memory=base.memory.replace(model=mem),
                units=base.units.replace(pipeline_depth=depth))
            for r in coexec_real_rows(real_spec, units=units):
                rows.append(dict(
                    kind="real", workload=r["kernel"],
                    pipeline_depth=depth,
                    **{k: r[k] for k in
                       ("kernel", "memory", "policy", "requests", "n",
                        "seconds", "packages", "req_per_s", "items_per_s",
                        "dispatches", "h2d_copies", "d2h_copies",
                        "device_idle_frac", "host_overhead_frac",
                        "p50_ms", "p99_ms")}))
    return rows


def run_coexec(spec=None, *, smoke: bool = False, structured=None):
    """Package-scheduler sweep: DES (sim) and persistent engine (real).

    The measurement loops live in `repro.launch.serve` (shared with the
    `serve --coexec {real,sim}` CLI); this wrapper formats the structured
    rows of :func:`coexec_structured_rows` as CSV (pass ``structured`` to
    format pre-measured rows instead of re-running). `spec` is an
    optional `repro.api.CoexecSpec` base — `benchmarks.run` builds it
    from its spec-derived CLI flags.
    """
    if structured is None:
        structured = coexec_structured_rows(spec, smoke=smoke)
    rows = []
    for r in structured:
        depth = r.get("pipeline_depth", 1)
        if r["kind"] == "sim":
            rows.append((f"coexec-sim/{r['workload']}/{r['policy']}"
                         f"/{r['memory']}/d{depth}",
                         round(r["seconds"] * 1e3, 1),
                         f"packages={r['packages']};"
                         f"balance={r['balance']:.2f};"
                         f"steals={r['steals']};"
                         f"h2d={r['h2d_copies']};d2h={r['d2h_copies']};"
                         f"idle={r['device_idle_frac']:.2f}"))
        else:
            rows.append((f"coexec-real/{r['kernel']}/{r['policy']}"
                         f"/{r['memory']}/d{depth}",
                         round(r["seconds"] * 1e3, 1),
                         f"requests={r['requests']};"
                         f"packages={r['packages']};"
                         f"req_per_s={r['req_per_s']:.1f};"
                         f"h2d={r['h2d_copies']};d2h={r['d2h_copies']};"
                         f"idle={r['device_idle_frac']:.2f};"
                         f"p99_ms={r['p99_ms']:.1f}"))
    return rows


def coexec_multi_structured_rows(spec=None, *, smoke: bool = False
                                 ) -> list[dict]:
    """The coexec-multi sweep as machine-readable dicts (JSON artifact).

    One dict per (tenant count, intra-launch policy, admission policy,
    fusion mode, preemption mode) on the deterministic multi-launch DES —
    what `benchmarks.run` serializes into ``BENCH_coexec_multi.json``.
    The preemption axis sweeps {off,on} under WFQ (the `hguided` policy
    rows are where the fairness-curve tightening shows: large early
    packages are exactly what pull-capping preempts); ``smoke`` shrinks
    the tenant axis for CI.
    """
    from repro.launch.serve import coexec_multi_rows, default_serve_spec

    base = spec if spec is not None else default_serve_spec()
    base = base.replace(workload=base.workload.replace(name="taylor"))
    tenants = (1, 8, 32) if smoke else (1, 2, 4, 8, 16, 32)
    return coexec_multi_rows(base, tenants=tenants,
                             policies=("dynamic", "hguided"),
                             admissions=("fifo", "wfq"),
                             fuse_modes=(False, True),
                             preempt_modes=(False, True))


def run_coexec_multi(spec=None, *, smoke: bool = False, structured=None):
    """Admission sweep: tenants x {fifo,wfq} x {fuse} x {preempt}.

    Rows are `coexec-multi/<workload>/<policy>/<N>t/<admission>[+fuse]
    [+preempt]` with the p99 latency (ms) as the value and p50/fairness/
    fairness-curve/packages derived. Deterministic (DES virtual time):
    safe as a CI-tracked artifact (pass ``structured`` to format
    pre-measured rows instead of re-running).
    """
    if structured is None:
        structured = coexec_multi_structured_rows(spec, smoke=smoke)
    rows = []
    for r in structured:
        tag = (f"{r['admission']}{'+fuse' if r['fuse'] else ''}"
               f"{'+preempt' if r['preempt'] else ''}")
        rows.append((f"coexec-multi/{r['workload']}/{r['policy']}"
                     f"/{r['tenants']}t/{tag}",
                     round(r["p99_ms"], 2),
                     f"p50_ms={r['p50_ms']:.2f};"
                     f"fairness={r['fairness']:.3f};"
                     f"curve={r['fairness_curve_mean']:.3f};"
                     f"packages={r['packages']};"
                     f"fused_batches={r['fused_batches']}"))
    return rows


def run():
    rows = []
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    for policy_name in ("static", "dyn5", "dynamic", "hguided"):
        pipe = DataPipeline(seed=3, global_batch=MICROBATCHES,
                            seq_len=32, vocab=cfg.vocab_size,
                            num_shards=MICROBATCHES)
        policy = make_policy(policy_name, {k: 1.0 for k in SPEEDS},
                             total_steps=STEPS)
        tr = HeteroTrainer(model, params0, optimizer=AdamW(lr=1e-3),
                           policy=policy, pipeline=pipe,
                           group_speeds=SPEEDS,
                           total_microbatches=MICROBATCHES)
        reports = tr.run(STEPS)
        tail = reports[STEPS // 2:]
        mean_step = float(np.mean([r.step_seconds for r in tail]))
        per_group = {g: float(np.mean([r.group_seconds[g] for r in tail
                                       if g in r.group_seconds]))
                     for g in tr.monitor.alive()}
        balance = min(per_group.values()) / max(per_group.values())
        assignment = reports[-1].assignment
        rows.append((f"hetero/{policy_name}",
                     round(mean_step * 1e3, 1),
                     f"balance={balance:.2f};assign={assignment};"
                     f"compiles={tr.exec_cache.compilations};"
                     f"loss={reports[-1].loss:.3f}"))
    return rows
