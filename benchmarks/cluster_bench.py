"""Elastic cluster benchmark: failure recovery and autoscaling under load.

Replays seeded open-loop traces against the runtime-resizable DES pool
(:func:`repro.core.replay_trace_cluster`), pinning the PR's two headline
claims as tracked artifact rows:

* **Exact failure recovery** — killing 1-of-4 units mid-serve at 0.8x
  capacity degrades p99 gracefully while the exact-once audit stays
  clean: ``lost == duplicated == 0`` with a strictly positive
  ``reissued`` count (the dead unit's in-flight packages really did
  re-issue, bitwise-identically, to the survivors).
* **Autoscaling pays** — under a bursty trace, a pool autoscaling
  2 -> 8 at least halves admitted p99 latency vs the fixed 2-unit
  floor it starts from.

Deterministic (seeded traces, DES virtual time): safe as a CI-tracked
artifact. Rows share the ``cluster_rows`` helper with
``serve --coexec sim --cluster`` so the CLI and the benchmark can never
drift apart.
"""
from __future__ import annotations

ARRIVALS = 600        # per-scenario trace length (smoke shrinks this)
ITEMS = 2048          # serving-sized launches (not the paper batch size)
JOIN_FRAC = 0.7       # rejoin this far into the span, arrivals continuing


def _failure_plan(spec):
    """Kill the pool's highest slot mid-package, join it back later.

    A kill instant picked blindly (say, 40% into the trace span) often
    lands in the idle gap between launch service bursts, where the
    victim owns nothing and the kill exercises none of the re-issue
    machinery. Instead, replay the scenario undisturbed once (the DES is
    deterministic and the disturbed run is identical up to the kill),
    find the victim's package nearest mid-trace, and kill halfway
    through its compute window — the victim is then *provably* mid-
    package at the kill, so the row's ``reissued`` column is a live
    measurement of exact re-issue, not a vacuous zero.
    """
    from repro.core import (FailurePlan, capacity_items_per_s,
                            replay_trace_cluster)
    from repro.launch.serve import cluster_pool_units, trace_from_spec

    cl = spec.cluster
    victim = cl.max_units - 1
    units = cluster_pool_units(spec, cl.max_units)
    trace = trace_from_spec(
        spec, capacity_items_per_s(units[:cl.min_units]))
    ts = [a.t for a in trace.arrivals]
    t0, t1 = min(ts), max(ts)
    rep = replay_trace_cluster(trace, units, spec=spec,
                               min_units=cl.min_units)
    mid = t0 + 0.5 * (t1 - t0)
    victim_pkgs = [p for e in rep.launches if e.stats is not None
                   for p in e.stats.packages
                   if p.unit == victim and p.t_complete > p.t_issue]
    if not victim_pkgs:
        raise RuntimeError(f"unit {victim} served nothing; cannot place "
                           f"a mid-package kill")
    pkg = min(victim_pkgs, key=lambda p: abs(p.t_issue - mid))
    t_kill = 0.5 * (pkg.t_issue + pkg.t_complete)
    return FailurePlan(timeline=(
        (t_kill, f"kill:{victim}"),
        (t0 + JOIN_FRAC * (t1 - t0), f"join:{victim}")))


def _scenario_specs(spec, *, smoke: bool = False):
    """The four benchmark scenarios as (name, spec, plan) triples."""
    from repro.launch.serve import default_serve_spec

    base = spec if spec is not None else default_serve_spec()
    arrivals = 200 if smoke else ARRIVALS
    steady = base.replace(
        workload=base.workload.replace(name="taylor", items=ITEMS),
        traffic=base.traffic.replace(arrival="poisson", load=0.8,
                                     arrivals=arrivals, seed=17),
        cluster=base.cluster.replace(enabled=True, min_units=4,
                                     max_units=4))
    burst = steady.replace(
        traffic=steady.traffic.replace(arrival="burst", load=0.9,
                                       burst=4.0, burst_duty=0.2),
        cluster=steady.cluster.replace(min_units=2, max_units=2))
    autoscale = burst.replace(
        cluster=burst.cluster.replace(max_units=8, autoscale=True,
                                      sustain_s=0.02, cooldown_s=0.05))
    return [
        ("fixed4/undisturbed", steady, None),
        ("fixed4/kill1of4", steady, _failure_plan(steady)),
        ("fixed2/burst", burst, None),
        ("autoscale2to8/burst", autoscale, None),
    ]


def structured_rows(spec=None, *, smoke: bool = False) -> list[dict]:
    """The cluster sweep as machine-readable dicts (JSON artifact).

    One dict per scenario; every row carries the exact-once audit
    columns (``lost``/``duplicated``/``reissued``) next to the latency
    percentiles, so a regression in either recovery exactness or
    recovery *cost* is a tracked quantity.
    """
    from repro.launch.serve import cluster_rows

    rows = []
    for name, scenario, plan in _scenario_specs(spec, smoke=smoke):
        row = cluster_rows(scenario, plans={name: plan})[0]
        row["load"] = scenario.traffic.load
        rows.append(row)
    return rows


def run(spec=None, *, smoke: bool = False, structured=None):
    """Elastic-cluster sweep: pool scenario x failure plan.

    Rows are ``cluster/<scenario>/<arrival>`` with admitted p99 latency
    (ms) as the value and the exact-once audit derived (pass
    ``structured`` to format pre-measured rows instead of re-running).
    """
    if structured is None:
        structured = structured_rows(spec, smoke=smoke)
    rows = []
    for r in structured:
        rows.append((f"cluster/{r['name']}/{r['arrival']}",
                     round(r["p99_ms"], 2),
                     f"p50_ms={r['p50_ms']:.2f};"
                     f"admitted={r['admitted']}/{r['arrivals']};"
                     f"lost={r['lost']};dup={r['duplicated']};"
                     f"reissued={r['reissued']};kills={r['kills']};"
                     f"joins={r['joins']};resizes={r['resizes']};"
                     f"pool={r['min_units']}..{r['max_units']}"))
    return rows
