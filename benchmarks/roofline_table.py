"""§Roofline table: read the dry-run JSONs and emit one row per cell."""
from __future__ import annotations

import glob
import json
import os

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def run():
    rows = []
    files = sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))
    if not files:
        return [("roofline/NO_DATA", 0,
                 f"run `python -m repro.launch.dryrun --all` first "
                 f"(looked in {RESULTS_DIR})")]
    for path in files:
        with open(path) as f:
            d = json.load(f)
        key = os.path.basename(path)[:-5].replace("__", "/")
        if d.get("status") == "skipped":
            rows.append((f"roofline/{key}", 0,
                         f"SKIPPED:{d['reason'][:60]}"))
            continue
        bound_ms = max(d["t_compute"], d["t_memory"],
                       d["t_collective"]) * 1e3
        rows.append((
            f"roofline/{key}", round(bound_ms, 2),
            f"frac={d['roofline_frac']:.3f};bound={d['bottleneck']};"
            f"t_comp={d['t_compute'] * 1e3:.1f}ms;"
            f"t_mem={d['t_memory'] * 1e3:.1f}ms;"
            f"t_coll={d['t_collective'] * 1e3:.1f}ms;"
            f"hbm={(d.get('hbm_per_dev') or 0) / 2**30:.1f}GiB"))
    return rows
