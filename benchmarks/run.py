"""Benchmark driver: one section per paper table/figure + framework
benchmarks. Prints ``name,value,derived`` CSV rows.

  python -m benchmarks.run                 # everything
  python -m benchmarks.run fig5 fig7       # selected artifacts
  python -m benchmarks.run coexec --policy work_stealing --n 16384
  python -m benchmarks.run coexec --smoke  # CI-sized data-plane exercise
  python -m benchmarks.run --list          # registered plugins

The co-execution suites (``coexec`` / ``coexec-multi``) take the same
spec-derived flags as ``repro.launch.serve`` — both CLIs generate them
from the ``repro.api.CoexecSpec`` fields, so a new spec field becomes a
new flag in both tools with no edits here. When a coexec suite runs, the
driver also writes the machine-readable ``BENCH_coexec.json`` (path via
``--bench-json``): per-workload/policy/memory throughput plus the data
plane's dispatch and staging-copy counters, the artifact CI uploads so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys


def build_parser(suite_names) -> argparse.ArgumentParser:
    """Suites as positionals + the spec-derived co-execution flags.

    Args:
        suite_names: valid suite keys, for the help text.

    Returns:
        The driver's argparse parser.
    """
    from repro.api import add_spec_args

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("suites", nargs="*", metavar="SUITE",
                    help=f"suites to run (default: all); "
                         f"have {sorted(suite_names)}")
    ap.add_argument("--list", action="store_true",
                    help="print registered schedulers, workloads and "
                         "kernels (with their option fields) and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the coexec suite to CI-smoke sizes")
    ap.add_argument("--bench-json", default="BENCH_coexec.json",
                    metavar="PATH",
                    help="where to write the machine-readable coexec "
                         "results (default: %(default)s)")
    add_spec_args(ap)
    return ap


def main() -> None:
    from repro.api import registry_listing, spec_from_args

    from . import hetero_bench, kernel_micro, paper_figs, roofline_table
    from repro.launch.serve import default_serve_spec

    ap = build_parser(
        list(dict(paper_figs.ALL))
        + ["kernels", "hetero", "coexec", "coexec-multi", "roofline"])
    args = ap.parse_args()
    if args.list:
        print(registry_listing())
        return
    try:
        spec = spec_from_args(args, base=default_serve_spec()).validate()
    except (KeyError, ValueError) as e:
        ap.error(str(e))

    bench_rows: list[dict] = []

    def coexec_suite():
        structured = hetero_bench.coexec_structured_rows(spec,
                                                         smoke=args.smoke)
        bench_rows.extend(structured)
        return hetero_bench.run_coexec(spec, structured=structured)

    suites = dict(paper_figs.ALL)
    suites["kernels"] = kernel_micro.run
    suites["hetero"] = hetero_bench.run
    suites["coexec"] = coexec_suite
    suites["coexec-multi"] = lambda: hetero_bench.run_coexec_multi(spec)
    suites["roofline"] = roofline_table.run

    wanted = args.suites or list(suites)
    print("name,value,derived")
    for key in wanted:
        if key not in suites:
            print(f"# unknown suite {key}; have {sorted(suites)}",
                  file=sys.stderr)
            continue
        for name, value, derived in suites[key]():
            print(f"{name},{value},{derived}")

    if bench_rows:
        doc = {"version": 1, "spec": spec.to_dict(), "rows": bench_rows}
        with open(args.bench_json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {args.bench_json} ({len(bench_rows)} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
