"""Benchmark driver: one section per paper table/figure + framework
benchmarks. Prints ``name,value,derived`` CSV rows.

  python -m benchmarks.run                 # everything
  python -m benchmarks.run fig5 fig7       # selected artifacts
  python -m benchmarks.run coexec --policy work_stealing --n 16384
  python -m benchmarks.run coexec --smoke  # CI-sized data-plane exercise
  python -m benchmarks.run --list          # registered plugins

The co-execution suites (``coexec`` / ``coexec-multi``) take the same
spec-derived flags as ``repro.launch.serve`` — both CLIs generate them
from the ``repro.api.CoexecSpec`` fields, so a new spec field becomes a
new flag in both tools with no edits here (``--preempt`` arrived that
way). When a coexec suite runs, the driver also writes machine-readable
artifacts: ``BENCH_coexec.json`` (path via ``--bench-json``) with
per-workload/policy/memory throughput plus the data plane's dispatch
and staging-copy counters, and ``BENCH_coexec_multi.json`` (path via
``--bench-multi-json``) with the multi-tenant admission sweep —
fairness curves included, so the preemption win is a tracked quantity.
The ``kernels`` suite likewise writes ``BENCH_kernels.json`` (path via
``--bench-kernels-json``) with one row per (wrapper, impl) pair along
the ``pallas``/``xla``/``ref`` implementation axis, and the ``cluster``
suite writes ``BENCH_cluster.json`` (path via ``--bench-cluster-json``)
with the elastic-pool failure/autoscale scenarios and their exact-once
audit columns. All of these documents carry
``schema_version``/``suite`` fields and are validated by
``scripts/check_bench_schema.py`` in CI's docs job.
"""
from __future__ import annotations

import argparse
import json
import sys


def build_parser(suite_names) -> argparse.ArgumentParser:
    """Suites as positionals + the spec-derived co-execution flags.

    Args:
        suite_names: valid suite keys, for the help text.

    Returns:
        The driver's argparse parser.
    """
    from repro.api import add_spec_args

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("suites", nargs="*", metavar="SUITE",
                    help=f"suites to run (default: all); "
                         f"have {sorted(suite_names)}")
    ap.add_argument("--list", action="store_true",
                    help="print registered schedulers, workloads and "
                         "kernels (with their option fields) and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the coexec suites to CI-smoke sizes")
    ap.add_argument("--bench-json", default="BENCH_coexec.json",
                    metavar="PATH",
                    help="where to write the machine-readable coexec "
                         "results (default: %(default)s)")
    ap.add_argument("--bench-multi-json", default="BENCH_coexec_multi.json",
                    metavar="PATH",
                    help="where to write the machine-readable coexec-multi "
                         "results (default: %(default)s)")
    ap.add_argument("--bench-kernels-json", default="BENCH_kernels.json",
                    metavar="PATH",
                    help="where to write the machine-readable per-impl "
                         "kernel microbenchmark results "
                         "(default: %(default)s)")
    ap.add_argument("--bench-traffic-json", default="BENCH_traffic.json",
                    metavar="PATH",
                    help="where to write the machine-readable open-loop "
                         "SLO traffic results (default: %(default)s)")
    ap.add_argument("--bench-cluster-json", default="BENCH_cluster.json",
                    metavar="PATH",
                    help="where to write the machine-readable elastic "
                         "cluster results (default: %(default)s)")
    add_spec_args(ap)
    return ap


BENCH_SCHEMA_VERSION = 2


def write_bench_doc(path: str, suite: str, spec, rows: list) -> None:
    """Serialize one suite's structured rows as a schema-tagged artifact.

    Args:
        path: output JSON path.
        suite: suite key (``"coexec"`` / ``"coexec-multi"``) — recorded
            in the document so the schema checker knows the row contract.
        spec: the resolved ``CoexecSpec`` the run used.
        rows: the structured measurement dicts.
    """
    doc = {"schema_version": BENCH_SCHEMA_VERSION, "suite": suite,
           "spec": spec.to_dict(), "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# wrote {path} ({len(rows)} rows)", file=sys.stderr)


def main() -> None:
    from repro.api import registry_listing, spec_from_args

    from . import (cluster_bench, hetero_bench, kernel_micro, paper_figs,
                   roofline_table, traffic_bench)
    from repro.launch.serve import default_serve_spec

    ap = build_parser(
        list(dict(paper_figs.ALL))
        + ["kernels", "hetero", "coexec", "coexec-multi", "roofline",
           "traffic", "cluster"])
    args = ap.parse_args()
    if args.list:
        print(registry_listing())
        return
    try:
        spec = spec_from_args(args, base=default_serve_spec()).validate()
    except (KeyError, ValueError) as e:
        ap.error(str(e))

    def coexec_suite():
        structured = hetero_bench.coexec_structured_rows(spec,
                                                         smoke=args.smoke)
        write_bench_doc(args.bench_json, "coexec", spec, structured)
        return hetero_bench.run_coexec(spec, structured=structured)

    def coexec_multi_suite():
        structured = hetero_bench.coexec_multi_structured_rows(
            spec, smoke=args.smoke)
        write_bench_doc(args.bench_multi_json, "coexec-multi", spec,
                        structured)
        return hetero_bench.run_coexec_multi(spec, structured=structured)

    def kernels_suite():
        structured = kernel_micro.structured_rows(smoke=args.smoke)
        write_bench_doc(args.bench_kernels_json, "kernels", spec,
                        structured)
        return kernel_micro.run(structured=structured)

    def traffic_suite():
        structured = traffic_bench.structured_rows(spec, smoke=args.smoke)
        write_bench_doc(args.bench_traffic_json, "traffic",
                        traffic_bench.base_spec(spec, smoke=args.smoke),
                        structured)
        return traffic_bench.run(spec, structured=structured)

    def cluster_suite():
        structured = cluster_bench.structured_rows(spec, smoke=args.smoke)
        write_bench_doc(args.bench_cluster_json, "cluster", spec,
                        structured)
        return cluster_bench.run(spec, structured=structured)

    suites = dict(paper_figs.ALL)
    suites["kernels"] = kernels_suite
    suites["hetero"] = hetero_bench.run
    suites["coexec"] = coexec_suite
    suites["coexec-multi"] = coexec_multi_suite
    suites["roofline"] = roofline_table.run
    suites["traffic"] = traffic_suite
    suites["cluster"] = cluster_suite

    wanted = args.suites or list(suites)
    unknown = [key for key in wanted if key not in suites]
    print("name,value,derived")
    for key in wanted:
        if key not in suites:
            print(f"# unknown suite {key}; have {sorted(suites)}",
                  file=sys.stderr)
            continue
        for name, value, derived in suites[key]():
            print(f"{name},{value},{derived}")
    if unknown:
        # a typo'd suite name must fail the run (CI would otherwise pass
        # silently while measuring nothing)
        raise SystemExit(2)


if __name__ == "__main__":
    main()
