"""Benchmark driver: one section per paper table/figure + framework
benchmarks. Prints ``name,value,derived`` CSV rows.

  python -m benchmarks.run                 # everything
  python -m benchmarks.run fig5 fig7       # selected artifacts
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import hetero_bench, kernel_micro, paper_figs, roofline_table

    suites = dict(paper_figs.ALL)
    suites["kernels"] = kernel_micro.run
    suites["hetero"] = hetero_bench.run
    suites["coexec"] = hetero_bench.run_coexec
    suites["coexec-multi"] = hetero_bench.run_coexec_multi
    suites["roofline"] = roofline_table.run

    wanted = sys.argv[1:] or list(suites)
    print("name,value,derived")
    for key in wanted:
        if key not in suites:
            print(f"# unknown suite {key}; have {sorted(suites)}",
                  file=sys.stderr)
            continue
        for name, value, derived in suites[key]():
            print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
