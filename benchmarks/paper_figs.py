"""Reproduction of the paper's tables/figures from the calibrated DES.

One function per artifact; each returns rows of (name, value, derived)
printed as CSV by run.py. Figures:
  table1  — benchmark properties (straight from the specs)
  fig5    — balancing efficiency + speedups, 4 configs × 2 memory models
  fig6    — energy split (cores / gpu / uncore+dram) per config
  fig7    — EDP ratio vs GPU-only (the 72 % geomean headline)
  fig8    — size scalability sweeps with CPU/GPU/co-exec curves
"""
from __future__ import annotations

from repro.api import build_scheduler
from repro.core import (ALL_BENCHMARKS, MemoryModel, PAPER_POWER, SPECS,
                        edp_ratio, geomean, paper_workload, simulate,
                        solo_run)
from repro.core.workloads import effective_shares

KINDS = {"gpu": "gpu", "cpu": "cpu"}
POLICIES = ("static", "dyn5", "dyn200", "hguided")
HINT_ERR = 0.25


def _run(name, policy, mem, size_scale=1.0):
    wl, cpu, gpu = paper_workload(name, size_scale=size_scale)
    speeds = effective_shares(wl, cpu, gpu, hint_error=HINT_ERR)
    kw = {"speeds": speeds} if policy in ("static", "hguided") else {}
    sched = build_scheduler(policy, wl.total, 2, **kw)
    res = simulate(sched, [cpu, gpu], wl, memory=mem)
    return res, wl, cpu, gpu


def table1():
    rows = []
    for name, s in SPECS.items():
        rows.append((f"table1/{name}",
                     s.work_items,
                     f"lws={s.local_work_size};mem={s.mem_mib}MiB;"
                     f"rw={s.read_write[0]}:{s.read_write[1]};"
                     f"groups={s.groups}"))
    return rows


def fig5():
    rows = []
    for name in ALL_BENCHMARKS:
        for mem in (MemoryModel.USM, MemoryModel.BUFFERS):
            solo = None
            for policy in POLICIES:
                res, wl, cpu, gpu = _run(name, policy, mem)
                if solo is None:
                    solo = solo_run(gpu, wl, memory=mem)
                bal = res.balance()
                sp = solo.total_s / res.total_s
                rows.append((f"fig5/{name}/{policy}/{mem.value}",
                             round(sp, 3), f"balance={bal:.3f};"
                             f"pkgs={res.num_packages}"))
    for mem in ("usm", "buffers"):
        for policy in POLICIES:
            sps = [r[1] for r in rows
                   if f"/{policy}/{mem}" in r[0]]
            rows.append((f"fig5/geomean/{policy}/{mem}",
                         round(geomean(sps), 3), "speedup-geomean"))
    return rows


def fig6():
    rows = []
    for name in ALL_BENCHMARKS:
        res, wl, cpu, gpu = _run(name, "hguided", MemoryModel.USM)
        solo = solo_run(gpu, wl)
        e_co = res.energy(PAPER_POWER, KINDS)
        e_gpu = solo.energy(PAPER_POWER, KINDS)
        rows.append((f"fig6/{name}/coexec", round(e_co.total_J, 1),
                     f"cores={e_co.per_unit_J.get('cpu', 0):.0f}J;"
                     f"gpu={e_co.per_unit_J.get('gpu', 0):.0f}J;"
                     f"uncore={e_co.uncore_dram_J:.0f}J"))
        rows.append((f"fig6/{name}/gpu_only", round(e_gpu.total_J, 1),
                     f"cores={e_gpu.per_unit_J.get('cpu', 0):.0f}J;"
                     f"gpu={e_gpu.per_unit_J.get('gpu', 0):.0f}J;"
                     f"uncore={e_gpu.uncore_dram_J:.0f}J"))
    return rows


def fig7():
    rows = []
    ratios = {}
    for mem in (MemoryModel.USM, MemoryModel.BUFFERS):
        for policy in POLICIES:
            rs = []
            for name in ALL_BENCHMARKS:
                res, wl, cpu, gpu = _run(name, policy, mem)
                solo = solo_run(gpu, wl, memory=mem)
                r = edp_ratio(solo.energy(PAPER_POWER, KINDS),
                              res.energy(PAPER_POWER, KINDS))
                rows.append((f"fig7/{name}/{policy}/{mem.value}",
                             round(r, 3), "edp_gpu/edp_coexec"))
                rs.append(r)
            ratios[(policy, mem.value)] = geomean(rs)
            rows.append((f"fig7/geomean/{policy}/{mem.value}",
                         round(geomean(rs), 3), "edp-geomean"))
    headline = ratios[("hguided", "usm")]
    rows.append(("fig7/HEADLINE/hguided/usm", round(headline, 3),
                 "paper_claims=1.72"))
    return rows


def fig8():
    rows = []
    for name in ALL_BENCHMARKS:
        for scale in (0.001, 0.01, 0.1, 0.5, 1.0, 2.0, 4.0, 8.0):
            wl, cpu, gpu = paper_workload(name, size_scale=scale)
            speeds = effective_shares(wl, cpu, gpu, hint_error=HINT_ERR)
            sched = build_scheduler("hguided", wl.total, 2, speeds=speeds)
            co = simulate(sched, [cpu, gpu], wl)
            g = solo_run(gpu, wl)
            c = solo_run(cpu, wl)
            rows.append((f"fig8/{name}/x{scale}", round(co.total_s, 4),
                         f"gpu={g.total_s:.4f};cpu={c.total_s:.4f};"
                         f"speedup={g.total_s / co.total_s:.3f}"))
    return rows


ALL = {"table1": table1, "fig5": fig5, "fig6": fig6, "fig7": fig7,
       "fig8": fig8}
