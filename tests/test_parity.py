"""DES ↔ real-runtime parity: same policy + speeds ⇒ same package count
and exact cover on both `simulate` (virtual time) and `CoexecEngine`
(real threads), for a regular and an irregular workload.

Parity is asserted for the policies whose package count is serve-order
independent: `static` (one package per nonzero share), `dynamic` (fixed
ceil-split), and `work_stealing` (chunks are seeded up front and steals
never split them). `hguided` sizes depend on request order, so only the
cover invariant is checked there.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import CoexecSpec, build_scheduler
from repro.core import (CoexecEngine, MemoryModel, SimUnit,
                        counits_from_devices, simulate,
                        validate_cover, Workload)

TOTAL = 4096
SPEEDS = [0.4, 0.6]
GRAN = 16

COUNT_STABLE = ["static", "dyn16", "work_stealing"]


def regular_workload():
    return Workload(name="reg", total=TOTAL, bytes_in_per_item=4.0,
                    bytes_out_per_item=4.0, working_set_bytes=8.0 * TOTAL)


def irregular_workload():
    w = np.linspace(0.2, 1.8, TOTAL)
    return Workload(name="irr", total=TOTAL, bytes_in_per_item=4.0,
                    bytes_out_per_item=4.0, working_set_bytes=8.0 * TOTAL,
                    weights=w / w.mean())


def sim_units():
    return [SimUnit("cpu", "cpu", speed=4e5 * SPEEDS[0]),
            SimUnit("gpu", "gpu", speed=4e5 * SPEEDS[1], alpha=1.3)]


def real_units():
    return counits_from_devices(jax.local_devices()[:1] * 2,
                                kinds=["cpu", "cpu"], speed_hints=SPEEDS)


def sched(policy):
    kw = {}
    if policy in ("static", "hguided", "work_stealing"):
        kw["speeds"] = list(SPEEDS)
    return build_scheduler(policy, TOTAL, 2, granularity=GRAN, **kw)


def irregular_kernel(offset, chunk):
    # cost grows with the item's weight position — real irregularity
    idx = jnp.arange(chunk.shape[0], dtype=jnp.float32) + offset
    acc = chunk
    for _ in range(3):
        acc = jnp.sin(acc) + idx * 1e-4
    return acc


@pytest.mark.parametrize("policy", COUNT_STABLE)
@pytest.mark.parametrize("workload_fn", [regular_workload,
                                         irregular_workload])
def test_package_count_and_cover_parity(policy, workload_fn):
    wl = workload_fn()
    r = simulate(sched(policy), sim_units(), wl)
    validate_cover(r.packages, TOTAL)

    data = np.random.default_rng(0).normal(size=TOTAL).astype(np.float32)
    kernel = ((lambda off, c: c * 2.0) if wl.weights is None
              else irregular_kernel)
    with CoexecEngine(real_units()) as engine:
        h = engine.submit(sched(policy), kernel, [data],
                          np.zeros(TOTAL, np.float32))
        h.result(timeout=120)
    validate_cover(h.stats.packages, TOTAL)
    assert h.stats.num_packages == r.num_packages, (
        f"{policy}/{wl.name}: engine issued {h.stats.num_packages} "
        f"packages, DES {r.num_packages}")


@pytest.mark.parametrize("workload_fn", [regular_workload,
                                         irregular_workload])
def test_hguided_cover_parity(workload_fn):
    """HGuided package sizes are order-dependent; parity holds for the
    cover invariant and for both paths terminating with all work issued."""
    wl = workload_fn()
    r = simulate(sched("hguided"), sim_units(), wl)
    validate_cover(r.packages, TOTAL)

    data = np.zeros(TOTAL, np.float32)
    with CoexecEngine(real_units()) as engine:
        h = engine.submit(sched("hguided"), lambda off, c: c + 1.0, [data],
                          np.zeros(TOTAL, np.float32), adaptive=False)
        out = h.result(timeout=120)
    np.testing.assert_allclose(out, 1.0)
    validate_cover(h.stats.packages, TOTAL)


@pytest.mark.parametrize("memory", [MemoryModel.USM, MemoryModel.BUFFERS])
def test_work_stealing_memory_models_parity(memory):
    """Both memory models preserve the count/cover parity (the memory model
    changes data movement and per-package costs, never the package
    structure), and the DES models the same per-package staging copies
    the real data plane counts."""
    wl = regular_workload()
    r = simulate(sched("work_stealing"), sim_units(), wl, memory=memory)
    data = np.arange(TOTAL, dtype=np.float32)
    spec = CoexecSpec.builder().memory(memory.value).build()
    with CoexecEngine.from_spec(spec, units=real_units()) as engine:
        h = engine.submit(sched("work_stealing"), lambda off, c: c * 3.0,
                          [data], np.zeros(TOTAL, np.float32))
        out = h.result(timeout=120)
    np.testing.assert_allclose(out, data * 3.0)
    assert h.stats.num_packages == r.num_packages
    # counter parity: per-package copy structure matches across substrates
    # (the sim charges one H2D + one D2H per package under BUFFERS; the
    # real plane pays one H2D per argument — one here — plus one D2H)
    assert h.stats.data.dispatches == r.data.dispatches
    assert (h.stats.data.h2d_copies == r.data.h2d_copies) and \
        (h.stats.data.d2h_copies == r.data.d2h_copies)
