"""The declarative CoexecSpec API: round trips, registry, deprecations.

Covers the PR's acceptance criteria:
* lossless spec round trips (dict and JSON), randomized via _propcheck;
* strict option validation — unknown/misspelled scheduler kwargs raise
  ValueError naming the offending key and the accepted fields;
* third-party plugin registration without core edits;
* the legacy kwarg paths (rt.config, make_scheduler, engine kwargs,
  package_kernel) are gone — their deprecation window closed — and the
  spec paths are warning-free;
* one spec drives the real engine and simulate_multi identically.
"""
import warnings

import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.api import (AdmissionSpec, CoexecSpec, MemorySpec, SchedulerSpec,
                       UnitsSpec, WorkloadSpec, build_scheduler,
                       register_scheduler, register_workload,
                       scheduler_names, speed_hint_policies,
                       temporary_plugins, workload_names)
from repro.core import (CoexecEngine, CoexecutorRuntime, LaunchSpec,
                        Scheduler, paper_workload,
                        simulate, simulate_multi)


def two_units():
    from repro.api import CoexecSpec

    return (CoexecSpec.builder()
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .build().build_units())


# ---------------------------------------------------------------------------
# Round trips
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(policy=st.sampled_from(("static", "dynamic", "hguided",
                               "work_stealing")),
       granularity=st.integers(1, 256),
       num_packages=st.integers(1, 64),
       admission=st.sampled_from(("fifo", "wfq")),
       fuse=st.sampled_from((False, True)),
       max_inflight=st.integers(1, 128),
       memory=st.sampled_from(("usm", "buffers")),
       workload=st.sampled_from(("taylor", "mandelbrot", "rap")),
       items=st.integers(16, 1 << 20),
       tenants=st.integers(1, 64),
       dist=st.floats(0.05, 0.95))
def test_spec_round_trip_randomized(policy, granularity, num_packages,
                                    admission, fuse, max_inflight, memory,
                                    workload, items, tenants, dist):
    options = {"num_packages": num_packages} if policy == "dynamic" else {}
    spec = CoexecSpec(
        units=UnitsSpec(count=2, kinds=("cpu", "gpu"),
                        speed_hints=(0.4, 0.6), dist=(dist,)),
        scheduler=SchedulerSpec(policy=policy, granularity=granularity,
                                options=tuple(options.items())),
        admission=AdmissionSpec(policy=admission, fuse=fuse,
                                max_inflight=max_inflight),
        memory=MemorySpec(model=memory),
        workload=WorkloadSpec(name=workload, items=items, tenants=tenants),
    )
    assert CoexecSpec.from_dict(spec.to_dict()) == spec
    assert CoexecSpec.from_json(spec.to_json()) == spec
    assert spec.validate() is spec


def test_spec_rejects_unknown_fields_and_versions():
    with pytest.raises(ValueError, match="unknown AdmissionSpec field"):
        AdmissionSpec.from_dict({"polciy": "wfq"})
    data = CoexecSpec().to_dict()
    data["version"] = 99
    with pytest.raises(ValueError, match="version"):
        CoexecSpec.from_dict(data)


def test_spec_options_are_order_insensitive_and_frozen():
    a = SchedulerSpec(policy="dynamic",
                      options=(("num_packages", 8), ("granularity", 2)))
    b = SchedulerSpec(policy="dynamic",
                      options=(("granularity", 2), ("num_packages", 8)))
    assert a == b
    with pytest.raises(Exception):      # frozen dataclass
        a.policy = "static"
    # list option values freeze to tuples (JSON round trip preserves them)
    c = SchedulerSpec(policy="hguided", options=(("speeds", [0.4, 0.6]),))
    assert c.options_dict()["speeds"] == (0.4, 0.6)
    assert SchedulerSpec.from_dict(c.to_dict()) == c


def test_builder_issue_example():
    spec = (CoexecSpec.builder()
            .policy("hguided")
            .admission(wfq=True, max_inflight=64)
            .fuse(True)
            .build())
    assert spec.scheduler.policy == "hguided"
    assert spec.admission.policy == "wfq"
    assert spec.admission.max_inflight == 64
    assert spec.admission.fuse is True
    # builder on a base spec derives without mutating the base
    derived = CoexecSpec.builder(spec).policy("dynamic",
                                              num_packages=4).build()
    assert spec.scheduler.policy == "hguided"
    assert derived.scheduler.policy == "dynamic"
    assert derived.scheduler.options_dict() == {"num_packages": 4}
    assert derived.admission == spec.admission


def test_admission_spec_config_round_trip():
    spec = AdmissionSpec(policy="wfq", fuse=True, fuse_limit=8,
                         max_inflight=3, quantum=512)
    assert AdmissionSpec.from_config(spec.to_config()) == spec


# ---------------------------------------------------------------------------
# Registry: strict validation + plugins
# ---------------------------------------------------------------------------

def test_unknown_scheduler_kwarg_raises_value_error_naming_key():
    with pytest.raises(ValueError) as ei:
        build_scheduler("static", 100, 2, chunk_pkgs=5)
    msg = str(ei.value)
    assert "chunk_pkgs" in msg           # the offending key, by name
    assert "static" in msg
    assert "speeds" in msg and "granularity" in msg    # accepted fields
    # misspelled options are caught for every policy, shorthand included
    with pytest.raises(ValueError, match="num_package"):
        build_scheduler("dynamic", 100, 2, num_package=5)  # misspelled
    # spec validation reports it too, before anything is built
    bad = CoexecSpec(scheduler=SchedulerSpec(
        policy="hguided", options=(("divisr", 3.0),)))
    with pytest.raises(ValueError, match="divisr"):
        bad.validate()


def test_unknown_policy_and_workload_raise_key_error():
    with pytest.raises(KeyError):
        build_scheduler("nope", 10, 1)
    with pytest.raises(KeyError):
        paper_workload("nope")
    with pytest.raises(KeyError):
        WorkloadSpec(name="nope").validate()


def test_builtin_registrations_present():
    assert set(scheduler_names()) >= {"static", "dynamic", "hguided",
                                      "work_stealing"}
    assert set(workload_names()) >= {"gaussian", "matmul", "taylor",
                                     "mandelbrot", "rap", "ray"}
    assert set(speed_hint_policies()) == {"static", "hguided",
                                          "work_stealing"}
    # shorthand aliases resolve through the registry
    s = build_scheduler("dyn17", 1000, 2)
    assert s.num_packages == 17
    assert build_scheduler("work-stealing", 100, 2).name == "work_stealing"


def test_third_party_scheduler_plugin_end_to_end():
    class EveryOther(Scheduler):
        """Toy policy: fixed-size packages, round-robin by request."""

        name = "every_other"

        def __init__(self, total, num_units, *, step=7, granularity=1):
            super().__init__(total, num_units, granularity=granularity)
            self.step = int(step)

        def _package_size(self, unit):
            return self.step

    with temporary_plugins():
        register_scheduler("every_other", EveryOther, fields=("step",))
        assert "every_other" in scheduler_names()
        spec = CoexecSpec.builder().policy("every_other", step=5).build()
        sched = spec.build_scheduler(101, 2)
        assert isinstance(sched, EveryOther) and sched.step == 5
        # unknown options are rejected with the plugin's own field list
        with pytest.raises(ValueError, match="stepp"):
            build_scheduler("every_other", 10, 1, stepp=3)
        # duplicate registration is refused without overwrite
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("every_other", EveryOther)
    assert "every_other" not in scheduler_names()    # scope restored


def test_third_party_workload_plugin():
    def tiny(size_scale=1.0):
        from repro.core import SimUnit, Workload

        n = int(64 * size_scale)
        wl = Workload(name="tiny", total=n, bytes_in_per_item=4.0,
                      bytes_out_per_item=4.0, working_set_bytes=8.0 * n)
        return wl, SimUnit("cpu", "cpu", speed=100.0), \
            SimUnit("gpu", "gpu", speed=200.0)

    with temporary_plugins():
        register_workload("tiny", tiny, fields=("size_scale",))
        wl, cpu, gpu = paper_workload("tiny", size_scale=2.0)
        assert wl.total == 128
        spec = CoexecSpec.builder().workload("tiny").build()
        wl2, *_ = spec.build_workload()
        assert wl2.name == "tiny"


# ---------------------------------------------------------------------------
# Closed deprecation window: the kwarg-era shims are gone for good
# ---------------------------------------------------------------------------

def test_legacy_shims_are_removed():
    """docs/api.md's removal timeline is enforced: the shims no longer
    exist, and the replacement spec surface is the only path."""
    import repro.core
    import repro.core.scheduler
    import repro.kernels

    assert not hasattr(repro.core, "make_scheduler")
    assert not hasattr(repro.core.scheduler, "make_scheduler")
    assert not hasattr(CoexecutorRuntime, "config")
    assert not hasattr(repro.kernels, "package_kernel")
    with pytest.raises(ImportError):
        from repro.kernels.ops import package_kernel  # noqa: F401
    # the engine's kwarg-era constructor surface is gone too
    with pytest.raises(TypeError):
        CoexecEngine(two_units(), admission="wfq", max_inflight=4)


def test_spec_paths_are_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        spec = (CoexecSpec.builder().policy("dyn8").dist(0.4)
                .admission(wfq=True).build())
        units = two_units()
        engine = CoexecEngine.from_spec(spec, units=units)
        assert engine.admission.config == spec.admission_config()
        rt = CoexecutorRuntime.from_spec(spec, units=units)
        assert rt.policy == "dyn8"
        wl, cpu, gpu = paper_workload("taylor")
        simulate(None, [cpu, gpu], wl, spec=spec)


def test_engine_takes_only_spec_configuration():
    spec = (CoexecSpec.builder().admission(wfq=True, max_inflight=4)
            .build())
    engine = CoexecEngine(two_units(), spec=spec)
    assert engine.admission.config.policy == "wfq"
    assert engine.admission.config.max_inflight == 4


# ---------------------------------------------------------------------------
# One spec, two substrates (acceptance criterion)
# ---------------------------------------------------------------------------

def test_one_spec_drives_engine_and_des_identically():
    """Serve-style CLI args → spec → JSON round trip → real + DES runs."""
    import argparse

    from repro.api import add_spec_args, args_from_spec, spec_from_args

    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    argv = ["--policy", "dyn8", "--admission", "wfq", "--n", "2048",
            "--tenants", "3", "--workload", "taylor",
            "--max-inflight", "16"]
    spec = spec_from_args(ap.parse_args(argv)).validate()

    # (a) the spec is a lossless artifact
    assert CoexecSpec.from_json(spec.to_json()) == spec
    # (b) and regenerates equivalent CLI args
    assert spec_from_args(ap.parse_args(args_from_spec(spec))) == spec

    n_tenants = spec.workload.tenants
    total = spec.workload.items

    # (c) the DES run, configured by the spec
    import dataclasses

    wl, cpu, gpu = spec.build_workload()
    wl = dataclasses.replace(wl, total=total, weights=None)
    sim_specs = [LaunchSpec(wl, spec.build_scheduler(total, 2),
                            tenant=f"t{i}") for i in range(n_tenants)]
    sim = simulate_multi(sim_specs, [cpu, gpu], spec=spec)
    sim_pkgs = sorted(r.num_packages for r in sim.launches)

    # (d) the real engine run, configured by the same spec object
    units = two_units()
    def kernel(offset, chunk):
        return chunk * 2.0

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with CoexecutorRuntime.from_spec(spec, units=units) as rt:
            data = [np.arange(total, dtype=np.float32) + i
                    for i in range(n_tenants)]
            handles = [rt.launch_async(total, kernel,
                                       [data[i]], tenant=f"t{i}")
                       for i in range(n_tenants)]
            outs = [h.result() for h in handles]
            engine_cfg = rt.engine.admission.config
            real_pkgs = sorted(len(h.packages) for h in handles)
    for i, out in enumerate(outs):
        np.testing.assert_allclose(out, data[i] * 2.0)

    # identical admission behavior: both substrates ran the exact config
    assert engine_cfg == spec.admission_config()
    # identical policy behavior: dyn8 issues exactly 8 packages per
    # launch on both substrates (deterministic package count)
    assert real_pkgs == sim_pkgs == [8] * n_tenants


def test_simulate_multi_spec_matches_explicit_admission():
    """spec= and admission= are the same code path (same controller)."""
    wl, cpu, gpu = paper_workload("taylor")
    spec = CoexecSpec.builder().admission(wfq=True).build()

    def mk_specs():
        return [LaunchSpec(wl, spec.build_scheduler(wl.total, 2),
                           tenant=f"t{i}") for i in range(3)]

    a = simulate_multi(mk_specs(), [cpu, gpu], spec=spec)
    b = simulate_multi(mk_specs(), [cpu, gpu],
                       admission=spec.admission_config())
    assert a.dispatched_packages == b.dispatched_packages
    assert a.latencies() == b.latencies()
