"""Real (threaded, JAX-dispatch) co-execution: the Listing-1 path.

Kernels resolve through the registry (`repro.api.build_kernel`) and the
runtime is configured by `CoexecSpec` — the kwarg-era shim surfaces
(`rt.config`, `package_kernel`) were removed when their deprecation
window closed (pinned in tests/test_api.py).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import CoexecSpec, build_kernel
from repro.core import CoexecutorRuntime
from repro.kernels import ref


def spec_for(policy: str, dist: float = 0.4,
             memory: str = "usm") -> CoexecSpec:
    """Two Coexecution Units (sharing this host's one device)."""
    return (CoexecSpec.builder()
            .policy(policy)
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .dist(dist)
            .memory(memory)
            .build())


@pytest.mark.parametrize("policy", ["static", "dyn16", "hguided"])
@pytest.mark.parametrize("memory", ["usm", "buffers"])
def test_saxpy_all_policies(policy, memory):
    n = 1 << 14
    data = np.arange(n, dtype=np.float32)

    def kernel(offset, chunk):
        return chunk * 3.0

    spec = spec_for(policy, memory=memory)
    with CoexecutorRuntime.from_spec(spec) as rt:
        out = rt.launch(n, kernel, [data], granularity=64)
    np.testing.assert_allclose(out, data * 3.0)
    assert rt.last_stats.num_packages >= (1 if policy == "static" else 2)
    # MemorySpec selects real data-plane behavior, visible in the stats
    if memory == "usm":
        assert rt.last_stats.data.staging_copies == 0
    else:
        assert rt.last_stats.data.staging_copies > 0


def test_offset_dependent_kernel():
    n = 1 << 13

    def kernel(offset, chunk):
        idx = jnp.arange(chunk.shape[0], dtype=jnp.float32) + offset
        return chunk + idx

    with CoexecutorRuntime.from_spec(spec_for("dyn8")) as rt:
        out = rt.launch(n, kernel, [np.zeros(n, np.float32)])
    np.testing.assert_allclose(out, np.arange(n, dtype=np.float32))


def test_paper_benchmark_packages_taylor():
    n = 5000
    x = np.random.default_rng(0).uniform(-2, 2, n).astype(np.float32)
    with CoexecutorRuntime.from_spec(spec_for("hguided", 0.5)) as rt:
        out = rt.launch(n, build_kernel("taylor"), [x])
    np.testing.assert_allclose(out, np.sin(x), rtol=1e-3, atol=1e-4)


def test_paper_benchmark_packages_mandelbrot():
    side = 96
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = np.meshgrid(re_, im)
    with CoexecutorRuntime.from_spec(spec_for("dyn8")) as rt:
        out = rt.launch(side * side, build_kernel("mandelbrot"),
                        [cre.ravel(), cim.ravel()])
    want = np.asarray(ref.mandelbrot(jnp.asarray(cre.ravel()),
                                     jnp.asarray(cim.ravel())))
    np.testing.assert_allclose(out, want)


def test_paper_benchmark_packages_rap():
    n, L = 400, 48
    rng = np.random.default_rng(1)
    vals = rng.normal(size=(n, L)).astype(np.float32)
    lens = rng.integers(0, L, size=n).astype(np.int32)
    with CoexecutorRuntime.from_spec(spec_for("hguided", 0.3)) as rt:
        out = rt.launch(n, build_kernel("rap"), [vals, lens])
    want = np.asarray(ref.rap(jnp.asarray(vals), jnp.asarray(lens)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_matmul_rowwise_coexecution():
    """MatMul co-executed by rows of A; B is a declared broadcast operand."""
    m, k, n2 = 160, 32, 24
    rng = np.random.default_rng(2)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n2)).astype(np.float32)

    with CoexecutorRuntime.from_spec(spec_for("dyn4")) as rt:
        # typed kernel: output shape/dtype derive from the declaration
        out = rt.launch(m, build_kernel("matmul"), [a, b])
    np.testing.assert_allclose(out, a @ b, rtol=1e-5, atol=1e-5)


def test_single_unit_degenerates_gracefully():
    spec = (CoexecSpec.builder().policy("hguided").dist(1.0)
            .units(count=1).build())
    n = 4096
    with CoexecutorRuntime.from_spec(spec) as rt:
        out = rt.launch(n, lambda off, c: c + 1.0,
                        [np.zeros(n, np.float32)])
    np.testing.assert_allclose(out, 1.0)


def test_launch_stats_recorded():
    n = 1 << 12
    with CoexecutorRuntime.from_spec(spec_for("dyn8")) as rt:
        rt.launch(n, lambda off, c: c, [np.zeros(n, np.float32)])
        st = rt.last_stats
    assert st is not None and st.total_s > 0
    assert sum(p.size for p in st.packages) == n
    assert st.data.dispatches == st.num_packages


def test_registry_kernel_with_explicit_units():
    """The spec surface covers the old shim flow end to end: resolve a
    registered kernel, configure dist, launch on explicit units."""
    from repro.core import counits_from_devices

    n = 4096
    x = np.random.default_rng(3).uniform(-2, 2, n).astype(np.float32)
    units = counits_from_devices(jax.local_devices() * 2,
                                 kinds=["cpu", "cpu"],
                                 speed_hints=[0.4, 0.6])
    spec = CoexecSpec.builder().policy("hguided").dist(0.5).build()
    with CoexecutorRuntime.from_spec(spec, units=units) as rt:
        out = rt.launch(n, build_kernel("taylor"), [x])
    np.testing.assert_allclose(out, np.sin(x), rtol=1e-3, atol=1e-4)
