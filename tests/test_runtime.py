"""Real (threaded, JAX-dispatch) co-execution: the Listing-1 path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import CoexecutorRuntime, counits_from_devices
from repro.kernels import demo_spheres, package_kernel, ref


def two_units():
    """Two Coexecution Units (sharing this host's one device)."""
    devs = jax.local_devices() * 2
    return counits_from_devices(devs, kinds=["cpu", "cpu"],
                                speed_hints=[0.4, 0.6])


@pytest.mark.parametrize("policy", ["static", "dyn16", "hguided"])
@pytest.mark.parametrize("memory", ["usm", "buffers"])
def test_saxpy_all_policies(policy, memory):
    n = 1 << 14
    data = np.arange(n, dtype=np.float32)

    def kernel(offset, chunk):
        return chunk * 3.0

    rt = CoexecutorRuntime(policy=policy)
    rt.config(units=two_units(), dist=0.4, memory=memory)
    out = rt.launch(n, kernel, [data], granularity=64)
    np.testing.assert_allclose(out, data * 3.0)
    assert rt.last_stats.num_packages >= (1 if policy == "static" else 2)


def test_offset_dependent_kernel():
    n = 1 << 13

    def kernel(offset, chunk):
        idx = jnp.arange(chunk.shape[0], dtype=jnp.float32) + offset
        return chunk + idx

    rt = CoexecutorRuntime("dyn8").config(units=two_units())
    out = rt.launch(n, kernel, [np.zeros(n, np.float32)])
    np.testing.assert_allclose(out, np.arange(n, dtype=np.float32))


def test_paper_benchmark_packages_taylor():
    n = 5000
    x = np.random.default_rng(0).uniform(-2, 2, n).astype(np.float32)
    rt = CoexecutorRuntime("hguided").config(units=two_units(), dist=0.5)
    out = rt.launch(n, package_kernel("taylor"), [x])
    np.testing.assert_allclose(out, np.sin(x), rtol=1e-3, atol=1e-4)


def test_paper_benchmark_packages_mandelbrot():
    side = 96
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = np.meshgrid(re_, im)
    rt = CoexecutorRuntime("dyn8").config(units=two_units())
    out = rt.launch(side * side, package_kernel("mandelbrot"),
                    [cre.ravel(), cim.ravel()])
    want = np.asarray(ref.mandelbrot(jnp.asarray(cre.ravel()),
                                     jnp.asarray(cim.ravel())))
    np.testing.assert_allclose(out, want)


def test_paper_benchmark_packages_rap():
    n, L = 400, 48
    rng = np.random.default_rng(1)
    vals = rng.normal(size=(n, L)).astype(np.float32)
    lens = rng.integers(0, L, size=n).astype(np.int32)
    rt = CoexecutorRuntime("hguided").config(units=two_units(), dist=0.3)
    out = rt.launch(n, package_kernel("rap"), [vals, lens])
    want = np.asarray(ref.rap(jnp.asarray(vals), jnp.asarray(lens)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_matmul_rowwise_coexecution():
    """MatMul co-executed by rows of A (the B operand rides along)."""
    m, k, n2 = 160, 32, 24
    rng = np.random.default_rng(2)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n2)).astype(np.float32)

    def kernel(offset, a_rows):
        return a_rows @ b

    rt = CoexecutorRuntime("dyn4").config(units=two_units())
    out = rt.launch(m, kernel, [a], out_dtype=np.float32,
                    out_trailing_shape=(n2,))
    np.testing.assert_allclose(out, a @ b, rtol=1e-5, atol=1e-5)


def test_single_unit_degenerates_gracefully():
    rt = CoexecutorRuntime("hguided").config(
        units=counits_from_devices(), dist=1.0)
    n = 4096
    out = rt.launch(n, lambda off, c: c + 1.0,
                    [np.zeros(n, np.float32)])
    np.testing.assert_allclose(out, 1.0)


def test_launch_stats_recorded():
    rt = CoexecutorRuntime("dyn8").config(units=two_units())
    n = 1 << 12
    rt.launch(n, lambda off, c: c, [np.zeros(n, np.float32)])
    st = rt.last_stats
    assert st is not None and st.total_s > 0
    assert sum(p.size for p in st.packages) == n
