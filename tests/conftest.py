"""Shared pytest configuration for the tier-1 suite.

Registers the `slow` mark (long dry-run/e2e tests) and keeps the default
profile fast: slow tests are skipped unless explicitly requested with
``--runslow`` or an ``-m`` expression that mentions ``slow``.
"""
import sys
from pathlib import Path

import pytest

# make the in-repo package and the tests/ helpers importable regardless of
# how pytest was invoked (PYTHONPATH=src is the documented way, this is the
# safety net for bare `pytest` runs)
_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT / "tests"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked `slow`")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running dry-run/e2e test (excluded from the "
                   "default fast profile; enable with --runslow or -m slow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    if "slow" in (config.getoption("-m") or ""):
        return
    skip = pytest.mark.skip(reason="slow test: pass --runslow to include")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
