"""Shared pytest configuration for the tier-1 suite.

Registers the `slow` mark (long dry-run/e2e tests) and keeps the default
profile fast: slow tests are skipped unless explicitly requested with
``--runslow`` or an ``-m`` expression that mentions ``slow``.

Also implements a dependency-free ``timeout`` mark: thread-backed cluster
tests carry ``@pytest.mark.timeout(N)`` so a wedged engine (a worker that
never drains after a unit kill) fails the test instead of hanging the
whole run. Enforced with ``signal.setitimer`` where SIGALRM exists
(POSIX main thread); elsewhere the mark is a no-op — the tests still
pass, they just lose the hang guard.
"""
import signal
import sys
from pathlib import Path

import pytest

# make the in-repo package and the tests/ helpers importable regardless of
# how pytest was invoked (PYTHONPATH=src is the documented way, this is the
# safety net for bare `pytest` runs)
_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT / "tests"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="also run tests marked `slow`")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running dry-run/e2e test (excluded from the "
                   "default fast profile; enable with --runslow or -m slow)")
    config.addinivalue_line(
        "markers", "timeout(seconds): hard per-test wall-clock limit, "
                   "SIGALRM-enforced where available")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    mark = item.get_closest_marker("timeout")
    if mark is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = float(mark.args[0]) if mark.args else 60.0

    def _expired(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded its {seconds:g}s timeout mark")

    previous = signal.signal(signal.SIGALRM, _expired)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, previous)


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    if "slow" in (config.getoption("-m") or ""):
        return
    skip = pytest.mark.skip(reason="slow test: pass --runslow to include")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
