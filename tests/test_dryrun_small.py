"""Dry-run machinery on a small fake mesh (subprocess: own XLA_FLAGS).

The production 512-device sweep runs via `python -m repro.launch.dryrun`;
here we prove the same path (shardings, lower, compile, roofline terms) on
an 8-device fake mesh with reduced configs, in a subprocess so the main
test process keeps its single-device view.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import build_model, cache_specs, param_specs
from repro.models.sharding import batch_spec
from repro.optim import AdamW
from repro.roofline import collective_bytes

results = {}
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
assert mesh.devices.size == 8

for arch in ["qwen3-0.6b", "phi3.5-moe-42b-a6.6b", "zamba2-7b"]:
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    with jax.sharding.set_mesh(mesh):
        ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_specs = param_specs(ps)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                            is_leaf=lambda x: isinstance(x, P))
        B, T = 8, 32
        batch = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, T), jnp.int32)}
        b_sh = {k: NamedSharding(mesh, batch_spec(v.shape))
                for k, v in batch.items()}
        opt = AdamW(lr=1e-3)
        os_ = jax.eval_shape(opt.init, ps)
        o_sh = type(os_)(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)

        def train_step(params, opt_state, b, model=model, opt=opt):
            (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(
                params, b)
            params, opt_state = opt.update(g, opt_state, params)
            return params, opt_state, l

        fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, NamedSharding(mesh, P())))
        compiled = fn.lower(ps, os_, batch).compile()
        coll = collective_bytes(compiled.as_text())
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost

        # decode path too
        cache = jax.eval_shape(lambda m=model: m.init_cache(B, T))
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            cache_specs(cache),
                            is_leaf=lambda x: isinstance(x, P))
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        sfn = jax.jit(lambda p, c, t, m=model: m.decode_step(p, t, c),
                      in_shardings=(p_sh, c_sh,
                                    NamedSharding(mesh,
                                                  batch_spec((B, 1)))),
                      out_shardings=(NamedSharding(mesh, P()), c_sh))
        sfn.lower(ps, cache, tok).compile()

    results[arch] = {"collectives": {k: int(v) for k, v in coll.items()},
                     "flops": float(cost.get("flops", 0))}

print("RESULT" + json.dumps(results))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT")][0]
    results = json.loads(line[len("RESULT"):])
    assert set(results) == {"qwen3-0.6b", "phi3.5-moe-42b-a6.6b",
                            "zamba2-7b"}
    for arch, r in results.items():
        # sharded training must move *some* collective traffic
        assert sum(r["collectives"].values()) > 0, arch
