"""The static-analysis subsystem holds its own contracts.

Fixture-backed true-positive and clean cases for every rule, the
suppression round trip (honored, unused, over-budget), the seeded
lock-guard mutation (deleting one ``with self._cv:`` from a copy of
``engine.py`` must turn the locks pass red), registry semantics, and the
repo itself staying clean under ``python -m repro.analysis``.
"""
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import (AnalysisPass, Rule, load_source, pass_names,
                            pass_plugin, register_pass, run_passes,
                            temporary_passes)
from repro.analysis.consistency import (check_plugin_registrations,
                                        check_spec_cli_docs)
from repro.analysis.determinism import check_determinism
from repro.analysis.exceptions import check_exceptions
from repro.analysis.locks import check_locks

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = pathlib.Path(__file__).resolve().parent / "analysis_fixtures"


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_determinism_true_positives():
    findings = check_determinism(load_source(FIXTURES / "det_bad.py"))
    assert _rules(findings) == ["det-naive-datetime", "det-set-iteration",
                                "det-unseeded-rng", "det-wall-clock"]
    # both unseeded-RNG shapes fire: argless default_rng and np.random.*
    assert sum(f.rule == "det-unseeded-rng" for f in findings) == 2
    assert sum(f.rule == "det-set-iteration" for f in findings) == 2


def test_determinism_clean():
    assert check_determinism(load_source(FIXTURES / "det_clean.py")) == []


def test_determinism_scope_is_the_decision_path():
    globs = pass_plugin("determinism").default_globs
    for mod in ("exec", "admission", "traffic", "sim", "cluster"):
        assert f"src/repro/core/{mod}.py" in globs


# ---------------------------------------------------------------------------
# lock discipline
# ---------------------------------------------------------------------------

def test_locks_true_positive():
    findings = check_locks(load_source(FIXTURES / "locks_bad.py"))
    assert _rules(findings) == ["lock-guard"]
    (f,) = findings
    assert "_pending" in f.message and "_lock" in f.message


def test_locks_clean():
    assert check_locks(load_source(FIXTURES / "locks_clean.py")) == []


def test_locks_mutation_of_engine_turns_red(tmp_path):
    """Deleting one ``with self._cv:`` from engine.py must be caught."""
    source = (REPO / "src/repro/core/engine.py").read_text()
    guarded = ("        with self._cv:\n"
               "            self._stop = True\n"
               "            self._cv.notify_all()\n"
               "            threads = list(self._threads)\n")
    unguarded = ("        self._stop = True\n"
                 "        self._cv.notify_all()\n"
                 "        threads = list(self._threads)\n")
    assert guarded in source, "engine.py shutdown lock block moved; " \
                              "update the mutation fixture"

    pristine = tmp_path / "engine_pristine.py"
    pristine.write_text(source)
    assert check_locks(load_source(pristine)) == []

    mutated = tmp_path / "engine_mutated.py"
    mutated.write_text(source.replace(guarded, unguarded))
    findings = check_locks(load_source(mutated))
    assert any(f.rule == "lock-guard" and "_stop" in f.message
               for f in findings)
    assert any(f.rule == "lock-guard" and "_threads" in f.message
               for f in findings)


# ---------------------------------------------------------------------------
# exception hygiene
# ---------------------------------------------------------------------------

def test_exceptions_true_positives():
    findings = check_exceptions(load_source(FIXTURES / "exc_bad.py"))
    assert _rules(findings) == ["exc-bare-except", "exc-broad-except",
                                "exc-swallowed-control"]


def test_exceptions_clean():
    assert check_exceptions(load_source(FIXTURES / "exc_clean.py")) == []


# ---------------------------------------------------------------------------
# spec/CLI/registry consistency
# ---------------------------------------------------------------------------

def test_consistency_spec_true_positives():
    findings = check_spec_cli_docs(FIXTURES / "spec_bad.py",
                                   FIXTURES / "spec_bad.md")
    assert sum(f.rule == "con-spec-cli" for f in findings) == 1
    docs = [f for f in findings if f.rule == "con-spec-doc"]
    messages = " | ".join(f.message for f in docs)
    assert "alpha.burst" in messages       # missing row
    assert "alpha.ghost" in messages       # stale row


def test_consistency_spec_clean():
    assert check_spec_cli_docs(FIXTURES / "spec_clean.py",
                               FIXTURES / "spec_clean.md") == []


def test_consistency_registration_true_positive():
    findings = check_plugin_registrations([FIXTURES / "reg_bad.py"])
    assert _rules(findings) == ["con-plugin-fields"]
    assert "typo_option" in findings[0].message


def test_consistency_registration_clean():
    assert check_plugin_registrations([FIXTURES / "reg_clean.py"]) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def _write_module(tmp_path, body):
    p = tmp_path / "mod.py"
    p.write_text(body)
    return p


def test_suppression_silences_a_finding(tmp_path):
    p = _write_module(tmp_path, (
        '"""Mod."""\n'
        "import time\n"
        "t = time.perf_counter()  # lint: disable=det-wall-clock\n"))
    findings = run_passes([pass_plugin("determinism")], tmp_path,
                          paths=[str(p)])
    assert findings == []


def test_unused_suppression_is_flagged(tmp_path):
    p = _write_module(tmp_path, (
        '"""Mod."""\n'
        "x = 1  # lint: disable=det-wall-clock\n"))
    findings = run_passes([pass_plugin("determinism")], tmp_path,
                          paths=[str(p)])
    assert _rules(findings) == ["unused-suppression"]


def test_unknown_rule_suppression_is_ignored(tmp_path):
    # a rule no selected pass checks is not "unused" — another pass owns it
    p = _write_module(tmp_path, (
        '"""Mod."""\n'
        "x = 1  # lint: disable=lock-guard\n"))
    findings = run_passes([pass_plugin("determinism")], tmp_path,
                          paths=[str(p)])
    assert findings == []


def test_suppression_budget_enforced(tmp_path):
    p = _write_module(tmp_path, (
        '"""Mod."""\n'
        "import time\n"
        "a = time.time()  # lint: disable=det-wall-clock\n"
        "b = time.time()  # lint: disable=det-wall-clock\n"))
    over = run_passes([pass_plugin("determinism")], tmp_path,
                      paths=[str(p)], budget=1)
    assert _rules(over) == ["suppression-budget"]
    under = run_passes([pass_plugin("determinism")], tmp_path,
                       paths=[str(p)], budget=2)
    assert under == []


# ---------------------------------------------------------------------------
# registry + driver
# ---------------------------------------------------------------------------

def test_builtin_passes_registered():
    assert set(pass_names()) >= {"determinism", "locks", "exceptions",
                                 "consistency"}


def test_register_pass_rejects_duplicates_and_scopes():
    dummy = AnalysisPass(name="dummy", checker=lambda src: [],
                         rules=(Rule("dummy-rule", "test"),),
                         description="test pass")
    with temporary_passes():
        register_pass(dummy)
        with pytest.raises(ValueError, match="already registered"):
            register_pass(dummy)
        register_pass(dummy, overwrite=True)
        with pytest.raises(ValueError, match="scope"):
            register_pass(AnalysisPass(
                name="weird", checker=lambda src: [], rules=(),
                description="bad scope", scope="universe"))
    assert "dummy" not in pass_names()


def test_registry_listing_has_analysis_section():
    from repro.api.cli import registry_listing
    listing = registry_listing()
    assert "analysis:" in listing
    for name in ("determinism", "locks", "exceptions", "consistency"):
        assert name in listing
    assert "lock-guard" in listing


def _run_module(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, *args],
                          capture_output=True, text=True, timeout=120,
                          env=env, cwd=cwd or REPO)


def test_repo_is_clean_under_the_driver():
    proc = _run_module("-m", "repro.analysis", "--root", str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "repro.analysis: OK" in proc.stdout


def test_check_static_writes_report(tmp_path):
    report = tmp_path / "report.json"
    proc = _run_module(str(REPO / "scripts" / "check_static.py"),
                       "--report", str(report), cwd=tmp_path)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "check_static: OK" in proc.stdout
    import json
    data = json.loads(report.read_text())
    assert data["schema_version"] == 1
    assert data["count"] == 0
