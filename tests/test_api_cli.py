"""Spec-derived CLI flags: serve and benchmarks.run round trips.

Both CLIs generate their co-execution flags from the CoexecSpec fields
(repro.api.cli), so these tests pin the contract that makes that safe:
args → spec → args → spec is the identity for both parsers, every spec
field is reachable from the command line, and the parsers stay in sync
with the spec schema automatically.
"""
import pytest

from _propcheck import given, settings, st

from repro.api import (CoexecSpec, add_spec_args, args_from_spec,
                       spec_from_args)


def serve_parser():
    from repro.launch.serve import build_parser

    return build_parser()


def bench_parser():
    from benchmarks.run import build_parser

    return build_parser(["coexec"])


def roundtrip(parser, argv, base=None):
    spec = spec_from_args(parser.parse_args(argv), base=base)
    argv2 = args_from_spec(spec, base=base or CoexecSpec())
    spec2 = spec_from_args(parser.parse_args(argv2), base=base)
    return spec, spec2


SERVE_STYLE_ARGV = [
    [],
    ["--policy", "work_stealing", "--n", "16384"],
    ["--admission", "wfq", "--fuse", "--tenants", "16"],
    ["--policy", "dynamic", "--scheduler-opt", "num_packages=32",
     "--granularity", "64"],
    ["--workload", "mandelbrot", "--size-scale", "0.5",
     "--memory", "buffers"],
    ["--kernel", "rap", "--memory", "buffers", "--n", "2048"],
    ["--units", "2", "--unit-kinds", "cpu,gpu", "--speed-hints", "0.4,0.6",
     "--dist", "0.35"],
    ["--max-inflight", "8", "--fuse-threshold", "2048", "--fuse-limit",
     "16", "--fuse-wait-s", "0.0", "--quantum", "512"],
    ["--requests", "4", "--concurrent", "2"],
    ["--kernel-impl", "pallas", "--kernel", "taylor"],
    ["--kernel-impl", "ref", "--workload", "gaussian"],
]


@pytest.mark.parametrize("argv", SERVE_STYLE_ARGV)
def test_serve_cli_spec_cli_round_trip(argv):
    spec, spec2 = roundtrip(serve_parser(), argv)
    assert spec == spec2


@pytest.mark.parametrize("argv", SERVE_STYLE_ARGV)
def test_benchmarks_cli_spec_cli_round_trip(argv):
    parser = bench_parser()
    spec, spec2 = roundtrip(parser, ["coexec"] + argv)
    assert spec == spec2
    # suites positional coexists with the derived flags
    assert parser.parse_args(["coexec"] + argv).suites == ["coexec"]


def test_serve_cli_round_trip_with_serve_base():
    """Round trip holds over serve's non-default base spec too."""
    from repro.launch.serve import default_serve_spec

    base = default_serve_spec()
    parser = serve_parser()
    argv = ["--policy", "hguided", "--admission", "wfq", "--n", "4096"]
    spec = spec_from_args(parser.parse_args(argv), base=base)
    assert spec.units == base.units          # base fields survive
    assert spec.scheduler.policy == "hguided"
    argv2 = args_from_spec(spec, base=base)
    assert spec_from_args(parser.parse_args(argv2), base=base) == spec


@settings(max_examples=20)
@given(policy=st.sampled_from(("static", "dynamic", "hguided",
                               "work_stealing", "all")),
       admission=st.sampled_from(("fifo", "wfq")),
       fuse=st.sampled_from((False, True)),
       items=st.integers(16, 1 << 18),
       tenants=st.integers(1, 32),
       granularity=st.integers(1, 128),
       max_inflight=st.integers(1, 64),
       dist=st.floats(0.1, 0.9))
def test_random_spec_regenerates_from_its_own_argv(policy, admission, fuse,
                                                   items, tenants,
                                                   granularity,
                                                   max_inflight, dist):
    spec = CoexecSpec(
        scheduler=CoexecSpec().scheduler.replace(policy=policy,
                                                 granularity=granularity),
        admission=CoexecSpec().admission.replace(policy=admission,
                                                 fuse=fuse,
                                                 max_inflight=max_inflight),
        workload=CoexecSpec().workload.replace(items=items,
                                               tenants=tenants),
        units=CoexecSpec().units.replace(dist=(dist,)),
    )
    parser = serve_parser()
    argv = args_from_spec(spec)
    assert spec_from_args(parser.parse_args(argv)) == spec


def test_bad_flag_values_error_cleanly():
    parser = serve_parser()
    with pytest.raises(SystemExit):
        parser.parse_args(["--admission", "lifo"])      # not a choice
    with pytest.raises(SystemExit):
        parser.parse_args(["--scheduler-opt", "no-equals-sign"])
    with pytest.raises(SystemExit):
        parser.parse_args(["--kernel-impl", "opencl"])  # not a choice


def test_spec_json_flag_exists():
    ns = serve_parser().parse_args(["--coexec", "sim", "--spec-json"])
    assert ns.spec_json is True


def test_list_flag_exists_on_both_clis():
    assert serve_parser().parse_args(["--list"]).list is True
    assert bench_parser().parse_args(["--list"]).list is True


def test_none_literal_resets_optional_fields_over_base():
    """Every spec is reachable from argv even over a non-default base."""
    from repro.launch.serve import default_serve_spec

    base = default_serve_spec()          # units.count=2, dist set, ...
    parser = serve_parser()
    # an all-default spec regenerates from its own argv over that base
    spec = CoexecSpec()
    argv = args_from_spec(spec, base=base)
    assert spec_from_args(parser.parse_args(argv), base=base) == spec
    # and the literal is usable by hand
    ns = parser.parse_args(["--units", "none", "--max-inflight", "none"])
    merged = spec_from_args(ns, base=base)
    assert merged.units.count is None
    assert merged.admission.max_inflight is None


def test_scheduler_opt_none_clears_base_options():
    base = CoexecSpec().replace(
        scheduler=CoexecSpec().scheduler.replace(
            policy="dynamic", options=(("num_packages", 32),)))
    parser = serve_parser()
    bare = spec_from_args(
        parser.parse_args(["--scheduler-opt", "none"]), base=base)
    assert bare.scheduler.options == ()
    # and the automatic round trip uses it: spec without options over a
    # base with options regenerates exactly
    spec = base.replace(scheduler=base.scheduler.replace(options=()))
    argv = args_from_spec(spec, base=base)
    assert spec_from_args(parser.parse_args(argv), base=base) == spec


def test_sim_rows_honor_spec_scheduler_options():
    """The DES path obeys --scheduler-opt/--granularity like the engine."""
    from repro.launch.serve import coexec_sim_rows

    spec = (CoexecSpec.builder()
            .policy("dynamic", num_packages=32)
            .workload("taylor")
            .build())
    (row,) = coexec_sim_rows(spec)
    assert row["packages"] == 32


def test_benchmarks_cli_rejects_bad_policy_cleanly():
    import os
    import pathlib
    import subprocess
    import sys

    repo = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "coexec",
         "--policy", "tpyo"],
        capture_output=True, text=True, timeout=120, env=env, cwd=repo)
    assert proc.returncode == 2          # argparse usage error, not a crash
    assert "unknown scheduling policy" in proc.stderr
    assert "Traceback" not in proc.stderr
