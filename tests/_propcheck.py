"""Hypothesis-compatible property-test shim.

The tier-1 suite must collect and run on a bare container without
`hypothesis` installed. This module exposes the small subset the tests use
(`given`, `settings`, `st.integers/floats/sampled_from/booleans/
fixed_dictionaries`); when hypothesis
is importable it is re-exported unchanged (the CI property job exercises
that path), otherwise a seeded-random fallback generates a bounded number
of cases per test deterministically.

Fallback semantics:
* `@given(**strategies)` draws each keyword from its strategy with a
  `numpy` Generator seeded from the test name — stable across runs.
* `@settings(max_examples=N, ...)` is honored, capped at
  `_FALLBACK_CAP` examples to keep the no-hypothesis profile fast; all
  other settings are ignored.
* shrinking, `@example`, and `assume` are not provided — the real
  hypothesis path in CI covers those.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    _FALLBACK_CAP = 25
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def draw(self, rng: "np.random.Generator"):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, lo: float, hi: float):
            self.lo, self.hi = float(lo), float(hi)

        def draw(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def draw(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _Booleans(_Strategy):
        def draw(self, rng):
            return bool(rng.integers(2))

    class _FixedDicts(_Strategy):
        def __init__(self, mapping):
            self.mapping = dict(mapping)

        def draw(self, rng):
            return {k: s.draw(rng) for k, s in self.mapping.items()}

    class _St:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(options) -> _Strategy:
            return _SampledFrom(options)

        @staticmethod
        def booleans() -> _Strategy:
            return _Booleans()

        @staticmethod
        def fixed_dictionaries(mapping) -> _Strategy:
            return _FixedDicts(mapping)

    st = _St()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(fn, "_propcheck_max_examples",
                                _DEFAULT_EXAMPLES), _FALLBACK_CAP)
                seed = zlib.crc32(fn.__qualname__.encode())
                for case in range(n):
                    rng = np.random.default_rng((seed, case))
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except BaseException as e:
                        raise AssertionError(
                            f"falsifying example (propcheck case {case}): "
                            f"{drawn!r}") from e
                return None
            # hide the drawn parameters from pytest's fixture resolution
            # (hypothesis does the same via its own wrapper)
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
