"""Fixture spec: every field carries _cli metadata and has a doc row."""
import dataclasses


def _cli(flag, help_, **extra):
    """Mini copy of the spec metadata helper."""
    return {"cli": flag, "help": help_, **extra}


@dataclasses.dataclass(frozen=True)
class AlphaSpec:
    """Both fields wired to CLI flags."""

    rate: float = dataclasses.field(
        default=0.0, metadata=_cli("rate", "offered rate"))
    burst: float = dataclasses.field(
        default=1.0, metadata=_cli("burst", "on-phase multiplier"))


@dataclasses.dataclass(frozen=True)
class CoexecSpec:
    """Root spec with a single section."""

    alpha: AlphaSpec = dataclasses.field(default_factory=AlphaSpec)
