"""Clean fixture: handlers that re-raise, log, or inspect the error."""
import logging


class LaunchShed(Exception):
    """Stand-in for the control-plane shed outcome."""


def run(work, shed_log):
    """Every handler observes or propagates the failure."""
    try:
        work()
    except ValueError:
        pass                    # narrow type: allowed
    try:
        work()
    except Exception as e:
        logging.getLogger(__name__).exception("work failed: %s", e)
    try:
        work()
    except BaseException:
        raise
    try:
        work()
    except LaunchShed as shed:
        shed_log.append(shed)   # decision recorded, not dropped
