"""True-positive fixture: every exception-hygiene rule fires once."""


class LaunchShed(Exception):
    """Stand-in for the control-plane shed outcome."""


def run(work):
    """Three handlers, one violation each."""
    try:
        work()
    except:                     # exc-bare-except
        pass
    try:
        work()
    except Exception:           # exc-broad-except
        pass
    try:
        work()
    except LaunchShed:          # exc-swallowed-control
        pass
