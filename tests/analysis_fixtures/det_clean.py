"""Clean fixture: deterministic decision code the pass must not flag."""
from numpy.random import default_rng


def decide(backend, queue, seed):
    """Injected clock, seeded RNG, sorted set iteration — all allowed."""
    t = backend.now()
    rng = default_rng(seed)
    order = [x for x in sorted({3, 1, 2})]
    for item in sorted(set(queue)):
        pass
    return t, rng, order
