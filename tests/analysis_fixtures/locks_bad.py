"""True-positive fixture: guarded attribute touched outside its lock."""
import threading


class Engine:
    """Threaded class with one guarded counter."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pending = 0  # guarded-by: _lock

    def bump(self):
        """Correct: mutation under the lock."""
        with self._lock:
            self._pending += 1

    def peek(self):
        """Wrong: unlocked read of the guarded counter."""
        return self._pending  # lock-guard fires here
