"""Fixture spec: one field without _cli metadata, one without a doc row."""
import dataclasses


def _cli(flag, help_, **extra):
    """Mini copy of the spec metadata helper."""
    return {"cli": flag, "help": help_, **extra}


@dataclasses.dataclass(frozen=True)
class AlphaSpec:
    """Two fields: ``rate`` is wired up, ``burst`` is not."""

    rate: float = dataclasses.field(
        default=0.0, metadata=_cli("rate", "offered rate"))
    burst: float = 1.0  # con-spec-cli: surfaces no CLI flag


@dataclasses.dataclass(frozen=True)
class CoexecSpec:
    """Root spec with a single section."""

    alpha: AlphaSpec = dataclasses.field(default_factory=AlphaSpec)
