"""Fixture: registration declaring an option the factory does not accept."""
from repro.api.registry import register_scheduler


class BadScheduler:
    """Accepts ``chunk`` (and the implied ``granularity``) only."""

    def __init__(self, total, num_units, *, chunk=1, granularity=1):
        self.total = total
        self.num_units = num_units
        self.chunk = chunk
        self.granularity = granularity


register_scheduler("fixture-bad", BadScheduler,
                   fields=("chunk", "typo_option"))  # con-plugin-fields
