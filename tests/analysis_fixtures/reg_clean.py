"""Fixture: registration whose declared fields match the factory."""
from repro.api.registry import register_scheduler


class GoodScheduler:
    """Accepts exactly the declared options plus implied granularity."""

    def __init__(self, total, num_units, *, chunk=1, granularity=1):
        self.total = total
        self.num_units = num_units
        self.chunk = chunk
        self.granularity = granularity


register_scheduler("fixture-good", GoodScheduler, fields=("chunk",))
