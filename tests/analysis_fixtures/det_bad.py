"""True-positive fixture: every determinism rule fires once or more."""
import time
from datetime import datetime

import numpy as np
from numpy.random import default_rng


def decide(queue):
    """One violation per determinism rule, line-pinned for the tests."""
    t = time.perf_counter()             # det-wall-clock
    stamp = datetime.now()              # det-naive-datetime
    rng = default_rng()                 # det-unseeded-rng (no seed)
    noise = np.random.rand(4)           # det-unseeded-rng (global RNG)
    order = [x for x in {3, 1, 2}]      # det-set-iteration
    for item in set(queue):             # det-set-iteration
        pass
    return t, stamp, rng, noise, order
