"""Clean fixture: every guarded access is locked, annotated, or exempt."""
import threading


class Engine:
    """Threaded class that follows the guarded-by discipline."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pending = 0  # guarded-by: _lock
        self._log = []     # guarded-by: caller

    def bump(self):
        """Mutation under the lock."""
        with self._lock:
            self._pending += 1

    def flush(self):  # guarded-by: _lock
        """Caller-holds contract via the def-line annotation."""
        n = self._pending
        self._pending = 0
        return n

    def note(self, msg):
        """Caller-serialized attribute needs no with-block."""
        self._log.append(msg)
