"""Pipelined per-unit dispatch (stage / issue / complete overlap).

Acceptance for the pipelining tentpole and its satellites:

* **bitwise depth parity** — every registered kernel produces
  byte-identical results at ``pipeline_depth`` 2 and 4 vs the serial
  depth-1 path, on both data planes, under all four package schedulers
  (pipelining changes *when* packages move, never what they compute);
* **depth-invariant structure** — a propcheck property that
  ``(seed, policy, depth)`` never changes the DES package cover or the
  ``DataPlaneCounters`` totals (scheduler decisions must not observe
  the pipeline);
* **kill mid-pipeline** — a unit dying with a full pipeline in flight
  has *all* of its in-flight packages disowned and re-issued exactly
  once, with covers and counter totals identical to an undisturbed run;
* **compile warm-up** (satellite) — ``JaxUnit.prewarm`` AOT-compiles
  without executing the kernel body and charges nothing to ``busy_s``;
  ``CoexecEngine.submit`` warms every package bucket before dispatch;
* **exact park wait** (satellite) — an idle engine holding a staged
  fusion group wakes at the ripen deadline, not on a coarse poll;
* **loud sync guard** (satellite) — a kernel whose output cannot be
  synchronized on (no ``block_until_ready``) fails the launch with a
  ``TypeError`` instead of silently serializing the pipeline.
"""
import time

import numpy as np
import jax
import pytest

from repro.api import CoexecSpec, build_kernel, build_scheduler, \
    kernel_demo_inputs
from repro.core import (CoexecEngine, FailurePlan, MemoryCosts, SimUnit,
                        Workload, as_coexec_kernel, replay_trace_cluster,
                        simulate, synthesize_trace, validate_cover)

from _propcheck import given, settings, st

PAPER_KERNELS = ("gaussian", "mandelbrot", "matmul", "rap", "ray", "taylor")
POLICIES = ("static", "dyn16", "hguided", "work_stealing")
N = 700          # deliberately not a power of two (uneven package sizes)


def spec_for(memory: str, policy: str, depth: int) -> CoexecSpec:
    return (CoexecSpec.builder()
            .policy(policy)
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6),
                   pipeline_depth=depth)
            .dist(0.4)
            .memory(memory)
            .build())


@pytest.fixture(scope="module")
def shared_units():
    """One unit set for the whole module (warm jit caches across tests)."""
    return spec_for("usm", "dyn16", 1).build_units()


def run_engine(memory, policy, depth, kernel, inputs, units):
    spec = spec_for(memory, policy, depth)
    with CoexecEngine.from_spec(spec, units=units) as engine:
        assert engine.pipeline_depth == depth
        sched = spec.build_scheduler(N, len(units))
        h = engine.submit(sched, kernel, inputs, kernel.alloc_out(N, inputs))
        out = h.result(timeout=120)
    return out.copy(), h.stats


# ---------------------------------------------------------------------------
# Acceptance: bitwise depth parity, every kernel x plane x policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("memory", ("usm", "buffers"))
@pytest.mark.parametrize("name", PAPER_KERNELS)
def test_depth_bitwise_parity_every_kernel(name, memory, shared_units):
    """depth ∈ {2, 4} is byte-identical to depth 1 for every registered
    kernel on both data planes under every scheduler whose package
    cover is deterministic (static / dyn16 / work_stealing — identical
    packaging means identical executables seeing identical values, so
    any difference would be the pipeline's fault). HGuided covers are
    request-order-dependent, which already perturbs XLA's per-chunk FMA
    contraction at depth 1 (see tests/test_dataplane.py) — there the
    depth axis is held to numerical equivalence plus the exact-cover
    invariant."""
    kernel = build_kernel(name)
    inputs = kernel_demo_inputs(name, N, seed=7)
    for policy in POLICIES:
        base, base_stats = run_engine(memory, policy, 1, kernel, inputs,
                                      shared_units)
        for depth in (2, 4):
            out, stats = run_engine(memory, policy, depth, kernel, inputs,
                                    shared_units)
            if policy == "hguided":
                np.testing.assert_allclose(base, out, rtol=1e-5,
                                           atol=1e-5)
            else:
                assert np.array_equal(base, out), (
                    f"{name}/{memory}/{policy}: depth {depth} differs "
                    f"from serial")
            validate_cover(stats.packages, N)
            if policy == "dyn16":   # fixed ceil-split: exact counters
                assert stats.num_packages == base_stats.num_packages
                assert stats.data.dispatches == base_stats.data.dispatches
                assert stats.data.h2d_copies == base_stats.data.h2d_copies
                assert stats.data.d2h_copies == base_stats.data.d2h_copies


# ---------------------------------------------------------------------------
# Propcheck: (seed, policy, depth) never changes covers or counter totals
# ---------------------------------------------------------------------------

def _sim_run(seed: int, policy: str, depth: int):
    rng = np.random.default_rng(seed)
    total = 2048 + 256 * int(rng.integers(0, 8))
    weights = None
    if rng.integers(0, 2):
        w = rng.uniform(0.2, 1.8, total)
        weights = w / w.mean()
    wl = Workload(name=f"prop{seed}", total=total, bytes_in_per_item=4.0,
                  bytes_out_per_item=4.0, working_set_bytes=8.0 * total,
                  weights=weights)
    units = [SimUnit("cpu", "cpu", speed=4e5 * 0.4),
             SimUnit("gpu", "gpu", speed=4e5 * 0.6, alpha=1.3)]
    kw = ({"speeds": [0.4, 0.6]}
          if policy in ("static", "hguided", "work_stealing") else {})
    sched = build_scheduler(policy, total, 2, granularity=16, **kw)
    spec = CoexecSpec.builder().pipeline_depth(depth).build()
    return simulate(sched, units, wl, spec=spec), total


@given(seed=st.integers(0, 10**6), policy=st.sampled_from(POLICIES),
       depth=st.integers(2, 4))
@settings(max_examples=12, deadline=None)
def test_sim_structure_is_depth_invariant(seed, policy, depth):
    """The DES models the overlap in *time* only: package covers,
    per-unit attribution and DataPlaneCounters totals are identical to
    the serial run for any (seed, policy, depth)."""
    r1, total = _sim_run(seed, policy, 1)
    rd, _ = _sim_run(seed, policy, depth)
    validate_cover(rd.packages, total)
    cover = lambda r: sorted((p.unit, p.offset, p.size) for p in r.packages)
    assert cover(rd) == cover(r1)
    assert rd.data == r1.data
    assert rd.host_busy_s == pytest.approx(r1.host_busy_s)
    # pipelining can only help the modeled makespan, never hurt it
    assert rd.total_s <= r1.total_s + 1e-12


# ---------------------------------------------------------------------------
# Kill mid-pipeline: every in-flight package re-issued exactly once
# ---------------------------------------------------------------------------

def test_kill_mid_pipeline_reissues_all_inflight_exactly_once():
    """A unit dying with a full pipeline (depth 2 => 2 packages in
    flight) has both disowned and re-issued exactly once; covers and
    counter totals stay bitwise identical to an undisturbed run."""
    trace = synthesize_trace(60, 40.0, tenants=4, items=4096,
                             item_jitter=0.8, slo_ms=200.0, seed=3)
    units = [SimUnit(f"u{i}", "cpu", speed=20_000.0, setup_s=1e-3)
             for i in range(4)]
    spec = CoexecSpec.builder().pipeline_depth(2).build()
    r0 = replay_trace_cluster(trace, units, admission="wfq", spec=spec)
    plan = FailurePlan(timeline=((0.2, "kill:3"),))
    r1 = replay_trace_cluster(trace, units, admission="wfq", spec=spec,
                              plan=plan)
    assert r1.kills == [(0.2, 3)]
    # the dead unit held a full pipeline: >= 2 attempts were lost and
    # re-issued; exactly once each (nothing lost, nothing duplicated)
    assert r1.reissued >= 2
    assert r1.lost == 0 and r1.duplicated == 0
    assert r1.completed == r0.completed == len(trace)
    assert r1.covers() == r0.covers()
    assert r1.data_totals() == r0.data_totals()


# ---------------------------------------------------------------------------
# Satellite: compile warm-up is AOT and never charged to busy clocks
# ---------------------------------------------------------------------------

def test_prewarm_compiles_without_executing_or_charging_busy(shared_units):
    calls = []

    def body(off, chunk):
        def host(c):
            calls.append(1)
            return np.asarray(c) * 2.0
        return jax.pure_callback(
            host, jax.ShapeDtypeStruct(chunk.shape, chunk.dtype), chunk)

    unit = shared_units[0]
    args = [np.ones(64, np.float32)]
    busy0 = unit.busy_s
    unit.prewarm(body, args)
    assert calls == [], "prewarm must not execute the kernel body"
    assert unit.busy_s == busy0, "warm-up charged to the busy clock"
    # the warmed executable computes the same thing the jit path does
    out = unit.dispatch(body, 0, args)
    out.block_until_ready()
    assert calls, "dispatch after prewarm never ran the kernel"
    np.testing.assert_array_equal(np.asarray(out), args[0] * 2.0)
    # memoized: warming the same bucket again is a no-op
    unit.prewarm(body, args)


def test_submit_prewarms_every_bucket_before_dispatch(shared_units):
    """The engine warms each power-of-two package bucket at submit time,
    so the first dispatch of every bucket runs a precompiled executable
    (XLA compile time never lands in ``busy_s``/SpeedBoard samples)."""
    kernel = as_coexec_kernel(lambda off, c: c * 3.0, 1)  # fresh fn object
    inputs = [np.random.default_rng(0).normal(size=N).astype(np.float32)]
    warmed0 = {id(u): len(u._aot) for u in shared_units}
    out, stats = run_engine("usm", "dyn16", 2, kernel, inputs, shared_units)
    for u in shared_units:
        assert len(u._aot) > warmed0[id(u)], (
            f"{u.name}: submit left no ahead-of-time executables")
    validate_cover(stats.packages, N)


# ---------------------------------------------------------------------------
# Satellite: exact park wait — ripen deadlines, not a coarse poll
# ---------------------------------------------------------------------------

def test_idle_engine_flushes_fusion_group_within_ripen_window(shared_units):
    """A staged fusion group left alone on an idle engine is flushed by
    a worker waking at the ripen deadline. The pre-pipelining park loop
    polled every 100 ms, so a 30 ms window could not complete before
    ~100 ms; the exact wait must finish well under that."""
    spec = (CoexecSpec.builder()
            .policy("dyn16")
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6),
                   pipeline_depth=2)
            .dist(0.4)
            .fuse(True, threshold=4096, limit=8, wait_s=0.03)
            .build())
    kernel = build_kernel("taylor")
    inputs = kernel_demo_inputs("taylor", 256, seed=1)
    with CoexecEngine.from_spec(spec, units=shared_units) as engine:
        sched = spec.build_scheduler(256, 2)
        t0 = time.perf_counter()
        h = engine.submit(sched, kernel, inputs,
                          kernel.alloc_out(256, inputs))
        out = h.result(timeout=30)
        elapsed = time.perf_counter() - t0
    assert out is not None
    assert elapsed < 0.09, (
        f"fusion window (30 ms) took {elapsed * 1e3:.0f} ms to flush — "
        f"workers are polling instead of waiting on the ripen deadline")


# ---------------------------------------------------------------------------
# Satellite: outputs the plane cannot synchronize on fail loudly
# ---------------------------------------------------------------------------

def test_unsyncable_kernel_output_raises_loudly(shared_units):
    """A kernel returning something without ``block_until_ready`` (here
    a tuple) must fail the launch with a TypeError naming the contract —
    never fall back to a silent host sync that would serialize the
    pipeline unnoticed."""
    tuple_kernel = as_coexec_kernel(lambda off, c: (c * 2.0,), 1)
    data = np.ones(128, np.float32)
    spec = spec_for("usm", "dyn16", 2)
    with CoexecEngine.from_spec(spec, units=shared_units) as engine:
        sched = spec.build_scheduler(128, 2)
        h = engine.submit(sched, tuple_kernel, [data],
                          np.zeros(128, np.float32))
        with pytest.raises(TypeError, match="block_until_ready"):
            h.result(timeout=30)
