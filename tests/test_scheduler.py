"""Property-based tests for the load-balancing algorithms (paper §3.2)."""
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.api import build_scheduler
from repro.core import (DynamicScheduler, HGuidedScheduler, StaticScheduler,
                        WorkStealingScheduler, static_bounds,
                        validate_cover)

ALL_POLICIES = ["static", "dyn5", "dyn200", "hguided", "work_stealing"]


def drain(sched, num_units, order_seed=0):
    """Serve packages round-robin-ish until exhausted; return packages."""
    rng = np.random.default_rng(order_seed)
    pkgs = []
    active = list(range(num_units))
    while active:
        u = int(rng.choice(active))
        p = sched.next_package(u)
        if p is None:
            active.remove(u)
        else:
            pkgs.append(p)
    return pkgs


@given(total=st.integers(1, 500_000),
       units=st.integers(1, 8),
       gran=st.sampled_from([1, 16, 64, 128]),
       policy=st.sampled_from(ALL_POLICIES),
       seed=st.integers(0, 5))
@settings(max_examples=120, deadline=None)
def test_exact_cover(total, units, gran, policy, seed):
    """THE invariant: every work-item computed exactly once, any policy."""
    kw = {}
    if policy in ("static", "hguided", "work_stealing"):
        kw["speeds"] = [1.0 + 0.5 * i for i in range(units)]
    sched = build_scheduler(policy, total, units, granularity=gran, **kw)
    pkgs = drain(sched, units, seed)
    validate_cover(pkgs, total)
    assert sched.done() and sched.remaining == 0


@given(total=st.integers(1, 200_000),
       units=st.integers(1, 8),
       gran=st.sampled_from([1, 16, 64]),
       policy=st.sampled_from(ALL_POLICIES))
@settings(max_examples=60, deadline=None)
def test_granularity_alignment(total, units, gran, policy):
    """Every package except the global tail starts and sizes on a
    granularity boundary (the kernel's local work size)."""
    kw = {}
    if policy in ("static", "hguided", "work_stealing"):
        kw["speeds"] = [1.0 + i for i in range(units)]
    sched = build_scheduler(policy, total, units, granularity=gran, **kw)
    pkgs = sorted(drain(sched, units, 1), key=lambda p: p.offset)
    for p in pkgs:
        assert p.offset % gran == 0, (p.offset, gran)
    for p in pkgs[:-1]:
        assert p.size % gran == 0, (p.size, gran)


@given(total=st.integers(1, 100_000),
       units=st.integers(1, 8),
       policy=st.sampled_from(ALL_POLICIES),
       seed=st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_no_overlap_and_termination(total, units, policy, seed):
    """Ranges are pairwise disjoint and every unit's request stream
    terminates (returns None) once the index space is exhausted."""
    kw = {}
    if policy in ("static", "hguided", "work_stealing"):
        kw["speeds"] = [0.5 + 0.25 * i for i in range(units)]
    sched = build_scheduler(policy, total, units, **kw)
    pkgs = sorted(drain(sched, units, seed), key=lambda p: p.offset)
    for a, b in zip(pkgs, pkgs[1:]):
        assert not a.rng.overlaps(b.rng), (a.rng, b.rng)
    for u in range(units):
        assert sched.next_package(u) is None


@given(total=st.integers(1000, 1_000_000),
       units=st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_static_proportional(total, units):
    speeds = [1.0 + i for i in range(units)]
    sched = StaticScheduler(total, units, speeds=speeds)
    pkgs = sorted(drain(sched, units), key=lambda p: p.unit)
    assert len(pkgs) == units                 # exactly one per unit
    shares = np.array([p.size for p in pkgs], float) / total
    want = np.array(speeds) / sum(speeds)
    np.testing.assert_allclose(shares, want, atol=0.02)


@given(total=st.integers(1000, 500_000), n=st.sampled_from([5, 50, 200]))
@settings(max_examples=40, deadline=None)
def test_dynamic_package_count(total, n):
    sched = DynamicScheduler(total, 2, num_packages=n)
    pkgs = drain(sched, 2)
    # ceil-split may produce up to n packages; never more
    assert len(pkgs) <= n
    assert len(pkgs) >= min(n, total) - n // 2


@given(total=st.integers(10_000, 1_000_000),
       cpu_share=st.floats(0.05, 0.6))
@settings(max_examples=40, deadline=None)
def test_hguided_sizes_decrease(total, cpu_share):
    """Per unit, package sizes are non-increasing down to the floor."""
    sched = HGuidedScheduler(total, 2, speeds=[cpu_share, 1 - cpu_share],
                             min_package=64)
    per_unit = {0: [], 1: []}
    pkgs = drain(sched, 2, order_seed=3)
    for p in pkgs:
        per_unit[p.unit].append(p.size)
    for u, sizes in per_unit.items():
        body = sizes[:-1]  # the tail package may be any remainder
        for a, b in zip(body, body[1:]):
            assert a >= b or a <= 64 * 2, (u, sizes)


def test_hguided_first_packages_proportional():
    sched = HGuidedScheduler(1_000_000, 2, speeds=[0.25, 0.75])
    p0 = sched.next_package(0)
    p1 = sched.next_package(1)
    # size_i = rem * s_i / (K * sum) with K = 2
    assert abs(p0.size - 1_000_000 * 0.25 / 2) < 1000
    assert abs(p1.size - (1_000_000 - p0.size) * 0.75 / 2) < 1000


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------

def test_work_stealing_seeds_proportional_chunks():
    sched = WorkStealingScheduler(100_000, 2, speeds=[0.25, 0.75],
                                  chunks_per_unit=8)
    bounds = static_bounds(100_000, [0.25, 0.75])
    # each unit's deque holds exactly its static region, in 8 chunks
    assert sched._load == [bounds[1], 100_000 - bounds[1]]
    assert len(sched._deques[0]) == len(sched._deques[1]) == 8


def test_work_stealing_idle_unit_steals_half():
    sched = WorkStealingScheduler(80_000, 2, speeds=[0.5, 0.5],
                                  chunks_per_unit=8)
    # unit 0 drains its own region first (no steals while it has local work)
    for _ in range(8):
        p = sched.next_package(0)
        assert p is not None and p.offset < 40_000
    assert sched.steals == 0
    # next request: unit 0 steals half of unit 1's 8 remaining chunks
    p = sched.next_package(0)
    assert p is not None and p.offset >= 40_000
    assert sched.steals == 1
    assert len(sched._deques[0]) == 3           # 4 stolen, 1 issued
    assert len(sched._deques[1]) == 4
    drain(sched, 2)
    validate_cover(sched.issued, 80_000)


def test_work_stealing_victim_is_most_loaded():
    sched = WorkStealingScheduler(90_000, 3, speeds=[1.0, 1.0, 1.0],
                                  chunks_per_unit=4)
    # drain unit 0 fully and unit 1 partially; unit 2 untouched (max load)
    for _ in range(4):
        sched.next_package(0)
    sched.next_package(1)
    before = sched._load[2]
    sched.next_package(0)        # forces a steal
    assert sched.steals == 1
    assert sched._load[2] < before          # unit 2 was the victim


def test_work_stealing_package_count_is_deterministic():
    """Steals move chunks without splitting: total package count equals the
    seeded chunk count regardless of serve order (the DES↔engine parity
    anchor)."""
    counts = set()
    for seed in range(6):
        sched = WorkStealingScheduler(123_457, 4,
                                      speeds=[1.0, 2.0, 3.0, 4.0],
                                      chunks_per_unit=6, granularity=16)
        counts.add(len(drain(sched, 4, order_seed=seed)))
    assert len(counts) == 1


def test_work_stealing_single_unit_degenerates():
    sched = WorkStealingScheduler(1000, 1, chunks_per_unit=4)
    pkgs = drain(sched, 1)
    validate_cover(pkgs, 1000)
    assert sched.steals == 0


def test_registry_and_validation():
    with pytest.raises(KeyError):
        build_scheduler("nope", 10, 1)
    with pytest.raises(ValueError):
        build_scheduler("static", 0, 1)
    with pytest.raises(ValueError):
        build_scheduler("hguided", 10, 2, speeds=[1.0])
    with pytest.raises(ValueError):
        build_scheduler("work_stealing", 10, 2, speeds=[1.0, -1.0])
    s = build_scheduler("dyn17", 1000, 2)
    assert s.num_packages == 17
    assert build_scheduler("work-stealing", 100, 2).name == "work_stealing"
