"""Property-based tests for the load-balancing algorithms (paper §3.2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicScheduler, HGuidedScheduler, StaticScheduler,
                        make_scheduler, validate_cover)


def drain(sched, num_units, order_seed=0):
    """Serve packages round-robin-ish until exhausted; return packages."""
    rng = np.random.default_rng(order_seed)
    pkgs = []
    active = list(range(num_units))
    while active:
        u = int(rng.choice(active))
        p = sched.next_package(u)
        if p is None:
            active.remove(u)
        else:
            pkgs.append(p)
    return pkgs


@given(total=st.integers(1, 500_000),
       units=st.integers(1, 8),
       gran=st.sampled_from([1, 16, 64, 128]),
       policy=st.sampled_from(["static", "dyn5", "dyn200", "hguided"]),
       seed=st.integers(0, 5))
@settings(max_examples=120, deadline=None)
def test_exact_cover(total, units, gran, policy, seed):
    """THE invariant: every work-item computed exactly once, any policy."""
    kw = {}
    if policy in ("static", "hguided"):
        kw["speeds"] = [1.0 + 0.5 * i for i in range(units)]
    sched = make_scheduler(policy, total, units, granularity=gran, **kw)
    pkgs = drain(sched, units, seed)
    validate_cover(pkgs, total)


@given(total=st.integers(1000, 1_000_000),
       units=st.integers(2, 6))
@settings(max_examples=40, deadline=None)
def test_static_proportional(total, units):
    speeds = [1.0 + i for i in range(units)]
    sched = StaticScheduler(total, units, speeds=speeds)
    pkgs = sorted(drain(sched, units), key=lambda p: p.unit)
    assert len(pkgs) == units                 # exactly one per unit
    shares = np.array([p.size for p in pkgs], float) / total
    want = np.array(speeds) / sum(speeds)
    np.testing.assert_allclose(shares, want, atol=0.02)


@given(total=st.integers(1000, 500_000), n=st.sampled_from([5, 50, 200]))
@settings(max_examples=40, deadline=None)
def test_dynamic_package_count(total, n):
    sched = DynamicScheduler(total, 2, num_packages=n)
    pkgs = drain(sched, 2)
    # ceil-split may produce up to n packages; never more
    assert len(pkgs) <= n
    assert len(pkgs) >= min(n, total) - n // 2


@given(total=st.integers(10_000, 1_000_000),
       cpu_share=st.floats(0.05, 0.6))
@settings(max_examples=40, deadline=None)
def test_hguided_sizes_decrease(total, cpu_share):
    """Per unit, package sizes are non-increasing down to the floor."""
    sched = HGuidedScheduler(total, 2, speeds=[cpu_share, 1 - cpu_share],
                             min_package=64)
    per_unit = {0: [], 1: []}
    pkgs = drain(sched, 2, order_seed=3)
    for p in pkgs:
        per_unit[p.unit].append(p.size)
    for u, sizes in per_unit.items():
        body = sizes[:-1]  # the tail package may be any remainder
        for a, b in zip(body, body[1:]):
            assert a >= b or a <= 64 * 2, (u, sizes)


def test_hguided_first_packages_proportional():
    sched = HGuidedScheduler(1_000_000, 2, speeds=[0.25, 0.75])
    p0 = sched.next_package(0)
    p1 = sched.next_package(1)
    # size_i = rem * s_i / (K * sum) with K = 2
    assert abs(p0.size - 1_000_000 * 0.25 / 2) < 1000
    assert abs(p1.size - (1_000_000 - p0.size) * 0.75 / 2) < 1000


def test_registry_and_validation():
    with pytest.raises(KeyError):
        make_scheduler("nope", 10, 1)
    with pytest.raises(ValueError):
        make_scheduler("static", 0, 1)
    with pytest.raises(ValueError):
        make_scheduler("hguided", 10, 2, speeds=[1.0])
    s = make_scheduler("dyn17", 1000, 2)
    assert s.num_packages == 17
