"""The benchmark driver CLI contract.

* a typo'd suite name exits nonzero (CI must not pass while measuring
  nothing);
* the ``kernels`` suite produces the schema-tagged ``BENCH_kernels.json``
  artifact with one row per (wrapper, impl) pair — at least two impl
  variants per kernel, validated by ``scripts/check_bench_schema.py``
  (the same checker CI's docs job runs).
"""
import json
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run_driver(*args: str, cwd=None) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *args],
                          capture_output=True, text=True, timeout=300,
                          env=env, cwd=cwd or REPO)


def test_unknown_suite_exits_nonzero():
    proc = _run_driver("nope")
    assert proc.returncode == 2
    assert "unknown suite" in proc.stderr
    assert "Traceback" not in proc.stderr


def test_unknown_suite_fails_even_next_to_a_known_one():
    """A typo in a suite list still fails the run after the valid suites
    execute (the pre-fix driver printed a warning and exited 0)."""
    proc = _run_driver("nope", "kernels", "--smoke",
                       "--bench-kernels-json", os.devnull)
    assert proc.returncode == 2
    assert "unknown suite nope" in proc.stderr
    # the valid suite still ran and reported its rows first
    assert "kernel/" in proc.stdout


def test_kernels_smoke_rows_cover_impl_axis():
    from benchmarks import kernel_micro

    rows = kernel_micro.structured_rows(smoke=True)
    by_kernel = {}
    for row in rows:
        by_kernel.setdefault(row["kernel"], set()).add(row["impl"])
        assert row["kind"] == "kernel"
        assert row["us_per_call"] > 0
    assert len(by_kernel) == 8                   # every public wrapper
    for name, impls in by_kernel.items():
        assert len(impls) >= 2, (
            f"{name}: need >=2 impl variants per kernel, got {impls}")


def test_kernels_artifact_passes_schema_check(tmp_path):
    from benchmarks import kernel_micro
    from benchmarks.run import write_bench_doc
    from repro.api import CoexecSpec

    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import check_bench_schema as cbs
    finally:
        sys.path.pop(0)

    rows = kernel_micro.structured_rows(smoke=True)
    path = tmp_path / "BENCH_kernels.json"
    write_bench_doc(str(path), "kernels", CoexecSpec(), rows)
    doc = json.loads(path.read_text())
    assert cbs.check_doc(str(path), doc) == []
    assert doc["suite"] == "kernels"
    assert doc["schema_version"] == cbs.SCHEMA_VERSION
