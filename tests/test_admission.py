"""Admission layer: WFQ fairness, launch fusion, backpressure, timeouts.

The precise fairness/fusion ratios are pinned on the deterministic
multi-launch DES (`simulate_multi`); the real-engine tests pin the
correctness invariants (bitwise results, fewer dispatches, AdmissionFull,
LaunchWaitTimeout-vs-launch-failure) that survive thread scheduling.
"""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import AdmissionSpec, CoexecSpec, build_scheduler
from repro.core import (AdmissionConfig, AdmissionController, AdmissionFull,
                        CoexecEngine, CoexecutorRuntime, LaunchSpec,
                        LaunchWaitTimeout, SimUnit, Workload,
                        counits_from_devices, jain_index,
                        simulate_multi, validate_cover)

T = 512



def engine_with(units, admission=None):
    """Engine configured from an AdmissionConfig/policy name via the spec."""
    if admission is None:
        admission = AdmissionConfig()
    if isinstance(admission, str):
        admission = AdmissionConfig(policy=admission)
    spec = CoexecSpec(admission=AdmissionSpec.from_config(admission))
    return CoexecEngine(units, spec=spec)

def two_units():
    devs = jax.local_devices()[:1] * 2
    return counits_from_devices(devs, kinds=["cpu", "cpu"],
                                speed_hints=[0.4, 0.6])


def sim_units(speed=1000.0):
    return [SimUnit("u0", "cpu", speed=speed, setup_s=1e-3),
            SimUnit("u1", "cpu", speed=speed, setup_s=1e-3)]


def uniform_wl(total, name="uni"):
    return Workload(name, total, bytes_in_per_item=8.0,
                    bytes_out_per_item=8.0, working_set_bytes=1e4)


def affine_kernel(offset, chunk):
    idx = jnp.arange(chunk.shape[0], dtype=jnp.float32) + offset
    return chunk * 2.0 + idx


def expected(data):
    return data * 2.0 + np.arange(len(data), dtype=np.float32)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_admission_config_validates():
    with pytest.raises(ValueError, match="policy"):
        AdmissionConfig(policy="lifo")
    with pytest.raises(ValueError):
        AdmissionConfig(max_inflight=0)
    with pytest.raises(ValueError):
        AdmissionConfig(fuse_threshold=-1)
    with pytest.raises(ValueError):
        AdmissionConfig(quantum=0)
    assert AdmissionConfig(policy="wfq").policy == "wfq"


def test_jain_index():
    assert jain_index([1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert jain_index([1.0, 0.0, 0.0]) == pytest.approx(1.0 / 3.0)
    with pytest.raises(ValueError):
        jain_index([])


# ---------------------------------------------------------------------------
# WFQ fairness (deterministic, on the DES)
# ---------------------------------------------------------------------------

def _two_tenant_specs(total=20000, num_packages=200):
    return [LaunchSpec(uniform_wl(total),
                       build_scheduler("dynamic", total, 2,
                                      num_packages=num_packages),
                       tenant=t, weight=w)
            for t, w in (("A", 2.0), ("B", 1.0))]


def test_wfq_two_tenants_2to1_within_10pct():
    """Acceptance: weights 2:1 ⇒ completed-work ratio within 10% of 2:1
    while both tenants are backlogged (measured at the first finish)."""
    res = simulate_multi(_two_tenant_specs(), sim_units(), admission="wfq")
    first_finish = min(l.t_finish for l in res.launches)
    served = res.tenant_service_until(first_finish)
    ratio = served["A"] / served["B"]
    assert 1.8 <= ratio <= 2.2
    # every launch still completes exactly (cover validated inside)
    assert len(res.launches) == 2
    assert all(l.items == 20000 for l in res.launches)


def test_fifo_starves_late_tenant_wfq_does_not():
    """FIFO drains tenant A before B gets service; WFQ interleaves, so
    B's share at A's finish is ~half of A's rather than ~zero."""
    fifo = simulate_multi(_two_tenant_specs(), sim_units(), admission="fifo")
    first = min(l.t_finish for l in fifo.launches)
    assert fifo.tenant_service_until(first).get("B", 0) == 0

    wfq = simulate_multi(_two_tenant_specs(), sim_units(), admission="wfq")
    first = min(l.t_finish for l in wfq.launches)
    assert wfq.tenant_service_until(first)["B"] > 0


def test_wfq_tiny_quantum_still_completes_every_launch():
    """Regression: a quantum far below package size must not wedge the
    DRR scan — empty rounds fast-forward instead of starving flows."""
    specs = _two_tenant_specs(total=8000, num_packages=20)
    res = simulate_multi(specs, sim_units(),
                         admission=AdmissionConfig(policy="wfq", quantum=1))
    assert len(res.launches) == 2
    assert all(l.items == 8000 for l in res.launches)


def test_wfq_fractional_weights_complete_and_stay_proportional():
    """Regression: weights < 1 (credit per round below one package) must
    neither drop launches nor distort the weight ratio."""
    specs = [LaunchSpec(uniform_wl(20000),
                        build_scheduler("dynamic", 20000, 2,
                                       num_packages=200),
                        tenant=t, weight=w)
             for t, w in (("A", 0.10), ("B", 0.05))]
    res = simulate_multi(specs, sim_units(), admission="wfq")
    assert len(res.launches) == 2
    first_finish = min(l.t_finish for l in res.launches)
    served = res.tenant_service_until(first_finish)
    assert 1.8 <= served["A"] / served["B"] <= 2.2


def test_wfq_equal_weights_fair_across_many_tenants():
    specs = [LaunchSpec(uniform_wl(4096),
                        build_scheduler("dynamic", 4096, 2, num_packages=32),
                        tenant=f"t{i}")
             for i in range(8)]
    res = simulate_multi(specs, sim_units(), admission="wfq")
    thru = [l.items / l.latency_s for l in res.launches]
    assert jain_index(thru) > 0.95


# ---------------------------------------------------------------------------
# fusion (deterministic, on the DES)
# ---------------------------------------------------------------------------

def _tiny_specs(n=16, total=256):
    return [LaunchSpec(uniform_wl(total, "tiny"),
                       build_scheduler("dyn8", total, 2), tenant=f"t{i}")
            for i in range(n)]


def test_sim_fusion_fewer_packages_equal_cover():
    """Acceptance: the fused 16-tenant sim sweep dispatches fewer packages
    than unfused while every launch's index space is still covered."""
    unfused = simulate_multi(_tiny_specs(), sim_units(),
                             admission=AdmissionConfig(fuse=False))
    fused = simulate_multi(_tiny_specs(), sim_units(),
                           admission=AdmissionConfig(
                               fuse=True, fuse_threshold=1024,
                               fuse_wait_s=0.0))
    assert fused.dispatched_packages < unfused.dispatched_packages
    assert fused.fused_batches == 1 and fused.fused_members == 16
    assert len(fused.launches) == len(unfused.launches) == 16
    assert all(l.fused for l in fused.launches)
    assert all(l.items == 256 and l.latency_s > 0 for l in fused.launches)


def test_sim_fusion_service_curve_keeps_tenant_attribution():
    """Regression: fused dispatches must credit the member tenants, not
    the synthetic fused flow, in the service curve."""
    res = simulate_multi(_tiny_specs(8), sim_units(),
                         admission=AdmissionConfig(fuse=True,
                                                   fuse_threshold=1024,
                                                   fuse_wait_s=0.0))
    served = res.tenant_service_until(res.total_s)
    assert set(served) == {f"t{i}" for i in range(8)}
    assert all(v == 256 for v in served.values())


def test_sim_fusion_respects_threshold():
    """Launches above fuse_threshold are never staged."""
    big = simulate_multi(_tiny_specs(total=4096), sim_units(),
                         admission=AdmissionConfig(fuse=True,
                                                   fuse_threshold=256,
                                                   fuse_wait_s=0.0))
    assert big.fused_batches == 0
    assert not any(l.fused for l in big.launches)


def test_sim_fusion_only_same_shape_coalesces():
    specs = _tiny_specs(4, total=256) + _tiny_specs(4, total=128)
    res = simulate_multi(specs, sim_units(),
                         admission=AdmissionConfig(fuse=True,
                                                   fuse_threshold=1024,
                                                   fuse_wait_s=0.0))
    # two distinct fuse keys -> two batches, never one mixed batch
    assert res.fused_batches == 2
    assert res.fused_members == 8


# ---------------------------------------------------------------------------
# fusion on the real engine
# ---------------------------------------------------------------------------

def test_engine_fusion_bitwise_identical_and_fewer_dispatches():
    """Acceptance: 16 identical-shape small launches produce bitwise-
    identical results to unfused execution with fewer total dispatches."""
    datas = [np.random.default_rng(i).normal(size=T).astype(np.float32)
             for i in range(16)]

    with CoexecEngine(two_units()) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        unfused = [h.result(timeout=120).copy() for h in handles]
        unfused_dispatches = engine.admission.dispatched

    cfg = AdmissionConfig(fuse=True, fuse_threshold=1024, fuse_wait_s=0.5)
    with engine_with(two_units(), cfg) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        fused = [h.result(timeout=120) for h in handles]
        fused_dispatches = engine.admission.dispatched
        assert engine.admission.fused_batches >= 1
        assert engine.admission.fused_members >= 2

    for a, b in zip(unfused, fused):
        assert np.array_equal(a, b)          # bitwise, not approx
    assert fused_dispatches < unfused_dispatches


def test_engine_fused_members_get_isolated_stats():
    datas = [np.arange(T, dtype=np.float32) for _ in range(6)]
    cfg = AdmissionConfig(fuse=True, fuse_threshold=1024, fuse_wait_s=0.5)
    with engine_with(two_units(), cfg) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        for h in handles:
            np.testing.assert_allclose(h.result(timeout=120),
                                       expected(datas[0]))
            assert h.stats is not None
            validate_cover(h.stats.packages, T)
            assert h.stats.total_s > 0
            assert sum(h.stats.unit_busy_s.values()) > 0


def test_engine_fusion_index_dependent_kernel_offsets_stay_local():
    """The fused vmapped dispatch must present each member a *local*
    offset of 0, or index-dependent kernels silently corrupt."""
    datas = [np.full(T, float(i), np.float32) for i in range(8)]
    cfg = AdmissionConfig(fuse=True, fuse_threshold=1024, fuse_wait_s=0.5)
    with engine_with(two_units(), cfg) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        outs = [h.result(timeout=120) for h in handles]
    for i, o in enumerate(outs):
        np.testing.assert_allclose(o, expected(datas[i]))


def test_engine_fusion_failure_fails_all_members():
    def bad_kernel(offset, chunk):
        raise RuntimeError("boom")

    datas = [np.arange(T, dtype=np.float32) for _ in range(4)]
    cfg = AdmissionConfig(fuse=True, fuse_threshold=1024, fuse_wait_s=0.5)
    with engine_with(two_units(), cfg) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), bad_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        for h in handles:
            with pytest.raises(RuntimeError, match="boom"):
                h.result(timeout=120)


# ---------------------------------------------------------------------------
# WFQ on the real engine
# ---------------------------------------------------------------------------

def test_engine_wfq_completes_all_tenants_correctly():
    datas = [np.random.default_rng(i).normal(size=T).astype(np.float32)
             for i in range(8)]
    with engine_with(two_units(), "wfq") as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32),
                                 tenant=f"t{i % 2}",
                                 weight=2.0 if i % 2 == 0 else 1.0)
                   for i, d in enumerate(datas)]
        for h, d in zip(handles, datas):
            np.testing.assert_allclose(h.result(timeout=120), expected(d))
            validate_cover(h.stats.packages, T)


def test_runtime_passes_admission_through():
    data = np.random.default_rng(0).normal(size=T).astype(np.float32)
    spec = (CoexecSpec.builder().policy("dyn8")
            .admission(wfq=True).fuse(True).build())
    with CoexecutorRuntime.from_spec(spec, units=two_units()) as rt:
        h = rt.launch_async(T, affine_kernel, [data], tenant="a", weight=2.0)
        np.testing.assert_allclose(h.result(timeout=120), expected(data))
        assert rt.engine.admission.config.policy == "wfq"
        assert rt.engine.admission.config.fuse is True


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

def test_engine_backpressure_nonblocking_raises_then_recovers():
    gate = threading.Event()

    def gated_kernel(offset, chunk):
        def host(c):
            gate.wait(20)
            return c
        return jax.pure_callback(
            host, jax.ShapeDtypeStruct(chunk.shape, chunk.dtype), chunk)

    data = np.arange(T, dtype=np.float32)
    try:
        with engine_with(two_units(), AdmissionConfig(max_inflight=2)) as engine:
            h1 = engine.submit(build_scheduler("dyn4", T, 2), gated_kernel,
                               [data], np.zeros(T, np.float32))
            h2 = engine.submit(build_scheduler("dyn4", T, 2), gated_kernel,
                               [data], np.zeros(T, np.float32))
            with pytest.raises(AdmissionFull, match="max_inflight"):
                engine.submit(build_scheduler("dyn4", T, 2), affine_kernel,
                              [data], np.zeros(T, np.float32), block=False)
            assert engine.admission.in_flight == 2
            gate.set()
            h1.result(timeout=120)
            h2.result(timeout=120)
            # capacity freed: blocking submit (the default) goes through
            h3 = engine.submit(build_scheduler("dyn4", T, 2), affine_kernel,
                               [data], np.zeros(T, np.float32))
            np.testing.assert_allclose(h3.result(timeout=120), expected(data))
            assert engine.admission.in_flight == 0
    finally:
        gate.set()


def test_submit_rejects_nonpositive_weight():
    with CoexecEngine(two_units()) as engine:
        with pytest.raises(ValueError, match="weight"):
            engine.submit(build_scheduler("dyn4", T, 2), affine_kernel,
                          [np.zeros(T, np.float32)],
                          np.zeros(T, np.float32), weight=0.0)


# ---------------------------------------------------------------------------
# LaunchHandle timeout distinction (satellite fix)
# ---------------------------------------------------------------------------

def test_wait_timeout_raises_launch_wait_timeout():
    gate = threading.Event()

    def gated_kernel(offset, chunk):
        def host(c):
            gate.wait(20)
            return c
        return jax.pure_callback(
            host, jax.ShapeDtypeStruct(chunk.shape, chunk.dtype), chunk)

    data = np.arange(T, dtype=np.float32)
    try:
        with CoexecEngine(two_units()) as engine:
            h = engine.submit(build_scheduler("dyn4", T, 2), gated_kernel,
                              [data], np.zeros(T, np.float32))
            with pytest.raises(LaunchWaitTimeout):
                h.result(timeout=0.2)
            with pytest.raises(LaunchWaitTimeout):
                h.exception(timeout=0.2)
            # LaunchWaitTimeout stays a TimeoutError for broad handlers
            assert issubclass(LaunchWaitTimeout, TimeoutError)
            gate.set()
            h.result(timeout=120)
    finally:
        gate.set()


def test_launch_failed_with_timeouterror_is_returned_not_raised():
    """A kernel's own TimeoutError must surface as the launch failure —
    never be conflated with (or swallowed by) a wait timeout."""
    def bad_kernel(offset, chunk):
        raise TimeoutError("kernel timed out")

    data = np.arange(T, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        h = engine.submit(build_scheduler("dyn4", T, 2), bad_kernel,
                          [data], np.zeros(T, np.float32))
        exc = h.exception(timeout=120)       # returned, not raised
        assert isinstance(exc, TimeoutError)
        assert not isinstance(exc, LaunchWaitTimeout)
        with pytest.raises(TimeoutError, match="kernel timed out"):
            h.result(timeout=120)            # raised as-is, wrong class? no
        try:
            h.result(timeout=120)
        except LaunchWaitTimeout:            # pragma: no cover - regression
            pytest.fail("launch failure misreported as wait timeout")
        except TimeoutError:
            pass


# ---------------------------------------------------------------------------
# controller unit behavior (no threads)
# ---------------------------------------------------------------------------

class _FakeEntry:
    def __init__(self, sched, tenant="t", weight=1.0):
        self.scheduler = sched
        self.tenant = tenant
        self.weight = weight
        self.fuse_key = None


def test_controller_fifo_matches_submit_order():
    ctl = AdmissionController(2)
    a = _FakeEntry(build_scheduler("dyn4", 256, 2), "a")
    b = _FakeEntry(build_scheduler("dyn4", 256, 2), "b")
    ctl.admit(a)
    ctl.admit(b)
    entry, pkg = ctl.next_work(0)
    assert entry is a and pkg.size > 0
    # FIFO keeps draining a before b
    assert ctl.next_work(1)[0] is a


def test_controller_capacity_accounting():
    ctl = AdmissionController(2, AdmissionConfig(max_inflight=1))
    a = _FakeEntry(build_scheduler("dyn4", 256, 2))
    assert ctl.has_capacity()
    ctl.admit(a)
    assert not ctl.has_capacity()
    ctl.discard(a)
    assert ctl.has_capacity() and ctl.drained()


def test_sim_rejects_nonpositive_weight():
    """Regression: the sim path must validate weights like the engine
    does (weight=0 divided the WFQ fast-forward; negative hung it)."""
    for w in (0.0, -1.0):
        specs = [LaunchSpec(uniform_wl(1024),
                            build_scheduler("dyn4", 1024, 2),
                            tenant="A", weight=w)]
        with pytest.raises(ValueError, match="weight"):
            simulate_multi(specs, sim_units(), admission="wfq")


def test_engine_accepts_default_and_wfq_specs():
    """Regression: a spec-less engine defaults to FIFO admission."""
    eng = CoexecEngine(two_units())
    assert eng.admission.config.policy == "fifo"
    eng2 = engine_with(two_units(), "wfq")
    assert eng2.admission.config.policy == "wfq"


def test_engine_wfq_plus_fuse_completes_correctly():
    """WFQ and fusion compose on the real engine: results stay exact."""
    datas = [np.arange(T, dtype=np.float32) for _ in range(6)]
    cfg = AdmissionConfig(policy="wfq", fuse=True, fuse_threshold=1024,
                          fuse_wait_s=0.5)
    with engine_with(two_units(), cfg) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2), affine_kernel,
                                 [d], np.zeros(T, np.float32))
                   for d in datas]
        for h in handles:
            np.testing.assert_allclose(h.result(timeout=120),
                                       expected(datas[0]))
        assert engine.admission.fused_batches >= 1


def test_controller_wfq_charges_fused_entries_at_cost_scale():
    """Regression: fused batches schedule in member units; WFQ must debit
    work-items (size x wfq_cost_scale) or fused flows are nearly free."""
    ctl = AdmissionController(2, AdmissionConfig(policy="wfq", quantum=100))
    entry = _FakeEntry(build_scheduler("dyn4", 8, 2), "fusedflow")
    entry.wfq_cost_scale = 512           # one member = 512 work-items
    ctl.admit(entry)
    got = ctl.next_work(0)
    assert got is not None
    _, pkg = got
    tq = ctl._tenants["fusedflow"]
    # one quantum (100) granted, pkg.size * 512 debited — deeply negative
    assert tq.deficit == pytest.approx(100.0 - pkg.size * 512)


def test_controller_wfq_interleaves_backlogged_tenants():
    ctl = AdmissionController(2, AdmissionConfig(policy="wfq"))
    a = _FakeEntry(build_scheduler("dynamic", 6400, 2, num_packages=100), "a",
                   weight=1.0)
    b = _FakeEntry(build_scheduler("dynamic", 6400, 2, num_packages=100), "b",
                   weight=1.0)
    ctl.admit(a)
    ctl.admit(b)
    served = {"a": 0, "b": 0}
    for _ in range(40):
        entry, pkg = ctl.next_work(0)
        served[entry.tenant] += pkg.size
    assert served["a"] > 0 and served["b"] > 0
    assert 0.7 <= served["a"] / served["b"] <= 1.4
