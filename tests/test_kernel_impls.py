"""The kernel implementation-variant axis (pallas / xla / ref).

Pins the contract added with the Pallas fast path:

* every ``<name>_op`` wrapper agrees across ``pallas`` (interpret mode
  off-TPU), ``xla`` and ``ref`` on randomized shapes;
* the wrapper default is backend-aware (never interpret-mode Pallas by
  accident off-TPU);
* ``build_kernel(name, impl=...)`` round-trips through the registry —
  memoized per canonical impl, "auto" aliased to the backend default,
  and ``temporary_plugins`` overrides are not shadowed by the
  ``lru_cache``'d builtin factories;
* the gaussian + matmul Pallas ``CoexecKernel`` bodies run end-to-end
  on the real engine across all four policies and both data planes,
  pinned against the ``ref`` oracle (documented f32 tolerance — the
  Pallas matmul accumulates through a VMEM f32 scratch, so it is not
  bitwise against ``jnp.dot``), while USM vs BUFFERS stays bitwise
  within each impl.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from repro.api import (CoexecSpec, build_kernel, kernel_demo_inputs,
                       register_kernel, scheduler_names, temporary_plugins)
from repro.core import (ArgSpec, CoexecEngine, CoexecKernel, OutputSpec)
from repro.kernels import (KERNEL_IMPLS, default_impl, demo_spheres,
                           flash_attention_op, gaussian_op,
                           linear_attention_op, mandelbrot_op, matmul_op,
                           rap_op, raytrace_op, resolve_impl, taylor_op)
from repro.kernels import ops, ref

PAPER_KERNELS = ("gaussian", "mandelbrot", "matmul", "rap", "ray", "taylor")
N = 220          # engine tests: not a power of two (uneven packages)

rng = np.random.default_rng(7)


def base_spec(memory: str = "usm", policy: str = "hguided") -> CoexecSpec:
    return (CoexecSpec.builder()
            .policy(policy)
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .dist(0.4)
            .memory(memory)
            .build())


@pytest.fixture(scope="module")
def shared_units():
    """One unit set for the whole module (warm jit caches across tests)."""
    return base_spec().build_units()


def run_engine(memory, kernel, inputs, units, policy="hguided"):
    spec = base_spec(memory, policy)
    with CoexecEngine.from_spec(spec, units=units) as engine:
        sched = spec.build_scheduler(N, len(units))
        h = engine.submit(sched, kernel, inputs, kernel.alloc_out(N, inputs))
        out = h.result(timeout=120)
    return out.copy(), h.stats


# ---------------------------------------------------------------------------
# Wrapper parity: pallas (interpret) vs ref on randomized shapes
# ---------------------------------------------------------------------------

def _wrapper_cases():
    """(name, op, args, kwargs, rtol, atol) per wrapper, random shapes."""
    m, k, n = rng.integers(17, 90, size=3)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)

    h, w = rng.integers(20, 150, size=2)
    img = jnp.asarray(rng.normal(size=(h, w)), jnp.float32)

    x = jnp.asarray(rng.uniform(-3, 3, size=(int(rng.integers(100, 3000)),)),
                    jnp.float32)

    side = int(rng.integers(16, 40))
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = [jnp.asarray(g) for g in np.meshgrid(re_, im)]

    rn = int(rng.integers(200, 900))
    dx, dy = rng.uniform(-.4, .4, (2, rn)).astype(np.float32)
    dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, .5)).astype(np.float32)

    rap_n, rap_l = int(rng.integers(50, 300)), int(rng.integers(16, 70))
    vals = jnp.asarray(rng.normal(size=(rap_n, rap_l)), jnp.float32)
    lens = jnp.asarray(rng.integers(0, rap_l + 1, size=(rap_n,)), jnp.int32)

    B, Hq, Hkv, T, D = 1, 2, 1, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, T, D)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, T, D)), jnp.float32)

    BH, T2, Dk, Dv = 2, 96, 8, 12
    q2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)), jnp.float32)
    k2 = jnp.asarray(rng.normal(size=(BH, T2, Dk)) * .2, jnp.float32)
    v2 = jnp.asarray(rng.normal(size=(BH, T2, Dv)), jnp.float32)
    ld = jnp.asarray(-np.abs(rng.normal(size=(BH, T2)) * .1), jnp.float32)

    return {
        "matmul": (matmul_op, (a, b), dict(bm=64, bn=64, bk=64),
                   2e-5, 2e-5),
        "gaussian": (gaussian_op, (img,), dict(bm=32), 1e-5, 1e-5),
        "taylor": (taylor_op, (x,), dict(terms=12, bm=8), 1e-5, 1e-6),
        "mandelbrot": (mandelbrot_op, (cre, cim),
                       dict(max_iter=48, bm=8), 0.0, 0.0),
        "raytrace": (raytrace_op,
                     (jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                      demo_spheres(5)), dict(bm=8), 1e-3, 1e-4),
        "rap": (rap_op, (vals, lens), dict(bm=32), 1e-5, 1e-5),
        "flash_attention": (flash_attention_op, (q, kk, v),
                            dict(bq=32, bk=32), 2e-5, 2e-5),
        "linear_attention": (linear_attention_op, (q2, k2, v2, ld),
                             dict(chunk=32), 3e-4, 3e-4),
    }


@pytest.mark.parametrize("name", sorted(_wrapper_cases()))
def test_wrapper_pallas_matches_ref(name):
    op, args, kw, rtol, atol = _wrapper_cases()[name]
    got = op(*args, impl="pallas", **kw)
    # the ref oracles take no block-size arguments
    ref_kw = {k: v for k, v in kw.items() if k in ("terms", "max_iter")}
    want = op(*args, impl="ref", **ref_kw)
    assert got.dtype == want.dtype
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), rtol=rtol, atol=atol)


@pytest.mark.parametrize("name", sorted(_wrapper_cases()))
def test_wrapper_xla_matches_ref_bitwise(name):
    op, args, kw, _, _ = _wrapper_cases()[name]
    ref_kw = {k: v for k, v in kw.items()
              if k in ("terms", "max_iter")}
    got = op(*args, impl="xla", **ref_kw)
    want = op(*args, impl="ref", **ref_kw)
    # same jnp program, jitted vs eager: XLA may fuse differently, so
    # allow float round-off but nothing structural
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), rtol=1e-5, atol=1e-5)


def test_wrapper_default_is_backend_aware(monkeypatch):
    """The default impl never silently selects interpret-mode Pallas."""
    assert resolve_impl(None) == default_impl()
    assert resolve_impl("auto") == default_impl()
    monkeypatch.setattr(ops, "_on_tpu", lambda: False)
    assert default_impl() == "xla"
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    assert default_impl() == "pallas"
    with pytest.raises(ValueError, match="impl"):
        resolve_impl("opencl")


def test_wrapper_default_matches_explicit_default_impl():
    x = jnp.asarray(rng.uniform(-2, 2, 512), jnp.float32)
    np.testing.assert_array_equal(np.asarray(taylor_op(x)),
                                  np.asarray(taylor_op(x,
                                                       impl=default_impl())))


# ---------------------------------------------------------------------------
# Registry round-trips for the impl axis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", PAPER_KERNELS)
def test_build_kernel_impl_round_trips(name):
    auto = build_kernel(name)
    assert auto is build_kernel(name, impl="auto")
    assert auto is build_kernel(name, impl=default_impl())
    for impl in KERNEL_IMPLS:
        k = build_kernel(name, impl=impl)
        assert k is build_kernel(name, impl=impl)       # memoized
        assert k.name == auto.name                      # same protocol id
        # identical declared semantics (defaults are fresh closures)
        assert [(s.name, s.role, s.axis, s.halo) for s in k.args] \
            == [(s.name, s.role, s.axis, s.halo) for s in auto.args]
    assert build_kernel(name, impl="pallas") \
        is not build_kernel(name, impl="ref")


def test_build_kernel_rejects_unknown_impl():
    with pytest.raises(ValueError, match="impl"):
        build_kernel("taylor", impl="cuda")


def test_impl_request_against_variantless_kernel_is_loud():
    """A kernel with no 'impl' field rejects impl= instead of silently
    serving its only body."""
    def factory():
        return CoexecKernel("single", lambda off, x: x * 2.0,
                            (ArgSpec("x"),), OutputSpec())

    with temporary_plugins():
        register_kernel("single", factory)
        assert build_kernel("single")(0, np.ones(4, np.float32))[0] == 2.0
        with pytest.raises(ValueError, match="implementation variants"):
            build_kernel("single", impl="pallas")


def test_temporary_override_not_shadowed_by_factory_cache():
    """An overwrite inside temporary_plugins wins over the lru_cache'd
    builtin factory, and the builtin comes back intact afterwards."""
    builtin = build_kernel("taylor")

    def factory(**kw):
        return CoexecKernel("taylor", lambda off, x: x + 1.0,
                            (ArgSpec("x"),), OutputSpec())

    with temporary_plugins():
        register_kernel("taylor", factory, overwrite=True)
        custom = build_kernel("taylor")
        assert custom is not builtin
        x = np.zeros(8, np.float32)
        np.testing.assert_allclose(np.asarray(custom(0, x)), x + 1.0)
        with pytest.raises(ValueError, match="implementation variants"):
            build_kernel("taylor", impl="pallas")
    assert build_kernel("taylor") is builtin            # cache not stale
    assert build_kernel("taylor", impl="pallas") is not builtin


def test_workload_spec_kernel_impl_flows_to_registry():
    wl = (CoexecSpec.builder()
          .workload("taylor", kernel_impl="pallas").build().workload)
    assert wl.kernel_impl == "pallas"
    assert wl.build_kernel() is build_kernel("taylor", impl="pallas")
    # default stays the backend-aware auto
    assert CoexecSpec().workload.build_kernel() is build_kernel("taylor")
    with pytest.raises(ValueError, match="kernel_impl"):
        (CoexecSpec.builder()
         .workload("taylor", kernel_impl="opencl").build())


# ---------------------------------------------------------------------------
# Engine end-to-end: pallas CoexecKernels across policies and planes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("gaussian", "matmul"))
def test_pallas_engine_parity_all_policies_both_planes(name, shared_units):
    """The flagship halo (gaussian) and broadcast (matmul) kernels serve
    their Pallas bodies under every policy on both data planes, pinned
    against the ref oracle (f32 tolerance, see module docstring)."""
    pallas_k = build_kernel(name, impl="pallas")
    ref_k = build_kernel(name, impl="ref")
    inputs = kernel_demo_inputs(name, N, seed=9)
    want, _ = run_engine("usm", ref_k, inputs, shared_units, policy="dyn8")
    for policy in scheduler_names():
        for memory in ("usm", "buffers"):
            out, _ = run_engine(memory, pallas_k, inputs, shared_units,
                                policy=policy)
            assert_allclose(out, want, rtol=2e-5, atol=2e-5,
                            err_msg=f"{name}/{policy}/{memory}")


@pytest.mark.parametrize("name", PAPER_KERNELS)
def test_pallas_usm_buffers_bitwise_parity(name, shared_units):
    """Within the pallas impl, USM and BUFFERS stay bitwise identical
    (same executables, same padded chunks) — the data-plane guarantee
    holds for every registered kernel's Pallas variant too."""
    kernel = build_kernel(name, impl="pallas")
    inputs = kernel_demo_inputs(name, N, seed=7)
    usm_out, usm_stats = run_engine("usm", kernel, inputs, shared_units,
                                    policy="dyn16")
    buf_out, buf_stats = run_engine("buffers", kernel, inputs, shared_units,
                                    policy="dyn16")
    assert np.array_equal(usm_out, buf_out), (
        f"{name}[pallas]: USM and BUFFERS results differ")
    assert usm_stats.data.h2d_copies == 0
    assert buf_stats.data.d2h_copies == buf_stats.num_packages


def test_serve_rows_record_resolved_impl():
    """coexec_real_rows reports which variant actually served."""
    from repro.launch.serve import coexec_real_rows, default_serve_spec

    spec = default_serve_spec()
    spec = spec.replace(workload=spec.workload.replace(
        name="taylor", kernel_impl="pallas", items=256, requests=2,
        concurrent=2))
    rows = coexec_real_rows(spec, policies=("dyn4",))
    assert rows and all(r["impl"] == "pallas" for r in rows)
    assert all(r["kernel"] == "taylor" for r in rows)


def test_sim_backend_accepts_kernel_impl():
    """--kernel-impl flows through the sim path too (the DES costs are
    impl-agnostic; the flag must parse and run, not change the model)."""
    from repro.launch.serve import coexec_sim_rows, default_serve_spec

    spec = default_serve_spec()
    spec = spec.replace(workload=spec.workload.replace(
        name="mandelbrot", kernel_impl="pallas")).validate()
    rows = coexec_sim_rows(spec, policies=("static",))
    assert rows and rows[0]["workload"] == "mandelbrot"
