"""One control plane, two backends: structural engine↔DES parity and
preemptive pull-capping, both through `repro.core.exec.ExecutionLoop`.

The parity tests drive a `RealBackend` (real JAX dispatch through the
data plane) and a `SimBackend` (virtual clock) with an identical
deterministic round-robin serve order, so every control-plane decision —
admission pulls, WFQ credit, fusion staging/de-mux, finalization,
counter attribution — is exercised through the one shared loop and must
come out identical: per-unit package sequences and counter totals for
all four policies × {fifo,wfq} × {fuse on/off}.

The preemption tests pin the new `AdmissionSpec.preempt` semantics once
for both substrates: WFQ reclaims credit mid-launch by capping per-pull
package sizes of over-served tenants, which measurably tightens the
time-sampled Jain fairness curve at 32 tenants.
"""
import pathlib

import numpy as np
import pytest

import jax

from repro.api import CoexecSpec, build_scheduler
from repro.core import (AdmissionConfig, CoexecEngine, ExecutionLoop,
                        LaunchSpec, MemoryModel, SimUnit, Workload,
                        counits_from_devices, jain_index,
                        service_fairness_curve, simulate_multi)
from repro.core.dataplane import as_coexec_kernel, make_plane
from repro.core.engine import RealBackend, _Launch, _fuse_key
from repro.core.memory import MemoryCosts
from repro.core.sim import SimBackend, _SimLaunchState

NUNITS = 2
SPEEDS = [0.5, 0.5]
POLICIES = ["static", "dyn8", "hguided", "work_stealing"]


def real_units():
    return counits_from_devices(jax.local_devices()[:1] * NUNITS,
                                kinds=["cpu"] * NUNITS, speed_hints=SPEEDS)


def sim_units():
    return [SimUnit(f"u{i}", "cpu", speed=1000.0, setup_s=1e-3)
            for i in range(NUNITS)]


def sched_for(policy, total):
    kw = {"speeds": SPEEDS} if policy in ("static", "hguided",
                                          "work_stealing") else {}
    return build_scheduler(policy, total, NUNITS, **kw)


def double_kernel(offset, chunk):
    return chunk * 2.0


def drive(loop):
    """Serve one package per unit per sweep, round-robin, until drained.

    The same deterministic serve order is applied to both backends, so
    any divergence in what the units are handed is a control-plane
    divergence — exactly what the parity tests are after.
    """
    backend = loop.backend
    for _ in range(100_000):
        if loop.drained():
            return
        progressed = False
        for u in range(NUNITS):
            work = loop.pull(u, force_flush=True)
            if work is None:
                continue
            launch, pkg = work
            backend.dispatch(u, launch, pkg)
            loop.complete(launch, pkg)
            progressed = True
        if not progressed and not loop.drained():
            raise AssertionError("drive wedged with work outstanding")
    raise AssertionError("drive did not converge")


def run_real(policy, cfg, memory, datas, total):
    units = real_units()
    backend = RealBackend(units, make_plane(memory))
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)
    launches = []
    for i, d in enumerate(datas):
        kernel = as_coexec_kernel(double_kernel, 1)
        s = sched_for(policy, total)
        out = np.zeros(total, np.float32)
        launch = _Launch(loop.next_id(), s, kernel, [d], out,
                         adaptive=False)
        launch.plan = backend.plane.plan(kernel, [d], out, total)
        launch.tenant = f"t{i}"
        launch.fuse_key = _fuse_key(cfg, s, kernel, [d], out)
        launches.append(launch)
    for launch in launches:
        loop.admit(launch, now=0.0)
    drive(loop)
    return launches, loop


def run_sim(policy, cfg, memory, n_launches, total):
    units = sim_units()
    backend = SimBackend(units, memory, MemoryCosts())
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)
    entries = []
    for i in range(n_launches):
        entry = _SimLaunchState(
            loop.next_id(), sched_for(policy, total),
            Workload("par", total, 4.0, 4.0, 1e4), tenant=f"t{i}")
        if cfg.fuse and total <= cfg.fuse_threshold:
            entry.fuse_key = ("par", total, 4.0, 4.0)
        entries.append(entry)
    for entry in entries:
        loop.admit(entry, now=0.0)
    drive(loop)
    return entries, loop


def signature(launch):
    """Order-independent per-unit package placement of one launch."""
    return sorted((p.seq, p.unit, p.offset, p.size)
                  for p in launch.stats.packages)


def counter_totals(launches):
    agg = [0, 0, 0]
    for launch in launches:
        agg[0] += launch.stats.data.dispatches
        agg[1] += launch.stats.data.h2d_copies
        agg[2] += launch.stats.data.d2h_copies
    return agg


# ---------------------------------------------------------------------------
# Engine ↔ DES parity through the one shared loop (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("admission", ["fifo", "wfq"])
@pytest.mark.parametrize("fuse", [False, True])
def test_engine_vs_des_parity_all_policies(policy, admission, fuse):
    """Seeded workload, identical serve order ⇒ identical per-unit
    package sequences and identical counter totals on both backends,
    for every policy × admission × fusion combination."""
    total, n_launches = 512, 6
    cfg = AdmissionConfig(policy=admission, fuse=fuse, fuse_threshold=1024)
    memory = MemoryModel.BUFFERS      # exercises H2D/D2H counters too
    datas = [np.random.default_rng(i).normal(size=total).astype(np.float32)
             for i in range(n_launches)]

    real, real_loop = run_real(policy, cfg, memory, datas, total)
    sim, sim_loop = run_sim(policy, cfg, memory, n_launches, total)

    # every launch computed correctly on the real backend
    for launch, d in zip(real, datas):
        np.testing.assert_allclose(launch.handle.result(timeout=1), d * 2.0)

    # identical per-unit package sequences, launch by launch
    for launch_r, launch_s in zip(real, sim):
        assert signature(launch_r) == signature(launch_s), (
            policy, admission, fuse)

    # identical counter totals (dispatches, H2D, D2H) across the run
    assert counter_totals(real) == counter_totals(sim)
    assert real_loop.admission.dispatched == sim_loop.admission.dispatched
    assert real_loop.admission.fused_batches == \
        sim_loop.admission.fused_batches
    assert real_loop.admission.fused_members == \
        sim_loop.admission.fused_members
    if fuse:
        assert real_loop.admission.fused_members == n_launches
        assert all(launch.fused for launch in real)


def test_sim_module_has_no_control_loop_of_its_own():
    """Acceptance: core/sim.py is grep-clean for the deleted duplicate
    control plane and both backends drive repro.core.exec.ExecutionLoop."""
    src = (pathlib.Path(__file__).resolve().parent.parent /
           "src/repro/core/sim.py").read_text()
    assert "_fuse_sim_launches" not in src
    assert "ExecutionLoop" in src
    engine_src = (pathlib.Path(__file__).resolve().parent.parent /
                  "src/repro/core/engine.py").read_text()
    assert "ExecutionLoop" in engine_src
    # the engine exposes the shared loop object directly
    engine = CoexecEngine(real_units())
    assert isinstance(engine.loop, ExecutionLoop)


# ---------------------------------------------------------------------------
# Fused-batch counter attribution (satellite): exact remainder sums
# ---------------------------------------------------------------------------

def test_fused_counter_attribution_sums_exactly_sim():
    """Member LaunchStats.data must sum back to the fused batch's totals
    exactly — even when counters % members != 0 (here 2 packages over 6
    members), where even integer shares would drop the remainder."""
    cfg = AdmissionConfig(fuse=True, fuse_threshold=1024, fuse_wait_s=0.0)
    specs = [LaunchSpec(Workload("tiny", 256, 8.0, 8.0, 1e4),
                        build_scheduler("dyn8", 256, 2), tenant=f"t{i}")
             for i in range(6)]
    res = simulate_multi(specs, sim_units(), admission=cfg,
                         memory=MemoryModel.BUFFERS)
    assert res.fused_batches == 1 and res.fused_members == 6
    # the batch really produced a non-divisible share
    assert res.data.dispatches % 6 != 0
    for field in ("dispatches", "h2d_copies", "h2d_bytes",
                  "d2h_copies", "d2h_bytes"):
        member_sum = sum(getattr(r.data, field) for r in res.launches)
        assert member_sum == getattr(res.data, field), field


def test_fused_counter_attribution_sums_exactly_engine():
    """Same exact-sum property on the threaded engine (live threads,
    BUFFERS data plane): summing member stats recovers every dispatch
    and staging copy the batch actually paid."""
    T = 256
    spec = CoexecSpec(
        admission=CoexecSpec().admission.replace(
            fuse=True, fuse_threshold=1024, fuse_wait_s=0.5),
        memory=CoexecSpec().memory.replace(model="buffers"))
    datas = [np.arange(T, dtype=np.float32) + i for i in range(6)]
    with CoexecEngine(real_units(), spec=spec) as engine:
        handles = [engine.submit(build_scheduler("dyn8", T, 2),
                                 double_kernel, [d],
                                 np.zeros(T, np.float32))
                   for d in datas]
        for h, d in zip(handles, datas):
            np.testing.assert_allclose(h.result(timeout=120), d * 2.0)
        assert engine.admission.fused_batches == 1
        assert engine.admission.fused_members == 6
        dispatched = engine.admission.dispatched
    member_dispatches = sum(h.stats.data.dispatches for h in handles)
    member_h2d = sum(h.stats.data.h2d_copies for h in handles)
    member_d2h = sum(h.stats.data.d2h_copies for h in handles)
    assert member_dispatches == dispatched
    # one input argument: the BUFFERS plane pays one H2D and one D2H per
    # dispatched package — the member shares must sum to exactly that
    assert member_h2d == dispatched and member_d2h == dispatched
    assert dispatched % 6 != 0      # the remainder case is actually hit


# ---------------------------------------------------------------------------
# Preemptive pull-capping (tentpole proof): one implementation, two backends
# ---------------------------------------------------------------------------

def _multi_curve(preempt, *, tenants=32, total=2048, policy="hguided"):
    specs = [LaunchSpec(Workload("uni", total, 8.0, 8.0, 1e4),
                        sched_for(policy, total), tenant=f"t{i}")
             for i in range(tenants)]
    cfg = AdmissionConfig(policy="wfq", preempt=preempt)
    res = simulate_multi(specs, sim_units(), admission=cfg)
    return res, res.fairness_curve()


def test_preempt_tightens_fairness_curve_at_32_tenants_sim():
    """Acceptance: --preempt produces a measurably tighter Jain fairness
    curve at 32 tenants on the DES backend."""
    base_res, base = _multi_curve(False)
    pre_res, pre = _multi_curve(True)
    # every launch still completes its whole index space
    assert len(base_res.launches) == len(pre_res.launches) == 32
    assert float(np.mean(pre)) > float(np.mean(base)) + 0.03
    assert min(pre) > min(base) + 0.2
    # capping shows up as strictly smaller maximum pulls
    assert max(i for _, _, i in pre_res.service) < \
        max(i for _, _, i in base_res.service)


def test_preempt_tightens_fairness_curve_at_32_tenants_real():
    """Acceptance: the same preemption implementation (zero backend-
    specific code) tightens the fairness curve on the real backend —
    measured over real dispatches through the data plane, with the
    dispatch sequence as the (deterministic) service clock."""
    total, tenants = 1024, 32

    def curve(preempt):
        cfg = AdmissionConfig(policy="wfq", preempt=preempt)
        datas = [np.zeros(total, np.float32) for _ in range(tenants)]
        launches, _ = run_real("hguided", cfg, MemoryModel.USM, datas,
                               total)
        service = []
        for launch in launches:
            for p in launch.stats.packages:
                service.append((p.t_complete, launch.tenant, p.size))
        # deterministic duration-weighted clock: order dispatches by
        # (wall) completion and advance time by the items each computed —
        # the service curve a unit-speed device would produce, free of
        # wall-clock jitter
        clock, ticked = 0, []
        for _, tenant, items in sorted(service):
            clock += items
            ticked.append((clock, tenant, items))
        return service_fairness_curve(
            ticked, [f"t{i}" for i in range(tenants)])

    base = curve(False)
    pre = curve(True)
    assert float(np.mean(pre)) > float(np.mean(base)) + 0.03
    assert min(pre) > min(base) + 0.2


def test_preempt_caps_pull_sizes_at_credit():
    """The mechanism itself: with a small explicit quantum, an
    over-served tenant's pulls are capped near its per-round credit
    instead of emitting the scheduler's natural (huge) package."""
    base_res, _ = _multi_curve(False)
    specs = [LaunchSpec(Workload("uni", 2048, 8.0, 8.0, 1e4),
                        sched_for("hguided", 2048), tenant=f"t{i}")
             for i in range(8)]
    res = simulate_multi(
        specs, sim_units(),
        admission=AdmissionConfig(policy="wfq", quantum=64, preempt=True))
    assert max(items for _, _, items in res.service) <= 64
    # and without preempt the same quantum still emits giant packages
    specs = [LaunchSpec(Workload("uni", 2048, 8.0, 8.0, 1e4),
                        sched_for("hguided", 2048), tenant=f"t{i}")
             for i in range(8)]
    res2 = simulate_multi(
        specs, sim_units(),
        admission=AdmissionConfig(policy="wfq", quantum=64))
    assert max(items for _, _, items in res2.service) > 64


def test_preempt_on_threaded_engine_stays_exact():
    """Live worker threads + preemptive WFQ: results stay bitwise exact
    and every launch's (possibly capped) packages still tile its space."""
    from repro.core import validate_cover

    T = 4096
    spec = (CoexecSpec.builder()
            .admission("wfq", preempt=True, quantum=128).build())
    datas = [np.random.default_rng(i).normal(size=T).astype(np.float32)
             for i in range(8)]
    with CoexecEngine(real_units(), spec=spec) as engine:
        handles = [engine.submit(sched_for("hguided", T), double_kernel,
                                 [d], np.zeros(T, np.float32),
                                 tenant=f"t{i}", adaptive=False)
                   for i, d in enumerate(datas)]
        for h, d in zip(handles, datas):
            np.testing.assert_allclose(h.result(timeout=120), d * 2.0)
            validate_cover(h.stats.packages, T)


def test_preempt_is_inert_under_fifo():
    """preempt only reclaims WFQ credit; FIFO runs are byte-identical."""
    def run(preempt):
        specs = [LaunchSpec(Workload("uni", 1024, 8.0, 8.0, 1e4),
                            sched_for("dyn8", 1024), tenant=f"t{i}")
                 for i in range(4)]
        return simulate_multi(specs, sim_units(),
                              admission=AdmissionConfig(policy="fifo",
                                                        preempt=preempt))
    a, b = run(False), run(True)
    assert a.dispatched_packages == b.dispatched_packages
    assert a.latencies() == b.latencies()


def test_preempt_spec_round_trip_and_cli_flag():
    """AdmissionSpec.preempt rides the derived-flag machinery: both CLIs
    grow --preempt with no per-tool edits, and the spec round-trips."""
    import argparse

    from repro.api import add_spec_args, args_from_spec, spec_from_args

    spec = CoexecSpec.builder().admission("wfq", preempt=True).build()
    assert CoexecSpec.from_json(spec.to_json()) == spec
    assert spec.admission_config().preempt is True

    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    ns = ap.parse_args(["--admission", "wfq", "--preempt"])
    parsed = spec_from_args(ns)
    assert parsed.admission.preempt is True
    assert "--preempt" in args_from_spec(spec)


def test_fairness_curve_helper_validates():
    with pytest.raises(ValueError):
        service_fairness_curve([], [])
    assert service_fairness_curve([], ["a"]) == [1.0] * 9
    flat = service_fairness_curve(
        [(t, f"t{t % 2}", 1) for t in range(100)], ["t0", "t1"])
    assert all(f > 0.9 for f in flat)
    assert jain_index([1.0, 1.0]) == pytest.approx(1.0)
