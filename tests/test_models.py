"""Per-architecture smoke tests (reduced configs) + decode equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.models import build_model, count_params
from repro.optim import AdamW

B, T = 2, 32
rng = jax.random.PRNGKey(0)


def make_batch(cfg, tokens=None):
    if tokens is None:
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                    cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.encoder_seq, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    assert count_params(params) > 0
    batch = make_batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape[:2] == (B, T)
    assert logits.shape[2] >= cfg.vocab_size   # possibly padded vocab
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, state, batch):
        (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    params2, state2, loss = step(params, state, batch)
    assert jnp.isfinite(loss)
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree_util.tree_leaves(moved))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "h2o-danube3-4b",
                                  "minicpm-2b", "internvl2-1b",
                                  "whisper-medium", "xlstm-1.3b",
                                  "zamba2-7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(rng)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0,
                                cfg.vocab_size)
    batch = make_batch(cfg, tokens)
    # VLM: the decode path is text-only (the vision prefix enters via a
    # prefill pass in real serving); compare text-only forward vs decode
    batch.pop("vision_embeds", None)
    logits_full, _ = jax.jit(model.forward)(params, batch)

    cache = model.init_cache(B, T)
    if model.prefill is not None:
        cache = jax.jit(model.prefill)(params, batch, cache)
    step = jax.jit(model.decode_step)
    worst = 0.0
    for t in range(T):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        err = float(jnp.max(jnp.abs(
            lg[:, :cfg.vocab_size] -
            logits_full[:, t, :cfg.vocab_size])))
        worst = max(worst, err)
    assert worst < 0.12, worst


def test_moe_decode_matches_with_capacity():
    cfg = dataclasses.replace(
        get_config("phi3.5-moe-42b-a6.6b").reduced(), capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(rng)
    tokens = jax.random.randint(jax.random.PRNGKey(9), (B, T), 0,
                                cfg.vocab_size)
    logits_full, _ = jax.jit(model.forward)(
        params, {"tokens": tokens, "labels": tokens})
    cache = model.init_cache(B, T)
    step = jax.jit(model.decode_step)
    for t in range(T):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        assert float(jnp.max(jnp.abs(lg - logits_full[:, t]))) < 1e-4


def test_swa_ring_buffer_wraps():
    """h2o's sliding window: decode beyond the window stays correct."""
    cfg = get_config("h2o-danube3-4b").reduced()   # window 32
    assert cfg.window == 32
    model = build_model(cfg)
    params = model.init(rng)
    T2 = 48                                        # > window
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, T2), 0,
                                cfg.vocab_size)
    logits_full, _ = jax.jit(model.forward)(
        params, {"tokens": tokens, "labels": tokens})
    cache = model.init_cache(B, T2)                # ring of size window
    assert cache["k"].shape[3] == cfg.window
    step = jax.jit(model.decode_step)
    worst = 0.0
    for t in range(T2):
        lg, cache = step(params, tokens[:, t:t + 1], cache)
        worst = max(worst, float(jnp.max(jnp.abs(
            lg[:, :cfg.vocab_size] -
            logits_full[:, t, :cfg.vocab_size]))))
    assert worst < 0.12, worst


def test_long_shape_applicability_flags():
    sub = {a for a in ARCH_IDS if get_config(a).subquadratic}
    assert sub == {"h2o-danube3-4b", "xlstm-1.3b", "zamba2-7b"}
    assert SHAPES["long_500k"].kind == "decode"


def test_mixer_impl_consistency():
    for arch in ("zamba2-7b", "xlstm-1.3b"):
        cfg = get_config(arch).reduced()
        m_ref = build_model(dataclasses.replace(cfg, mixer_impl="ref"))
        m_chk = build_model(dataclasses.replace(cfg, mixer_impl="chunked"))
        params = m_ref.init(rng)
        batch = make_batch(cfg)
        l1, _ = jax.jit(m_ref.forward)(params, batch)
        l2, _ = jax.jit(m_chk.forward)(params, batch)
        assert float(jnp.max(jnp.abs(l1 - l2))) < 0.05, arch
