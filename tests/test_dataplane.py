"""The CoexecKernel protocol + USM/BUFFERS data plane (acceptance tests).

* every registered kernel runs on the real engine under both memory
  models with **bitwise-identical** results;
* USM performs **zero** staging copies (the counters prove it) while
  BUFFERS pays per-package H2D/D2H — strictly more;
* per-argument semantics do what they declare (broadcast operands are
  not sliced, halos reproduce the monolithic stencil exactly, outputs
  allocate from the declared slot);
* the kernel registry behaves like the scheduler/workload registries:
  introspection, strict option validation, third-party registration
  (the retired ``package_kernel`` shim is gone — see tests/test_api.py).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (CoexecSpec, build_kernel, kernel_demo_inputs,
                       kernel_names, kernel_plugin, register_kernel,
                       registry_listing, temporary_plugins)
from repro.core import (ArgRole, ArgSpec, CoexecEngine, CoexecKernel,
                        CoexecutorRuntime, OutputSpec)
from repro.kernels import ref

PAPER_KERNELS = ("gaussian", "mandelbrot", "matmul", "rap", "ray", "taylor")
N = 700          # deliberately not a power of two (uneven package sizes)


def base_spec(memory: str = "usm", policy: str = "hguided") -> CoexecSpec:
    return (CoexecSpec.builder()
            .policy(policy)
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .dist(0.4)
            .memory(memory)
            .build())


@pytest.fixture(scope="module")
def shared_units():
    """One unit set for the whole module (warm jit caches across tests)."""
    return base_spec().build_units()


def run_engine(memory, kernel, inputs, units, policy="hguided"):
    spec = base_spec(memory, policy)
    with CoexecEngine.from_spec(spec, units=units) as engine:
        sched = spec.build_scheduler(N, len(units))
        h = engine.submit(sched, kernel, inputs, kernel.alloc_out(N, inputs))
        out = h.result(timeout=120)
    return out.copy(), h.stats


# ---------------------------------------------------------------------------
# Acceptance: bitwise USM-vs-BUFFERS parity + counter assertions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", PAPER_KERNELS)
def test_usm_buffers_bitwise_parity_every_kernel(name, shared_units):
    # dyn16's package structure is deterministic and identical across the
    # two runs, so the same executables see the same values — any output
    # difference would be the data plane's fault (the thing under test).
    # (hguided splits are request-order-dependent, and XLA codegen may
    # contract FMAs differently per chunk shape.)
    kernel = build_kernel(name)
    inputs = kernel_demo_inputs(name, N, seed=7)
    usm_out, usm_stats = run_engine("usm", kernel, inputs, shared_units,
                                    policy="dyn16")
    buf_out, buf_stats = run_engine("buffers", kernel, inputs, shared_units,
                                    policy="dyn16")

    assert np.array_equal(usm_out, buf_out), (
        f"{name}: USM and BUFFERS results differ")
    # USM: zero staging copies, by construction
    assert usm_stats.data.h2d_copies == 0
    assert usm_stats.data.d2h_copies == 0
    assert usm_stats.data.h2d_bytes == 0 and usm_stats.data.d2h_bytes == 0
    # BUFFERS: one D2H per package, one H2D per (package, argument)
    assert buf_stats.data.d2h_copies == buf_stats.num_packages
    assert buf_stats.data.h2d_copies == \
        buf_stats.num_packages * len(kernel.args)
    # strictly fewer staging copies under USM (the paper's USM advantage)
    assert usm_stats.data.staging_copies < buf_stats.data.staging_copies
    # dispatch counts agree with the package log on both planes
    assert usm_stats.data.dispatches == usm_stats.num_packages
    assert buf_stats.data.dispatches == buf_stats.num_packages


def test_memory_spec_reaches_engine_plane(shared_units):
    """MemorySpec selects the engine's actual data plane, not a label."""
    from repro.core.dataplane import BuffersDataPlane, UsmDataPlane

    usm = CoexecEngine.from_spec(base_spec("usm"), units=shared_units)
    buf = CoexecEngine.from_spec(base_spec("buffers"), units=shared_units)
    assert isinstance(usm.plane, UsmDataPlane)
    assert isinstance(buf.plane, BuffersDataPlane)


# ---------------------------------------------------------------------------
# Per-argument semantics
# ---------------------------------------------------------------------------

def test_broadcast_operand_is_not_sliced(shared_units):
    """MatMul's B reaches the kernel whole — the declaration at work."""
    kernel = build_kernel("matmul")
    a, b = kernel_demo_inputs("matmul", N, seed=3)
    out, stats = run_engine("usm", kernel, [a, b], shared_units)
    np.testing.assert_allclose(out, a @ b, rtol=1e-5, atol=1e-5)
    assert out.shape == (N, b.shape[1])     # trailing from the declaration


def test_gaussian_halo_matches_monolithic_reference(shared_units):
    """Split-with-halo reproduces the whole-image stencil bit for bit
    (zero fill beyond the image edges, like the reference's padding)."""
    kernel = build_kernel("gaussian")
    (img,) = kernel_demo_inputs("gaussian", N, seed=11)
    out, _ = run_engine("usm", kernel, [img], shared_units, policy="dyn8")
    want = np.asarray(ref.gaussian_blur(jnp.asarray(img)))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-7)


def test_ray_broadcast_default_fills_missing_scene(shared_units):
    """Ray's sphere scene is a trailing BROADCAST default: both arities
    work and agree."""
    from repro.kernels import demo_spheres

    kernel = build_kernel("ray")
    dx, dy, dz = kernel_demo_inputs("ray", N, seed=5)
    out3, _ = run_engine("usm", kernel, [dx, dy, dz], shared_units,
                         policy="dyn8")
    out4, _ = run_engine("usm", kernel,
                         [dx, dy, dz, np.asarray(demo_spheres())],
                         shared_units, policy="dyn8")
    np.testing.assert_array_equal(out3, out4)


def test_runtime_allocates_output_from_declaration():
    """launch(out=None) with a typed kernel uses its declared out slot."""
    kernel = build_kernel("rap")
    vals, lens = kernel_demo_inputs("rap", 256, seed=1)
    with CoexecutorRuntime.from_spec(base_spec()) as rt:
        out = rt.launch(256, kernel, [vals, lens])
    assert out.shape == (256,) and out.dtype == np.float32
    want = np.asarray(ref.rap(jnp.asarray(vals), jnp.asarray(lens)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


def test_split_extent_mismatch_raises(shared_units):
    kernel = build_kernel("taylor")
    spec = base_spec()
    with CoexecEngine.from_spec(spec, units=shared_units) as engine:
        with pytest.raises(ValueError, match="index space"):
            engine.submit(spec.build_scheduler(N, 2), kernel,
                          [np.zeros(N + 1, np.float32)],
                          np.zeros(N, np.float32))


def test_argspec_validation():
    with pytest.raises(ValueError, match="halo"):
        ArgSpec("x", role=ArgRole.BROADCAST, halo=2)
    with pytest.raises(ValueError, match="BROADCAST"):
        ArgSpec("x", default=lambda: np.zeros(3))
    kernel = CoexecKernel("k", lambda off, x: x, (ArgSpec("x"),),
                          OutputSpec())
    with pytest.raises(ValueError, match="takes 1 args"):
        kernel.bind([np.zeros(3), np.zeros(3)])


# ---------------------------------------------------------------------------
# Registry: introspection, validation, plugins, shim
# ---------------------------------------------------------------------------

def test_builtin_kernels_registered():
    assert set(kernel_names()) >= set(PAPER_KERNELS)


def test_kernel_factories_are_memoized():
    """Same options ⇒ same object: jit caches and fusion keys stay warm."""
    assert build_kernel("taylor") is build_kernel("taylor")
    assert build_kernel("taylor", terms=8) is build_kernel("taylor", terms=8)
    assert build_kernel("taylor") is not build_kernel("taylor", terms=8)


def test_unknown_kernel_and_options_rejected():
    with pytest.raises(KeyError):
        build_kernel("nope")
    with pytest.raises(ValueError, match="trems"):
        build_kernel("taylor", trems=8)     # misspelled, named in the error
    with pytest.raises(KeyError):
        CoexecSpec.builder().workload("taylor", kernel="nope").build()


def test_registry_listing_covers_all_three_registries():
    listing = registry_listing()
    assert "schedulers:" in listing
    assert "workloads:" in listing
    assert "kernels:" in listing
    assert "img[split+halo2]" in listing            # gaussian's declaration
    assert "b[broadcast]" in listing                # matmul's declaration
    assert "spheres[broadcast=default]" in listing  # ray's default scene


def test_third_party_kernel_plugin_end_to_end(shared_units):
    """A kernel registered without core edits runs on the engine."""
    def factory(scale=2.0):
        def fn(offset, x, _s=float(scale)):
            return x * _s

        return CoexecKernel("doubler", fn, (ArgSpec("x"),), OutputSpec())

    with temporary_plugins():
        register_kernel("doubler", factory, fields=("scale",),
                        demo_inputs=lambda n, rng:
                        [rng.normal(size=n).astype(np.float32)])
        assert "doubler" in kernel_names()
        kernel = build_kernel("doubler", scale=3.0)
        (x,) = kernel_demo_inputs("doubler", N, seed=2)
        out, stats = run_engine("usm", kernel, [x], shared_units)
        np.testing.assert_allclose(out, x * 3.0)
        assert stats.data.staging_copies == 0
        with pytest.raises(ValueError, match="already registered"):
            register_kernel("doubler", factory)
    assert "doubler" not in kernel_names()          # scope restored


def test_workload_spec_resolves_kernel():
    assert CoexecSpec().workload.resolve_kernel() == "taylor"
    wl = CoexecSpec.builder().workload("mandelbrot").build().workload
    assert wl.resolve_kernel() == "mandelbrot"
    wl = CoexecSpec.builder().workload("mandelbrot",
                                       kernel="rap").build().workload
    assert wl.resolve_kernel() == "rap"
    assert wl.build_kernel() is build_kernel("rap")


def test_registry_kernel_is_callable_with_package_signature():
    kernel = build_kernel("taylor")
    assert kernel is build_kernel("taylor")      # factories memoize
    # callable with the package signature ``fn(offset, *chunks)``
    x = np.linspace(-1, 1, 64, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(kernel(0, x)), np.sin(x),
                               rtol=1e-3, atol=1e-4)
    with pytest.raises(KeyError):
        build_kernel("nope")


def test_registry_listing_survives_option_requiring_factory():
    """--list must not crash on a factory with a required option."""
    def needs_options(scale):            # no default: factory() raises
        return CoexecKernel("scaled", lambda off, x, _s=scale: x * _s,
                            (ArgSpec("x"),), OutputSpec())

    with temporary_plugins():
        register_kernel("scaled", needs_options, fields=("scale",))
        listing = registry_listing()
    assert "scaled" in listing
    assert "factory needs options" in listing


def test_usm_dispatch_places_on_the_units_device():
    """Uncommitted USM views still execute on the unit's device (the
    engine's co-execution claim would silently serialize otherwise)."""
    import jax

    from repro.core import counits_from_devices

    (unit,) = counits_from_devices(jax.local_devices()[:1])
    out = unit.dispatch(lambda off, x: x * 2.0, 0,
                        [np.ones(8, np.float32)])
    assert list(out.devices()) == [unit.device]


def test_fused_member_counters_sum_to_batch_totals():
    """Summing fused members' stats must not overcount the batch."""
    from repro.core import CoexecEngine, DataPlaneCounters
    from repro.api import build_scheduler

    c = DataPlaneCounters(dispatches=2, h2d_copies=7, d2h_copies=2)
    shares = c.split(3)
    assert sum(s.dispatches for s in shares) == 2
    assert sum(s.h2d_copies for s in shares) == 7
    assert sum(s.d2h_copies for s in shares) == 2

    spec = base_spec("buffers")
    units = spec.build_units()
    k = 8
    data = [np.full(256, i, np.float32) for i in range(k)]

    def kernel(offset, chunk):           # one object: launches can fuse
        return chunk * 2.0

    with CoexecEngine(units, spec=spec.replace(
            admission=spec.admission.replace(
                fuse=True, fuse_threshold=1024,
                fuse_wait_s=0.5))) as engine:
        handles = [engine.submit(build_scheduler("dyn4", 256, 2),
                                 kernel, [data[i]],
                                 np.zeros(256, np.float32))
                   for i in range(k)]
        outs = [h.result(timeout=120) for h in handles]
    for i, out in enumerate(outs):
        np.testing.assert_allclose(out, data[i] * 2.0)
    fused = [h for h in handles if h.stats.num_packages == 1]
    assert len(fused) >= 2                       # fusion actually happened
    total_dispatch = sum(h.stats.data.dispatches for h in handles)
    # the batch dispatched at most one package per unit plus any unfused
    # stragglers — far fewer than k launches' worth; summing member
    # shares recovers the true total instead of k x batch
    assert total_dispatch <= 2 * len(units) + (k - len(fused)) * 4


# ---------------------------------------------------------------------------
# DES counter surface matches the real one
# ---------------------------------------------------------------------------

def test_sim_counters_mirror_memory_model():
    from repro.core import SimUnit, Workload, simulate

    wl = Workload(name="reg", total=2048, bytes_in_per_item=4.0,
                  bytes_out_per_item=4.0, working_set_bytes=8.0 * 2048)
    units = [SimUnit("cpu", "cpu", speed=1e5),
             SimUnit("gpu", "gpu", speed=2e5)]
    for mem, copies in (("usm", 0), ("buffers", 1)):
        spec = CoexecSpec.builder().policy("dyn8").memory(mem).build()
        r = simulate(None, units, wl, spec=spec)
        assert r.data.dispatches == r.num_packages
        assert r.data.h2d_copies == copies * r.num_packages
        assert r.data.d2h_copies == copies * r.num_packages
        if mem == "buffers":
            assert r.data.h2d_bytes > 0 and r.data.d2h_bytes > 0
