"""Elastic cluster tier: resizable pool, failure recovery, exact re-issue.

The tentpole claim this file pins: pool membership is a *runtime*
property of the shared control plane, and recovery from a unit death is
**exact-once** — a killed unit's in-flight packages re-issue to the
survivors as bitwise-identical ranges, per-launch covers and data-plane
counters equal an undisturbed run's, and no launch is ever lost or
duplicated. On top of that:

* real-vs-sim lockstep structural parity across
  {kill, leave, join} x {wfq, edf} x {preempt} — decision logs, package
  covers and re-issue counts agree between the threaded engine backend
  and the DES;
* a ``LaunchHandle`` never spuriously raises ``LaunchWaitTimeout``
  because a unit died mid-launch (the regression the ownership ledger
  exists to prevent);
* ``FailurePlan`` is a lossless JSON artifact (``save``/``load`` mirror
  ``Trace``'s) and keeps the training loop's step-keyed ``events``;
* the supervisor's heartbeat detector, straggler flagging and
  share bookkeeping (the absorbed ``hetero/rebalance.py`` moves);
* the autoscaler's hysteresis/sustain/cooldown state machine;
* a deterministic 1000-unit DES pool surviving scripted failure waves.
"""
import numpy as np
import pytest

import jax

from repro.api import ClusterSpec, CoexecSpec
from repro.core import (AdmissionConfig, Autoscaler, ClusterSimBackend,
                        CoexecEngine, DynamicScheduler, ExecutionLoop,
                        FailurePlan, MemoryCosts, MemoryModel, Range,
                        SimUnit, Supervisor, UnitPool, Workload,
                        absorb_share, as_coexec_kernel,
                        counits_from_devices, grant_share,
                        replay_cluster_lockstep, replay_trace_cluster,
                        synthesize_trace)
from repro.core.cluster import _resolve_unit
from repro.core.engine import RealBackend, _Launch
from repro.core.dataplane import make_plane
from repro.core.sim import _SimLaunchState

from _propcheck import given, settings, st

NUNITS = 3      # cluster lockstep pool: a kill must leave >= 2 survivors


def double_kernel(offset, chunk):
    return chunk * 2.0


KERNEL = as_coexec_kernel(double_kernel, 1)


def sim_units(n=NUNITS, speed=50_000.0):
    return [SimUnit(f"u{i}", "cpu", speed=speed, setup_s=1e-3)
            for i in range(n)]


def cluster_cfg(policy="wfq", preempt=False):
    return AdmissionConfig(policy=policy, preempt=preempt, slo_ms=50.0)


def cluster_trace(arrivals=24, items=96, seed=3):
    return synthesize_trace(arrivals, 40.0, tenants=4, items=items,
                            item_jitter=0.8, slo_ms=50.0, seed=seed)


# ---------------------------------------------------------------------------
# Share bookkeeping (the absorbed hetero/rebalance.py moves)
# ---------------------------------------------------------------------------

def test_grant_and_absorb_share_renormalize():
    s = grant_share({}, "a", 1.0)
    s = grant_share(s, "b", 0.25)
    assert s == {"a": 0.75, "b": 0.25}
    s = grant_share(s, "c", 0.2)
    assert abs(sum(s.values()) - 1.0) < 1e-12
    # survivors keep their relative ratio when one member is absorbed
    dropped = absorb_share(s, "c")
    assert abs(dropped["a"] / dropped["b"] - 3.0) < 1e-9
    assert abs(sum(dropped.values()) - 1.0) < 1e-12
    # absent names are a no-op; bad hints raise
    assert absorb_share(dropped, "zzz") == dropped
    with pytest.raises(ValueError):
        grant_share(s, "d", 1.5)


def test_rebalance_policies_delegate_to_cluster_shares():
    """hetero's RebalancePolicy drop/add and the cluster supervisor now
    share one implementation — the moves must agree exactly."""
    from repro.hetero.rebalance import StaticPolicy

    pol = StaticPolicy({"cpu": 2.0, "gpu": 6.0})
    ours = dict(pol.shares)
    pol.add_group("tpu", 0.5)
    ours = grant_share(ours, "tpu", 0.5)
    assert pol.shares == ours
    pol.drop_group("cpu")
    ours = absorb_share(ours, "cpu")
    assert pol.shares == ours


# ---------------------------------------------------------------------------
# FailurePlan artifacts
# ---------------------------------------------------------------------------

def test_failure_plan_json_round_trip_and_save_load(tmp_path):
    plan = FailurePlan(events={5: "crash", 9: "kill:B"},
                       timeline=((0.05, "kill:1"), (0.2, "join:u2")))
    assert FailurePlan.from_json(plan.to_json()) == plan
    path = tmp_path / "plan.json"
    plan.save(path)
    assert FailurePlan.load(path) == plan
    # the training loop's step-keyed contract is unchanged
    assert plan.check(5) == "crash"
    assert plan.check(9) == "kill:B"
    assert plan.check(2) is None
    # int keys survive the str round trip
    loaded = FailurePlan.load(path)
    assert loaded.events == {5: "crash", 9: "kill:B"}


def test_failure_plan_rejects_malformed_input():
    with pytest.raises(ValueError):
        FailurePlan.from_dict({"version": 99})
    with pytest.raises(ValueError):
        FailurePlan(timeline=((0.1, "explode:1"),)).validate()
    with pytest.raises(ValueError):
        FailurePlan(timeline=((-0.1, "kill:1"),)).validate()
    with pytest.raises(ValueError):
        FailurePlan(timeline=((0.1, "kill"),)).validate()


def test_failure_plan_is_importable_from_ft():
    """Training code keeps its import path after the absorption."""
    from repro.core.cluster import FailurePlan as core_plan
    from repro.ft import FailurePlan as ft_plan
    from repro.ft import InjectedFailure as ft_err
    from repro.core.cluster import InjectedFailure as core_err

    assert ft_plan is core_plan
    assert ft_err is core_err


def test_resolve_unit_token():
    names = ["cpu0", "gpu1", "gpu2"]
    assert _resolve_unit("1", names) == 1
    assert _resolve_unit("gpu2", names) == 2
    with pytest.raises(ValueError):
        _resolve_unit("7", names)
    with pytest.raises(ValueError):
        _resolve_unit("nope", names)


def test_committed_example_plan_loads():
    import pathlib

    path = (pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
            / "failure_plans" / "example_plan.json")
    plan = FailurePlan.load(path).validate()
    assert any(a.startswith("kill:") for _, a in plan.timeline)


# ---------------------------------------------------------------------------
# Supervisor: heartbeats, stragglers, membership
# ---------------------------------------------------------------------------

def _bare_loop(n=NUNITS, cfg=None):
    units = sim_units(n)
    backend = ClusterSimBackend(units, MemoryModel.USM, MemoryCosts())
    loop = ExecutionLoop(backend, [u.name for u in units],
                         cfg or cluster_cfg())
    return loop, backend


def test_supervisor_heartbeat_detection_declares_silent_units_dead():
    loop, _ = _bare_loop()
    sup = Supervisor(loop, heartbeat_s=0.01, grace_s=0.05)
    for u in range(NUNITS):
        sup.register(u, 1.0, t=0.0)
    sup.beat(0, 0.04)
    sup.beat(1, 0.04)       # unit 2 goes silent after t=0
    assert sup.check(0.03) == []            # everyone within grace
    assert sup.check(0.06) == [2]           # only the silent unit dies
    assert loop.dead_units == {2}
    assert sup.check(0.06) == []            # idempotent
    assert [u for _, u in sup.kills] == [2]
    # its share was absorbed by the survivors
    assert set(sup.shares) == {"u0", "u1"}
    assert abs(sum(sup.shares.values()) - 1.0) < 1e-12


def test_supervisor_straggler_flagged_once_per_incident():
    loop, _ = _bare_loop()
    flagged = []
    sup = Supervisor(loop, grace_s=1.0, straggler_factor=2.0,
                     on_straggler=lambda u, age: flagged.append(u))
    for u in range(NUNITS):
        sup.register(u, 1.0)
    # one launch, one package pulled by unit 0 at t=0 and never completed
    entry = _SimLaunchState(loop.next_id(),
                            DynamicScheduler(64, NUNITS, num_packages=8),
                            Workload("t", 64, 8.0, 8.0, 1e4))
    assert loop.offer(entry, now=0.0)
    assert loop.pull(0, now=0.0) is not None
    sup.note_service(0.01)                  # EWMA ~ 10ms service time
    assert sup.flag_stragglers(0.015) == []         # below the threshold
    assert sup.flag_stragglers(0.5) == [0]          # way past 2x EWMA
    assert sup.flag_stragglers(0.6) == []           # same incident: once
    assert flagged == [0]


def test_supervisor_retire_refuses_inflight_work():
    loop, _ = _bare_loop()
    sup = Supervisor(loop)
    for u in range(NUNITS):
        sup.register(u, 1.0)
    entry = _SimLaunchState(loop.next_id(),
                            DynamicScheduler(64, NUNITS, num_packages=8),
                            Workload("t", 64, 8.0, 8.0, 1e4))
    assert loop.offer(entry, now=0.0)
    assert loop.pull(1, now=0.0) is not None
    with pytest.raises(ValueError):
        sup.retire_unit(1)
    sup.retire_unit(2)                      # idle unit retires gracefully
    assert loop.dead_units == {2}
    assert [u for _, u in sup.leaves] == [2]
    assert sup.kills == []


# ---------------------------------------------------------------------------
# UnitPool + Autoscaler
# ---------------------------------------------------------------------------

def test_unit_pool_grow_shrink_and_drain_guard():
    loop, _ = _bare_loop(n=4)
    pool = UnitPool(loop, min_units=2)
    assert pool.size == 2 and pool.alive == [0, 1]
    assert loop.dead_units == {2, 3}        # dormant slots park as dead
    assert pool.grow(1) == [2]
    assert pool.size == 3
    # a unit with in-flight work refuses to drain, shrink skips it
    entry = _SimLaunchState(loop.next_id(),
                            DynamicScheduler(64, 4, num_packages=8),
                            Workload("t", 64, 8.0, 8.0, 1e4))
    assert loop.offer(entry, now=0.0)
    assert loop.pull(2, now=0.0) is not None
    assert pool.drain(2) is False
    # shrink skips the busy unit and retires the idle one instead,
    # stopping at the floor
    assert pool.shrink(2) == [1]
    assert pool.size == 2 and pool.alive == [0, 2]
    assert pool.shrink(1) == []             # at the floor: refuse
    got = loop.pull(0, now=0.0)             # finish the work elsewhere
    while got is not None:
        launch, pkg = got
        loop.backend.dispatch(0, launch, pkg)
        loop.complete(launch, pkg)
        got = loop.pull(0, now=loop.backend.now())
    # unit 2's package is still owned by unit 2 — complete it so the
    # drain guard lifts
    for (lid, seq), (launch, pkg) in list(loop._owned.get(2, {}).items()):
        loop.backend.dispatch(2, launch, pkg)
        loop.complete(launch, pkg)
    assert pool.drain(2) is True
    assert pool.size == 1
    with pytest.raises(ValueError):
        UnitPool(loop, min_units=9)


def test_autoscaler_hysteresis_sustain_and_cooldown():
    loop, _ = _bare_loop(n=4)
    pool = UnitPool(loop, min_units=1)
    scaler = Autoscaler(pool, scale_up_depth=4, scale_down_depth=1,
                        sustain_s=0.1, idle_s=0.2, cooldown_s=0.5)
    assert scaler.observe(0.00, 8) == 0     # backlog must sustain first
    assert scaler.observe(0.05, 8) == 0
    assert scaler.observe(0.11, 8) == 1     # sustained: scale out
    assert pool.size == 2
    assert scaler.observe(0.30, 8) == 0     # cooldown holds
    assert scaler.observe(0.70, 8) == 1     # cooled: scale out again
    assert scaler.observe(0.80, 2) == 0     # hysteresis band: hold
    assert scaler.observe(1.50, 0) == 0     # idle clock starts
    assert scaler.observe(1.72, 0) == -1    # idle + cooled: scale in
    assert pool.size == 2
    assert [d for _, d in scaler.actions] == [1, 1, -1]
    with pytest.raises(ValueError):
        Autoscaler(pool, scale_up_depth=2, scale_down_depth=2)


# ---------------------------------------------------------------------------
# Exact-once re-issue: the tentpole invariant
# ---------------------------------------------------------------------------

def _pool_units(n):
    return sim_units(n=n, speed=20_000.0)


def _kill_trace(seed=3):
    return synthesize_trace(60, 40.0, tenants=4, items=4096,
                            item_jitter=0.8, slo_ms=200.0, seed=seed)


@pytest.mark.parametrize("policy", ["wfq", "edf"])
def test_kill_one_of_four_is_bitwise_identical_to_undisturbed(policy):
    """Acceptance: kill 1-of-4 units mid-serve — every launch completes,
    per-launch package covers and data-plane counters are bitwise
    identical to an undisturbed run, nothing lost or duplicated."""
    trace = _kill_trace()
    units = _pool_units(4)
    r0 = replay_trace_cluster(trace, units, admission=policy)
    plan = FailurePlan(timeline=((0.2, "kill:3"),))
    r1 = replay_trace_cluster(trace, units, admission=policy, plan=plan)
    assert r1.kills == [(0.2, 3)]
    assert r1.reissued > 0                  # the kill caught work in flight
    assert r1.lost == 0 and r1.duplicated == 0
    assert r1.completed == r0.completed == len(trace)
    assert r1.covers() == r0.covers()
    assert r1.data_totals() == r0.data_totals()


def test_kill_join_wave_keeps_exact_accounting():
    trace = _kill_trace()
    units = _pool_units(4)
    r0 = replay_trace_cluster(trace, units, admission="wfq")
    plan = FailurePlan(timeline=((0.2, "kill:3"), (0.5, "kill:1"),
                                 (0.8, "join:3"), (1.0, "join:1")))
    r1 = replay_trace_cluster(trace, units, admission="wfq", plan=plan)
    assert len(r1.kills) == 2 and len(r1.joins) == 2
    assert r1.lost == 0 and r1.duplicated == 0
    assert r1.covers() == r0.covers()
    assert r1.data_totals() == r0.data_totals()


def test_killing_the_whole_pool_wedges_loudly():
    trace = _kill_trace()
    units = _pool_units(2)
    plan = FailurePlan(timeline=((0.1, "kill:0"), (0.1, "kill:1")))
    with pytest.raises(RuntimeError, match="wedged"):
        replay_trace_cluster(trace, units, admission="wfq", plan=plan)


@settings(max_examples=10)
@given(cfg=st.fixed_dictionaries(dict(
    seed=st.integers(0, 10_000),
    kill_unit=st.integers(0, 3),
    t_kill=st.floats(0.05, 1.2),
    policy=st.sampled_from(["wfq", "edf", "fifo"]),
    join_back=st.booleans())))
def test_property_reissue_accounting_sums_exactly(cfg):
    """Property: for any (seed, victim, kill time, policy), the disturbed
    run's per-launch covers and data totals equal the undisturbed run's,
    with zero launches lost or duplicated."""
    trace = synthesize_trace(24, 50.0, tenants=3, items=2048,
                             item_jitter=0.6, slo_ms=200.0,
                             seed=cfg["seed"])
    units = _pool_units(4)
    timeline = [(cfg["t_kill"], f"kill:{cfg['kill_unit']}")]
    if cfg["join_back"]:
        timeline.append((cfg["t_kill"] + 0.3, f"join:{cfg['kill_unit']}"))
    r0 = replay_trace_cluster(trace, units, admission=cfg["policy"])
    r1 = replay_trace_cluster(trace, units, admission=cfg["policy"],
                              plan=FailurePlan(timeline=tuple(timeline)))
    assert r1.lost == 0 and r1.duplicated == 0
    assert r1.covers() == r0.covers()
    assert r1.data_totals() == r0.data_totals()


# ---------------------------------------------------------------------------
# Real-vs-sim lockstep structural parity
# ---------------------------------------------------------------------------

EVENT_SCRIPTS = {
    "kill": [(5, "kill:2")],
    "leave": [(5, "leave:2")],
    "kill+join": [(5, "kill:2"), (14, "join:2")],
}


def run_cluster_lockstep_real(trace, cfg, events):
    units = counits_from_devices(jax.local_devices()[:1] * NUNITS,
                                 kinds=["cpu"] * NUNITS,
                                 speed_hints=[1.0 / NUNITS] * NUNITS)
    backend = RealBackend(units, make_plane(MemoryModel.USM))
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)
    backend.loop = loop
    datas = {}

    def make_launch(a, lp):
        sched = DynamicScheduler(a.items, NUNITS, num_packages=8)
        d = np.random.default_rng(a.items).normal(
            size=a.items).astype(np.float32)
        out = np.zeros(a.items, np.float32)
        launch = _Launch(lp.next_id(), sched, KERNEL, [d], out,
                         adaptive=False)
        launch.plan = backend.plane.plan(KERNEL, [d], out, a.items)
        launch.tenant = a.tenant
        launch.weight = a.weight
        datas[launch.id] = d
        return launch

    admitted, shed = replay_cluster_lockstep(trace, loop, make_launch,
                                             events=events)
    return loop, admitted, shed, datas


def run_cluster_lockstep_sim(trace, cfg, events, depth=1):
    units = sim_units(speed=1000.0)
    backend = ClusterSimBackend(units, MemoryModel.USM, MemoryCosts(),
                                pipeline_depth=depth)
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)

    def make_launch(a, lp):
        return _SimLaunchState(
            lp.next_id(), DynamicScheduler(a.items, NUNITS, num_packages=8),
            Workload("traffic", a.items, 8.0, 8.0, 1e4), tenant=a.tenant,
            weight=a.weight)

    admitted, shed = replay_cluster_lockstep(trace, loop, make_launch,
                                             events=events)
    return loop, admitted, shed


@pytest.mark.parametrize("script", sorted(EVENT_SCRIPTS))
@pytest.mark.parametrize("policy", ["wfq", "edf"])
@pytest.mark.parametrize("preempt", [False, True])
@pytest.mark.parametrize("depth", [1, 2])
def test_cluster_lockstep_parity_real_vs_sim(script, policy, preempt,
                                             depth):
    """Acceptance (structure): identical trace + config + membership
    events = identical admission decisions, identical per-launch package
    covers and identical re-issue counts on the threaded backend and the
    DES — and the real results stay exact through kills and joins."""
    cfg = cluster_cfg(policy, preempt)
    trace = cluster_trace()
    events = EVENT_SCRIPTS[script]

    real_loop, real_adm, real_shed, datas = \
        run_cluster_lockstep_real(trace, cfg, events)
    sim_loop, sim_adm, sim_shed = run_cluster_lockstep_sim(trace, cfg,
                                                           events,
                                                           depth=depth)

    assert real_loop.admission.decision_log == \
        sim_loop.admission.decision_log
    assert len(real_adm) == len(sim_adm) > 0
    assert len(real_shed) == len(sim_shed)
    assert real_loop.reissued == sim_loop.reissued
    if script.startswith("kill"):
        assert real_loop.reissued > 0
    covers_real = {l.id: tuple(sorted((p.offset, p.size)
                                      for p in l.stats.packages))
                   for l in real_adm}
    covers_sim = {l.id: tuple(sorted((p.offset, p.size)
                                     for p in l.stats.packages))
                  for l in sim_adm}
    assert covers_real == covers_sim
    for launch in real_adm:
        np.testing.assert_array_equal(launch.handle.result(timeout=5),
                                      datas[launch.id] * 2.0)


def test_lockstep_events_match_cluster_sim_covers():
    """The same kill produces the same covers whether driven by the
    lockstep harness or the ClusterSimBackend event pump (undisturbed
    reference: both must equal the no-event run)."""
    cfg = cluster_cfg("wfq")
    trace = cluster_trace()
    base_loop, base_adm, _ = run_cluster_lockstep_sim(trace, cfg, [])
    kill_loop, kill_adm, _ = run_cluster_lockstep_sim(
        trace, cfg, EVENT_SCRIPTS["kill"])
    base_covers = {l.id: tuple(sorted((p.offset, p.size)
                                      for p in l.stats.packages))
                   for l in base_adm}
    kill_covers = {l.id: tuple(sorted((p.offset, p.size)
                                      for p in l.stats.packages))
                   for l in kill_adm}
    assert kill_covers == base_covers
    assert kill_loop.reissued > 0


# ---------------------------------------------------------------------------
# Thread-backed engine: kill mid-launch (the LaunchWaitTimeout regression)
# ---------------------------------------------------------------------------

@pytest.mark.timeout(60)
def test_engine_kill_unit_mid_launch_resolves_exactly():
    """Regression: a unit killed with packages in flight must never make
    a pending LaunchHandle time out or error — survivors pick up the
    re-issued ranges and the handle resolves with exact results."""
    units = counits_from_devices(jax.local_devices()[:1] * NUNITS,
                                 kinds=["cpu"] * NUNITS,
                                 speed_hints=[1.0 / NUNITS] * NUNITS)
    spec = CoexecSpec.builder().admission(wfq=True).build()
    eng = CoexecEngine(units, spec=spec).start()
    try:
        n = 4096
        x = np.arange(n, dtype=np.float32)
        outs = [np.zeros(n, np.float32) for _ in range(3)]
        handles = [eng.submit(DynamicScheduler(n, NUNITS, num_packages=64),
                              KERNEL, [x], out, tenant=f"t{i}")
                   for i, out in enumerate(outs)]
        eng.kill_unit(2)
        for h in handles:
            np.testing.assert_array_equal(h.result(timeout=30), x * 2.0)
        assert 2 in eng.loop.dead_units
        # the pool revives and keeps serving
        eng.join_unit(2)
        out2 = np.zeros(n, np.float32)
        h = eng.submit(DynamicScheduler(n, NUNITS, num_packages=16),
                       KERNEL, [x], out2)
        np.testing.assert_array_equal(h.result(timeout=30), x * 2.0)
        assert eng.loop.dead_units == set()
    finally:
        eng.shutdown()


@pytest.mark.timeout(60)
def test_engine_kill_refuses_last_live_unit():
    units = counits_from_devices(jax.local_devices()[:1] * 2,
                                 kinds=["cpu", "cpu"],
                                 speed_hints=[0.5, 0.5])
    eng = CoexecEngine(units).start()
    try:
        eng.kill_unit(0)
        with pytest.raises(RuntimeError, match="last live unit"):
            eng.kill_unit(1)
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# Scale + spec plumbing
# ---------------------------------------------------------------------------

def test_thousand_unit_pool_survives_failure_wave():
    """A 1000-slot DES pool with scripted kill/join waves stays exact."""
    trace = synthesize_trace(40, 200.0, tenants=8, items=4096,
                             item_jitter=0.5, slo_ms=500.0, seed=7)
    units = [SimUnit(f"u{i}", "cpu", speed=5_000.0, setup_s=1e-3)
             for i in range(1000)]
    plan = FailurePlan(
        timeline=tuple((0.02 + 0.002 * i, f"kill:{i}") for i in range(20))
        + tuple((0.2 + 0.002 * i, f"join:{i}") for i in range(10)))
    r = replay_trace_cluster(trace, units, admission="wfq", plan=plan)
    assert r.max_units == 1000
    assert r.completed == len(trace)
    assert r.lost == 0 and r.duplicated == 0
    assert len(r.kills) == 20 and len(r.joins) == 10


def test_autoscale_halves_burst_p99_vs_fixed_floor():
    """Acceptance: under a burst trace, autoscaling 2 -> 8 must at least
    halve admitted p99 latency vs the fixed 2-unit floor."""
    units = [SimUnit(f"u{i}", "cpu", speed=10_000.0, setup_s=1e-3)
             for i in range(8)]
    trace = synthesize_trace(96, 14.0, arrival="burst", burst=6.0,
                             burst_duty=0.15, tenants=4, items=2048,
                             item_jitter=0.3, slo_ms=2000.0, seed=11)
    fixed = replay_trace_cluster(trace, units[:2], admission="wfq")
    auto = replay_trace_cluster(
        trace, units, admission="wfq", min_units=2, autoscale=True,
        autoscale_opts=dict(scale_up_depth=4, scale_down_depth=1,
                            sustain_s=0.02, idle_s=0.5, cooldown_s=0.05))
    assert auto.scale_events                 # the pool actually resized
    assert auto.lost == 0 and auto.duplicated == 0
    assert auto.p99_ms() <= fixed.p99_ms() / 2


def test_cluster_spec_validates_and_round_trips():
    spec = (CoexecSpec.builder()
            .cluster(True, min_units=2, max_units=8, autoscale=True,
                     grace_s=0.5)
            .build())
    assert spec.cluster.enabled and spec.cluster.max_units == 8
    assert CoexecSpec.from_json(spec.to_json()) == spec
    opts = spec.cluster.autoscaler_opts()
    assert opts["scale_up_depth"] == 8 and opts["cooldown_s"] == 0.25
    for bad in (dict(min_units=0), dict(min_units=4, max_units=2),
                dict(grace_s=0.0), dict(scale_up_depth=1),
                dict(straggler_factor=0.0)):
        with pytest.raises(ValueError):
            ClusterSpec(**bad).validate()


def test_cluster_cli_flags_round_trip():
    import argparse

    from repro.api import add_spec_args, args_from_spec, spec_from_args

    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    argv = ["--cluster", "--cluster-min-units", "2",
            "--cluster-max-units", "6", "--cluster-autoscale",
            "--cluster-grace-s", "0.4"]
    spec = spec_from_args(ap.parse_args(argv))
    assert spec.cluster.enabled and spec.cluster.grace_s == 0.4
    assert sorted(args_from_spec(spec)) == sorted(argv)


def test_scheduler_unit_hooks_cover_exactly():
    """StaticScheduler hands back its region remainder on unit loss;
    work-stealing drains the dead deque — either way the re-issued
    ranges tile exactly what the dead unit still owed."""
    from repro.core import StaticScheduler, WorkStealingScheduler

    sched = StaticScheduler(100, NUNITS, speeds=[1.0, 1.0, 2.0])
    first = sched.next_package(2)
    freed = sched.unit_lost(2)
    assert sum(r.size for r in freed) + first.size == \
        sched._bounds[3] - sched._bounds[2]
    assert sched.unit_lost(2) == []          # nothing left to free

    ws = WorkStealingScheduler(96, NUNITS, chunks_per_unit=4)
    owed = sum(r.size for r in ws._deques[1])
    freed = ws.unit_lost(1)
    assert sum(r.size for r in freed) == owed
    assert not ws._deques[1]
