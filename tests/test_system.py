"""End-to-end behaviour tests: train-to-convergence (tiny), serving, and
the paper's public API shape (Listing 1)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import CoexecutorRuntime, counits_from_devices
from repro.data import DataPipeline
from repro.hetero import HeteroTrainer, make_policy
from repro.models import build_model
from repro.optim import AdamW


def test_e2e_training_learns():
    """Tiny LM on the synthetic topic distribution: loss must drop
    substantially from the random-init level."""
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = DataPipeline(seed=1, global_batch=8, seq_len=32,
                        vocab=cfg.vocab_size, num_shards=8)
    tr = HeteroTrainer(model, params, optimizer=AdamW(lr=3e-3),
                       policy=make_policy("hguided", {"A": 1.0, "B": 1.0},
                                          total_steps=40),
                       pipeline=pipe, group_speeds={"A": 1.0, "B": 0.7},
                       total_microbatches=8)
    reports = tr.run(40)
    first = np.mean([r.loss for r in reports[:3]])
    last = np.mean([r.loss for r in reports[-3:]])
    assert last < first - 0.5, (first, last)


def test_e2e_serving_batched_decode():
    """Prefill + batched greedy decode with the KV cache."""
    cfg = get_config("h2o-danube3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T_prompt, T_gen = 4, 16, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, T_prompt), 0,
                                cfg.vocab_size)
    cache = model.init_cache(B, T_prompt + T_gen)
    step = jax.jit(model.decode_step)
    # prefill token-by-token (cache path), then generate
    for t in range(T_prompt):
        logits, cache = step(params, tokens[:, t:t + 1], cache)
    generated = []
    cur = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    for _ in range(T_gen):
        generated.append(cur)
        logits, cache = step(params, cur, cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)[:, None]
    gen = jnp.concatenate(generated, axis=1)
    assert gen.shape == (B, T_gen)
    assert bool((gen >= 0).all()) and bool((gen < cfg.vocab_size).all())


def test_listing1_api_shape():
    """The paper's Listing 1, in this framework's Python rendering."""
    from repro.api import CoexecSpec

    n = 1 << 12
    data = np.arange(n, dtype=np.float32)
    datav = 2.5

    spec = (CoexecSpec.builder()                           # line 1
            .policy("hguided").dist(0.35).memory("usm")    # line 2
            .build())
    runtime = CoexecutorRuntime.from_spec(
        spec, units=counits_from_devices())

    def kernel(offset, chunk):                             # lines 3-13
        return chunk * datav

    out = runtime.launch(n, kernel, [data])                # blocking
    np.testing.assert_allclose(out, data * datav)          # results land
    assert runtime.last_stats.total_s > 0
