"""Step-level co-execution: policies, quantization, the hetero trainer."""
import jax
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.configs import get_config
from repro.data import DataPipeline
from repro.hetero import (DynamicPolicy, GroupMonitor, HGuidedPolicy,
                          HeteroTrainer, StaticPolicy, make_policy,
                          quantize_shares)
from repro.models import build_model
from repro.optim import AdamW


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

@given(n_groups=st.integers(1, 6), total=st.integers(6, 64),
       seed=st.integers(0, 100))
@settings(max_examples=60, deadline=None)
def test_quantize_preserves_total_and_minimum(n_groups, total, seed):
    rng = np.random.default_rng(seed)
    raw = rng.random(n_groups) + 0.01
    shares = {f"g{i}": float(v / raw.sum()) for i, v in enumerate(raw)}
    q = quantize_shares(shares, total)
    assert sum(q.values()) == total
    assert all(v >= 1 for v in q.values())
    # quantization error below one microbatch per group
    for k in shares:
        assert abs(q[k] - shares[k] * total) <= n_groups


def test_quantize_rejects_impossible():
    with pytest.raises(ValueError):
        quantize_shares({"a": 0.5, "b": 0.5}, 1)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

MEASURED = {"fast": 2 / 3, "slow": 1 / 3}


def test_static_never_moves():
    p = StaticPolicy({"fast": 1.0, "slow": 1.0})
    for s in range(20):
        assert not p.update(s, MEASURED)
    assert p.shares["fast"] == pytest.approx(0.5)


def test_dynamic_jumps_to_measured():
    p = DynamicPolicy({"fast": 1.0, "slow": 1.0}, period=5)
    assert not p.update(1, MEASURED)          # off-period
    assert p.update(5, MEASURED)
    assert p.shares["fast"] == pytest.approx(2 / 3)


def test_hguided_converges_with_damping_and_floor():
    p = HGuidedPolicy({"fast": 1.0, "slow": 1.0}, total_steps=100,
                      min_share=0.05)
    hist = []
    for s in range(100):
        p.update(s, {"fast": 0.97, "slow": 0.03})
        hist.append(p.shares["fast"])
    # converges toward the target but never starves the slow group
    assert hist[-1] > 0.9
    assert p.shares["slow"] >= 0.05 - 1e-9
    # early corrections bigger than late ones (the HGuided signature)
    assert (hist[1] - hist[0]) >= 0.8 * (hist[60] - hist[59])


def test_policy_elastic_drop_and_add():
    p = make_policy("hguided", {"a": 1.0, "b": 1.0, "c": 2.0},
                    total_steps=10)
    p.drop_group("c")
    assert set(p.shares) == {"a", "b"}
    assert sum(p.shares.values()) == pytest.approx(1.0)
    p.add_group("d", 0.25)
    assert p.shares["d"] == pytest.approx(0.25)
    assert sum(p.shares.values()) == pytest.approx(1.0)


def test_monitor_straggler_detection():
    m = GroupMonitor(["a", "b", "c"], straggler_factor=0.6)
    for _ in range(5):
        m.record("a", 1000, 1.0)
        m.record("b", 1000, 1.05)
        m.record("c", 1000, 4.0)     # 4x slower
    assert m.stragglers() == ["c"]
    m.mark_dead("c")
    assert set(m.alive()) == {"a", "b"}


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

def make_trainer(policy_name="hguided", speeds=None, steps=20):
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = DataPipeline(seed=5, global_batch=8, seq_len=16,
                        vocab=cfg.vocab_size, num_shards=8)
    speeds = speeds or {"A": 1.0, "B": 0.5}
    policy = make_policy(policy_name, {k: 1.0 for k in speeds},
                         total_steps=steps)
    return HeteroTrainer(model, params, optimizer=AdamW(lr=1e-3),
                         policy=policy, pipeline=pipe,
                         group_speeds=speeds, total_microbatches=8)


def test_trainer_loss_decreases():
    tr = make_trainer()
    reports = tr.run(15)
    assert reports[-1].loss < reports[0].loss


def test_hguided_assignment_tracks_speeds():
    tr = make_trainer("hguided", {"A": 1.0, "B": 0.25}, steps=25)
    tr.run(25)
    a = tr.history[-1].assignment
    assert a["A"] > a["B"]            # 4x speed ⇒ more microbatches
    assert a["A"] + a["B"] == 8


def test_gradients_invariant_to_policy():
    """Assignments move *where* microbatches run, never their content —
    the loss trajectory must be identical across policies."""
    t1 = make_trainer("static")
    t2 = make_trainer("hguided")
    l1 = [r.loss for r in t1.run(5)]
    l2 = [r.loss for r in t2.run(5)]
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_step_time_improves_under_hguided():
    tr = make_trainer("hguided", {"A": 1.0, "B": 0.2}, steps=30)
    reports = tr.run(30)
    first = np.mean([r.step_seconds for r in reports[1:4]])
    last = np.mean([r.step_seconds for r in reports[-3:]])
    assert last < first * 0.9         # rebalancing shortened the barrier


def test_kill_group_redistributes():
    tr = make_trainer("hguided", {"A": 1.0, "B": 1.0, "C": 1.0})
    tr.run(3)
    tr.kill_group("C")
    rep = tr.train_step()
    assert "C" not in rep.assignment
    assert sum(rep.assignment.values()) == 8
