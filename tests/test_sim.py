"""DES reproduction of the paper's headline claims (§5, Figs. 5-8)."""
import numpy as np
import pytest

from repro.api import build_scheduler
from repro.core import (ALL_BENCHMARKS, IRREGULAR, REGULAR, MemoryModel,
                        PAPER_POWER, edp_ratio, geomean,
                        paper_workload, simulate, solo_run)
from repro.core.workloads import effective_shares

KINDS = {"gpu": "gpu", "cpu": "cpu"}


def run(name, policy, mem=MemoryModel.USM, hint_error=0.25):
    wl, cpu, gpu = paper_workload(name)
    speeds = effective_shares(wl, cpu, gpu, hint_error=hint_error)
    kw = {"speeds": speeds} if policy in ("static", "hguided") else {}
    sched = build_scheduler(policy, wl.total, 2, **kw)
    res = simulate(sched, [cpu, gpu], wl, memory=mem)
    solo = solo_run(gpu, wl, memory=mem)
    return res, solo


def speedup(name, policy, mem=MemoryModel.USM):
    res, solo = run(name, policy, mem)
    return solo.total_s / res.total_s


def test_hguided_balance_near_one():
    """Fig. 5 top: HGuided balancing efficiency ≈ 1 on every benchmark."""
    for name in ALL_BENCHMARKS:
        res, _ = run(name, "hguided")
        assert 0.9 <= res.balance() <= 1.1, (name, res.balance())


def test_paper_speedup_anchors():
    """§5.1: HGuided speedups range from 1.48 (Ray) to 2.46 (Rap)."""
    assert speedup("ray", "hguided") == pytest.approx(1.48, abs=0.07)
    assert speedup("rap", "hguided") == pytest.approx(2.46, abs=0.07)
    for name in ALL_BENCHMARKS:
        s = speedup(name, "hguided")
        assert 1.3 <= s <= 2.6, (name, s)


def test_coexecution_profitable_with_dynamic_schedulers():
    """The headline: co-execution always >1 with dynamic scheduling."""
    for name in ALL_BENCHMARKS:
        for policy in ("dyn200", "hguided"):
            assert speedup(name, policy) > 1.0, (name, policy)


def test_dyn200_beats_dyn5_balance():
    """§5.1: more packages ⇒ better balancing (Dyn5 under-performs)."""
    for name in ("gaussian", "mandelbrot", "ray"):
        b200 = abs(1 - run(name, "dyn200")[0].balance())
        b5 = abs(1 - run(name, "dyn5")[0].balance())
        assert b200 < b5, name


def test_static_never_best():
    """§5.1: Static offers the worst performance of the four configs."""
    for name in ALL_BENCHMARKS:
        s_static = speedup(name, "static")
        s_hg = speedup(name, "hguided")
        assert s_hg >= s_static - 0.12, (name, s_static, s_hg)


def test_usm_geq_buffers():
    """§5.1: USM ≥ Buffers, with the regular kernels hurt most at
    Dyn200 ("Gaussian with Buffers")."""
    for name in ALL_BENCHMARKS:
        su = speedup(name, "hguided", MemoryModel.USM)
        sb = speedup(name, "hguided", MemoryModel.BUFFERS)
        assert su >= sb - 0.02, name
    gap_reg = speedup("gaussian", "dyn200", MemoryModel.USM) - \
        speedup("gaussian", "dyn200", MemoryModel.BUFFERS)
    assert gap_reg > 0.15


def test_energy_only_taylor_rap_improve():
    """Fig. 6: GPU-only is minimum energy except Taylor and Rap."""
    for name in ALL_BENCHMARKS:
        res, solo = run(name, "hguided")
        e_co = res.energy(PAPER_POWER, KINDS).total_J
        e_gpu = solo.energy(PAPER_POWER, KINDS).total_J
        if name in ("taylor", "rap"):
            assert e_co < e_gpu, name
        else:
            assert e_co >= e_gpu * 0.95, name


def test_edp_geomean_72_percent():
    """Fig. 7: HGuided+USM is ≈72 % more energy-efficient than GPU-only
    (we reproduce 1.72 within ±0.25) and favorable on every benchmark."""
    ratios = []
    for name in ALL_BENCHMARKS:
        res, solo = run(name, "hguided")
        r = edp_ratio(solo.energy(PAPER_POWER, KINDS),
                      res.energy(PAPER_POWER, KINDS))
        assert r > 1.0, (name, r)
        ratios.append(r)
    g = geomean(ratios)
    assert 1.45 <= g <= 2.0, g


def test_scalability_turning_point():
    """Fig. 8: co-execution loses below a size threshold, wins above."""
    name = "mandelbrot"
    small = None, None
    wl_s, cpu, gpu = paper_workload(name, size_scale=0.001)
    sp_small = (solo_run(gpu, wl_s).total_s /
                simulate(build_scheduler("hguided", wl_s.total, 2,
                                        speeds=effective_shares(
                                            wl_s, cpu, gpu)),
                         [cpu, gpu], wl_s).total_s)
    sp_big = speedup(name, "hguided")
    assert sp_small < sp_big
    assert sp_big > 1.2


def test_matmul_llc_contention_at_scale():
    """§5.3: very large MatMul degrades co-execution toward GPU-only."""
    wl, cpu, gpu = paper_workload("matmul", size_scale=8.0)
    sched = build_scheduler("hguided", wl.total, 2,
                           speeds=effective_shares(wl, cpu, gpu))
    res = simulate(sched, [cpu, gpu], wl)
    solo = solo_run(gpu, wl)
    big = solo.total_s / res.total_s
    assert big < speedup("matmul", "hguided") - 0.1
