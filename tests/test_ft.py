"""Fault tolerance: crash/restore exactness, elastic scale-down,
straggler hooks."""
import tempfile

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import DataPipeline
from repro.ft import FailurePlan, Supervisor
from repro.hetero import HeteroTrainer, make_policy
from repro.models import build_model
from repro.optim import AdamW


def make_trainer(speeds=None, mbs=4):
    import dataclasses
    # vlm backbone trained text-only (vision stub absent) for speed
    cfg = dataclasses.replace(get_config("internvl2-1b").reduced(),
                              vision_tokens=0, family="dense")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = DataPipeline(seed=11, global_batch=mbs, seq_len=16,
                        vocab=cfg.vocab_size, num_shards=mbs)
    speeds = speeds or {"A": 1.0, "B": 0.5}
    policy = make_policy("hguided", {k: 1.0 for k in speeds},
                         total_steps=30)
    return HeteroTrainer(model, params, optimizer=AdamW(lr=1e-3),
                         policy=policy, pipeline=pipe,
                         group_speeds=speeds, total_microbatches=mbs)


def test_crash_restore_resumes_identical_trajectory():
    """A crash + restore must replay to the same losses as a clean run
    (deterministic pipeline + exact checkpoint restore)."""
    with tempfile.TemporaryDirectory() as d:
        clean = Supervisor(make_trainer(), Checkpointer(d + "/clean"),
                           ckpt_every=3).run(10)
    with tempfile.TemporaryDirectory() as d:
        crashed = Supervisor(
            make_trainer(), Checkpointer(d + "/crash"), ckpt_every=3,
            failure_plan=FailurePlan(events={5: "crash"})).run(10)
    assert crashed.restarts == 1
    # steps 5.. replayed; final losses identical to the clean run
    np.testing.assert_allclose(sorted(clean.losses)[-3:],
                               sorted(crashed.losses)[-3:], rtol=1e-5)
    assert crashed.steps_run == clean.steps_run == 10


def test_group_failure_elastic_continue():
    with tempfile.TemporaryDirectory() as d:
        tr = make_trainer({"A": 1.0, "B": 1.0, "C": 1.0})
        rep = Supervisor(tr, Checkpointer(d), ckpt_every=5,
                         failure_plan=FailurePlan(events={4: "kill:C"})
                         ).run(8)
    assert rep.groups_lost == ["C"]
    assert rep.steps_run == 8
    assert "C" not in tr.history[-1].assignment
    assert rep.restarts == 0          # no restart needed: elastic


def test_straggler_hook_fires():
    seen = []
    with tempfile.TemporaryDirectory() as d:
        tr = make_trainer({"A": 1.0, "B": 0.2})
        Supervisor(tr, Checkpointer(d), ckpt_every=10,
                   on_straggler=seen.append).run(6)
    assert seen == ["B"]


def test_checkpoint_cadence():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=10)
        Supervisor(make_trainer(), ck, ckpt_every=2).run(7)
        assert ck.latest_step() is not None
        assert ck.latest_step() >= 6
