"""Docs subsystem stays healthy: mermaid/links parse, docstrings hold.

Runs the same stdlib-only checkers as CI's docs job, so a broken doc
link or a stripped public docstring fails tier-1 locally too.
"""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(script: str) -> subprocess.CompletedProcess:
    return subprocess.run([sys.executable, str(REPO / "scripts" / script)],
                          capture_output=True, text=True, timeout=60)


def test_docs_exist_and_linked_from_readme():
    for page in ("architecture.md", "policies.md", "benchmarks.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"
    readme = (REPO / "README.md").read_text()
    assert "docs/architecture.md" in readme
    assert "docs/policies.md" in readme
    assert "docs/benchmarks.md" in readme


def test_check_docs_passes():
    proc = _run("check_docs.py")
    assert proc.returncode == 0, proc.stderr


def test_check_docstrings_passes():
    proc = _run("check_docstrings.py")
    assert proc.returncode == 0, proc.stderr
