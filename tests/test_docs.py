"""Docs subsystem stays healthy: mermaid/links parse, docstrings hold,
the public-API snapshot matches, and the examples only use the
non-deprecated (CoexecSpec) surface.

Runs the same stdlib-only checkers as CI's docs job, so a broken doc
link, a stripped public docstring or an accidental API-surface break
fails tier-1 locally too.
"""
import ast
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# the kwarg-era entry points (all emit DeprecationWarning); examples must
# demonstrate the spec surface only — see docs/api.md's deprecation table
DEPRECATED_CALLS = {"make_scheduler", "package_kernel"}
DEPRECATED_METHODS = {"config"}


def _run(script: str, *args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, str(REPO / "scripts" / script),
                           *args],
                          capture_output=True, text=True, timeout=120,
                          env=env)


def test_docs_exist_and_linked_from_readme():
    for page in ("api.md", "architecture.md", "policies.md",
                 "benchmarks.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"
    readme = (REPO / "README.md").read_text()
    assert "docs/api.md" in readme
    assert "docs/architecture.md" in readme
    assert "docs/policies.md" in readme
    assert "docs/benchmarks.md" in readme
    # the architecture page links the API page (mermaid + module map)
    assert "api.md" in (REPO / "docs" / "architecture.md").read_text()


def test_check_docs_passes():
    proc = _run("check_docs.py")
    assert proc.returncode == 0, proc.stderr


def test_check_docstrings_passes():
    proc = _run("check_docstrings.py")
    assert proc.returncode == 0, proc.stderr


def test_check_api_snapshot_matches():
    proc = _run("check_api.py")
    assert proc.returncode == 0, (proc.stdout, proc.stderr)


def test_api_snapshot_committed_and_covers_both_modules():
    snap = (REPO / "scripts" / "api_snapshot.txt").read_text()
    assert "repro.api.CoexecSpec" in snap
    assert "repro.core.CoexecutorRuntime" in snap


def _deprecated_uses(path: pathlib.Path) -> list[str]:
    """Calls to deprecated surface in one source file (by AST)."""
    tree = ast.parse(path.read_text())
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in DEPRECATED_CALLS:
            hits.append(f"{path.name}:{node.lineno} {fn.id}()")
        elif isinstance(fn, ast.Attribute) and fn.attr in (
                DEPRECATED_CALLS | DEPRECATED_METHODS):
            # `.config(...)` on anything is the runtime's legacy surface;
            # argparse etc. don't define a .config() so this stays exact
            hits.append(f"{path.name}:{node.lineno} .{fn.attr}()")
    return hits


def test_examples_use_only_non_deprecated_surface():
    hits = []
    for example in sorted((REPO / "examples").glob("*.py")):
        hits += _deprecated_uses(example)
    assert not hits, (
        "examples must demonstrate the CoexecSpec surface, not the "
        f"deprecated kwarg API: {hits}")


def test_bench_schema_checker_accepts_and_rejects():
    """The artifact schema checker passes a well-formed document and
    names the violation for a malformed one (stdlib import, no subprocess
    needed — the same code CI's docs job runs)."""
    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import check_bench_schema as cbs
    finally:
        sys.path.pop(0)

    good = {
        "schema_version": cbs.SCHEMA_VERSION, "suite": "coexec-multi",
        "spec": {}, "rows": [{
            "workload": "taylor", "tenants": 8, "admission": "wfq",
            "fuse": False, "preempt": True, "policy": "hguided",
            "p50_ms": 1.0, "p99_ms": 2.0, "fairness": 0.99,
            "fairness_curve_mean": 0.95, "fairness_curve_min": 0.9,
            "packages": 100, "fused_batches": 0, "total_ms": 10.0}]}
    assert cbs.check_doc("good.json", good) == []

    bad = dict(good, schema_version=1)
    assert any("schema_version" in e for e in cbs.check_doc("b.json", bad))
    bad = dict(good, rows=[{k: v for k, v in good["rows"][0].items()
                            if k != "preempt"}])
    assert any("preempt" in e for e in cbs.check_doc("b.json", bad))
    bad = dict(good, rows=[dict(good["rows"][0], p99_ms="fast")])
    assert any("p99_ms" in e for e in cbs.check_doc("b.json", bad))
    bad = dict(good, suite="nope")
    assert any("suite" in e for e in cbs.check_doc("b.json", bad))


def test_examples_import_the_spec_api():
    """The migrated examples actually demonstrate repro.api."""
    for name in ("quickstart.py", "concurrent_requests.py"):
        text = (REPO / "examples" / name).read_text()
        assert "from repro.api import" in text, name
        assert "CoexecSpec" in text, name
