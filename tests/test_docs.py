"""Docs subsystem stays healthy: mermaid/links parse, docstrings hold,
the public-API snapshot matches, and the examples only use the
non-deprecated (CoexecSpec) surface.

Runs the same stdlib-only checkers as CI's docs job, so a broken doc
link, a stripped public docstring or an accidental API-surface break
fails tier-1 locally too.
"""
import ast
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# the kwarg-era entry points (all emit DeprecationWarning); examples must
# demonstrate the spec surface only — see docs/api.md's deprecation table
DEPRECATED_CALLS = {"make_scheduler", "package_kernel"}
DEPRECATED_METHODS = {"config"}


def _run(script: str, *args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, str(REPO / "scripts" / script),
                           *args],
                          capture_output=True, text=True, timeout=120,
                          env=env)


def test_docs_exist_and_linked_from_readme():
    for page in ("api.md", "architecture.md", "policies.md",
                 "benchmarks.md"):
        assert (REPO / "docs" / page).exists(), f"docs/{page} missing"
    readme = (REPO / "README.md").read_text()
    assert "docs/api.md" in readme
    assert "docs/architecture.md" in readme
    assert "docs/policies.md" in readme
    assert "docs/benchmarks.md" in readme
    # the architecture page links the API page (mermaid + module map)
    assert "api.md" in (REPO / "docs" / "architecture.md").read_text()


def test_check_docs_passes():
    proc = _run("check_docs.py")
    assert proc.returncode == 0, proc.stderr


def test_check_docstrings_passes():
    proc = _run("check_docstrings.py")
    assert proc.returncode == 0, proc.stderr


def test_check_api_snapshot_matches():
    proc = _run("check_api.py")
    assert proc.returncode == 0, (proc.stdout, proc.stderr)


def test_api_snapshot_committed_and_covers_both_modules():
    snap = (REPO / "scripts" / "api_snapshot.txt").read_text()
    assert "repro.api.CoexecSpec" in snap
    assert "repro.core.CoexecutorRuntime" in snap


def _deprecated_uses(path: pathlib.Path) -> list[str]:
    """Calls to deprecated surface in one source file (by AST)."""
    tree = ast.parse(path.read_text())
    hits = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in DEPRECATED_CALLS:
            hits.append(f"{path.name}:{node.lineno} {fn.id}()")
        elif isinstance(fn, ast.Attribute) and fn.attr in (
                DEPRECATED_CALLS | DEPRECATED_METHODS):
            # `.config(...)` on anything is the runtime's legacy surface;
            # argparse etc. don't define a .config() so this stays exact
            hits.append(f"{path.name}:{node.lineno} .{fn.attr}()")
    return hits


def test_examples_use_only_non_deprecated_surface():
    hits = []
    for example in sorted((REPO / "examples").glob("*.py")):
        hits += _deprecated_uses(example)
    assert not hits, (
        "examples must demonstrate the CoexecSpec surface, not the "
        f"deprecated kwarg API: {hits}")


def test_examples_import_the_spec_api():
    """The migrated examples actually demonstrate repro.api."""
    for name in ("quickstart.py", "concurrent_requests.py"):
        text = (REPO / "examples" / name).read_text()
        assert "from repro.api import" in text, name
        assert "CoexecSpec" in text, name
