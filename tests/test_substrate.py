"""Substrate layers: optimizer, schedules, grads, data, checkpointing."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _propcheck import given, settings, st

from repro.checkpoint import Checkpointer
from repro.data import DataPipeline
from repro.optim import (AdamW, ErrorFeedback, clip_by_global_norm,
                         compress_bf16, global_norm, make_schedule, wsd)


# ---------------------------------------------------------------------------
# optimizer / schedules / grads
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_wsd_schedule_shape():
    sched = wsd(peak_lr=1.0, warmup=10, total=100, decay_frac=0.1)
    lrs = [float(sched(jnp.asarray(s))) for s in range(100)]
    assert lrs[0] == 0.0
    assert lrs[5] == pytest.approx(0.5)
    assert lrs[50] == pytest.approx(1.0)        # stable plateau
    assert lrs[89] == pytest.approx(1.0)
    assert lrs[99] < 0.1                        # sharp final decay
    assert make_schedule("wsd", 1.0, 10, 100) is not None


def test_cosine_schedule():
    sched = make_schedule("cosine", 2.0, 5, 105)
    assert float(sched(jnp.asarray(5))) == pytest.approx(2.0)
    assert float(sched(jnp.asarray(105))) == pytest.approx(0.2, abs=0.02)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(250.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_bf16_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=1000), jnp.float32)}
    ef = ErrorFeedback.init(g)
    total_wire = jnp.zeros(1000, jnp.float32)
    total_true = jnp.zeros(1000, jnp.float32)
    for _ in range(50):
        wire, ef = compress_bf16(g, ef)
        total_wire = total_wire + wire["w"].astype(jnp.float32)
        total_true = total_true + g["w"]
    # error feedback keeps the long-run average unbiased
    err = float(jnp.max(jnp.abs(total_wire - total_true)))
    assert err < 0.05, err


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_restart():
    p1 = DataPipeline(seed=3, global_batch=8, seq_len=16, vocab=100,
                      num_shards=4)
    p2 = DataPipeline(seed=3, global_batch=8, seq_len=16, vocab=100,
                      num_shards=4)
    for step in (0, 5, 17):
        for shard in range(4):
            a = p1.batch_at(step, shard)
            b = p2.batch_at(step, shard)
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    b = p1.batch_at(0, 0)
    assert b["tokens"].shape == (2, 16)


def test_data_shards_differ():
    p = DataPipeline(seed=1, global_batch=8, seq_len=32, vocab=1000,
                     num_shards=4)
    a = p.batch_at(0, 0)["tokens"]
    b = p.batch_at(0, 1)["tokens"]
    assert not np.array_equal(a, b)


def test_data_prefetch_iterator():
    p = DataPipeline(seed=2, global_batch=4, seq_len=8, vocab=50,
                     num_shards=2, start_step=10)
    it = p.shard_iterator(0)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"],
                                  p.batch_at(10, 0)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(second["tokens"],
                                  p.batch_at(11, 0)["tokens"])


@given(shards=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=8, deadline=None)
def test_data_reshard_keeps_determinism(shards):
    p = DataPipeline(seed=9, global_batch=8, seq_len=8, vocab=64,
                     num_shards=shards)
    q = p.reshard(shards, start_step=5)
    np.testing.assert_array_equal(p.batch_at(5, 0)["tokens"],
                                  q.batch_at(5, 0)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def tree_eq(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree_util.tree_leaves(a),
                   jax.tree_util.tree_leaves(b)))


def test_checkpoint_roundtrip_exact():
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7),
            "nested": {"m": [jnp.ones(3), jnp.zeros(2)]}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(7, tree)
        step, got = ck.restore(tree)
        assert step == 7
        assert tree_eq(tree, got)


def test_checkpoint_async_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in range(5):
            ck.save_async(s, {"x": jnp.full((4,), float(s))})
        ck.wait()
        files = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(files) == 2
        assert ck.latest_step() == 4
        _, got = ck.restore({"x": jnp.zeros(4)})
        assert float(got["x"][0]) == 4.0


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(0, {"x": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ck.restore({"x": jnp.zeros((3, 3))})
