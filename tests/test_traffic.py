"""Open-loop SLO serving: the trace-replay parity harness (acceptance).

The tentpole claim this file pins: admission decisions are a pure
function of (trace, config) — never of the execution substrate — so a
seeded open-loop trace replayed through the real JAX engine and through
the DES produces the *same* accept/shed sequence and the *same* bucketed
fusion groupings, across {fifo,wfq,edf} x {preempt} x {fuse_buckets}.
On top of that structural parity:

* seeded trace synthesis is deterministic and scale-stable (same seed at
  2x the rate = the identical sequence with time halved exactly), which
  makes "deadline-miss rate is monotone in offered load" a single-seed
  statistical assertion;
* bounded load shedding never exceeds its budget, and a shed launch's
  handle resolves *immediately* with `LaunchShed` instead of blocking to
  a wait timeout (the latent-bug regression);
* bucketed fusion pads near-identical shapes to power-of-2 buckets and
  de-muxes bitwise-exactly, with member counters summing back to the
  batch totals even for padded members;
* the 32-tenant >=1.2x-capacity acceptance scenario: EDF credit boosts +
  shedding beat plain preemptive WFQ on admitted p99 *and* miss rate.
"""
import argparse
import pathlib
import sys
import time

import numpy as np
import pytest

import jax

from repro.api import (CoexecSpec, add_spec_args, args_from_spec,
                       build_kernel, build_scheduler, kernel_demo_inputs,
                       spec_from_args)
from repro.core import (AdmissionConfig, Arrival, CoexecEngine,
                        ExecutionLoop, LaunchShed, LaunchSpec, MemoryModel,
                        SimUnit, Trace, Workload, capacity_items_per_s,
                        counits_from_devices, fusion_bucket,
                        replay_trace_lockstep, replay_trace_sim,
                        simulate_multi, synthesize_trace, tenant_rows)
from repro.core.admission import AdmissionFull
from repro.core.dataplane import as_coexec_kernel, make_plane
from repro.core.engine import RealBackend, _Launch, _fuse_key
from repro.core.memory import MemoryCosts
from repro.core.sim import SimBackend, _SimLaunchState

from _propcheck import given, settings, st

REPO = pathlib.Path(__file__).resolve().parent.parent
NUNITS = 2


def double_kernel(offset, chunk):
    return chunk * 2.0


# One kernel OBJECT shared by every lockstep launch: the engine's fusion
# key includes the kernel identity, so a fresh closure per launch would
# silently disable fusion (and the parity it is supposed to prove).
KERNEL = as_coexec_kernel(double_kernel, 1)


def real_units():
    return counits_from_devices(jax.local_devices()[:1] * NUNITS,
                                kinds=["cpu"] * NUNITS,
                                speed_hints=[0.5, 0.5])


def sim_units(speed=50_000.0):
    return [SimUnit(f"u{i}", "cpu", speed=speed, setup_s=1e-3)
            for i in range(NUNITS)]


# ---------------------------------------------------------------------------
# Trace synthesis: determinism, scale stability, serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arrival", ["poisson", "burst"])
def test_trace_synthesis_deterministic_and_scale_stable(arrival):
    """Same seed = same trace; same seed at 2x the rate = the identical
    arrival sequence with every timestamp exactly halved (unit-rate gaps
    divided by the phase rate — the property the monotone-load tests
    lean on)."""
    kw = dict(arrival=arrival, tenants=4, items=128, item_jitter=0.5,
              slo_ms=40.0, seed=9)
    a = synthesize_trace(200, 100.0, **kw)
    b = synthesize_trace(200, 100.0, **kw)
    assert a == b
    fast = synthesize_trace(200, 200.0, **kw)
    assert [x.tenant for x in fast.arrivals] == \
        [x.tenant for x in a.arrivals]
    assert [x.items for x in fast.arrivals] == \
        [x.items for x in a.arrivals]
    np.testing.assert_array_equal(
        np.array([x.t for x in fast.arrivals]),
        np.array([x.t for x in a.arrivals]) / 2.0)
    # Trace.scaled produces the same compression as re-synthesis
    assert [x.t for x in a.scaled(2.0).arrivals] == \
        [x.t for x in fast.arrivals]


def test_trace_json_round_trip_and_save_load(tmp_path):
    trace = synthesize_trace(50, 80.0, arrival="burst", tenants=3,
                             items=200, item_jitter=1.0, slo_ms=25.0,
                             seed=4)
    assert Trace.from_json(trace.to_json()) == trace
    path = tmp_path / "t.json"
    trace.save(path)
    assert Trace.load(path) == trace
    with pytest.raises(ValueError):
        Trace.from_dict({"version": 99, "arrivals": []})


def test_trace_synthesis_validates_arguments():
    for bad in (dict(arrivals=0), dict(rate=0.0),
                dict(arrival="uniform"),
                dict(arrival="burst", burst=0.5),
                dict(arrival="burst", burst_duty=1.5),
                dict(arrival="burst", burst=6.0, burst_duty=0.2),
                dict(mix=[1.0]), dict(tenant_weights=[2.0]),
                dict(tenants=0)):
        kw = dict(arrivals=10, rate=10.0, tenants=4)
        kw.update(bad)
        with pytest.raises(ValueError):
            synthesize_trace(kw.pop("arrivals"), kw.pop("rate"), **kw)


def test_committed_example_trace_replays():
    """The repo ships a replayable example trace; CI keeps it loadable
    and decision-complete under the full SLO stack."""
    trace = Trace.load(REPO / "benchmarks" / "traces" /
                       "example_trace.json")
    assert len(trace) == 64 and trace.offered_rate() > 0
    cfg = AdmissionConfig(policy="edf", preempt=True, shed=True,
                          shed_budget=0.5, slo_ms=40.0)
    rep = replay_trace_sim(trace, sim_units(speed=5000.0), admission=cfg)
    assert len(rep.decisions) == len(trace)
    assert len(rep.result.launches) + len(rep.result.shed) == len(trace)
    assert sum(r.arrivals for r in rep.rows) == len(trace)


# ---------------------------------------------------------------------------
# Tentpole: real-engine vs DES structural parity through lockstep replay
# ---------------------------------------------------------------------------

def lockstep_cfg(policy, preempt, fuse):
    # fuse_wait_s spans several mean inter-arrival gaps (25ms at 40/s)
    # so staged groups actually accumulate members between trace-timed
    # flush sweeps instead of ripening as singletons
    return AdmissionConfig(policy=policy, preempt=preempt, fuse=fuse,
                           fuse_buckets=fuse, fuse_threshold=1024,
                           fuse_wait_s=0.1, shed=True, shed_rate=2000.0,
                           shed_budget=0.5, slo_ms=50.0)


def lockstep_trace(arrivals=24, items=96, seed=3):
    # ~2x the shed estimator's 2000 items/s capacity: a real mix of
    # accepts and sheds, with jitter so bucketing actually buckets
    return synthesize_trace(arrivals, 40.0, tenants=4, items=items,
                            item_jitter=0.8, slo_ms=50.0, seed=seed)


def run_lockstep_real(trace, cfg):
    units = real_units()
    backend = RealBackend(units, make_plane(MemoryModel.USM))
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)
    datas = {}

    def make_launch(a, lp):
        sched = build_scheduler("dyn8", a.items, NUNITS)
        d = np.random.default_rng(a.items).normal(
            size=a.items).astype(np.float32)
        out = np.zeros(a.items, np.float32)
        launch = _Launch(lp.next_id(), sched, KERNEL, [d], out,
                         adaptive=False)
        launch.plan = backend.plane.plan(KERNEL, [d], out, a.items)
        launch.tenant = a.tenant
        launch.weight = a.weight
        launch.fuse_key = _fuse_key(cfg, sched, KERNEL, [d], out)
        if launch.fuse_key is not None and cfg.fuse_buckets:
            launch.fuse_bucket = fusion_bucket(a.items)
        datas[launch.id] = d
        return launch

    admitted, shed = replay_trace_lockstep(trace, loop, make_launch)
    return loop, admitted, shed, datas


def run_lockstep_sim(trace, cfg, depth=1):
    units = sim_units(speed=1000.0)
    backend = SimBackend(units, MemoryModel.USM, MemoryCosts(),
                         pipeline_depth=depth)
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)

    def make_launch(a, lp):
        entry = _SimLaunchState(
            lp.next_id(), build_scheduler("dyn8", a.items, NUNITS),
            Workload("traffic", a.items, 8.0, 8.0, 1e4), tenant=a.tenant,
            weight=a.weight)
        if cfg.fuse and a.items <= cfg.fuse_threshold:
            if cfg.fuse_buckets:
                entry.fuse_key = ("traffic", "bucket",
                                  fusion_bucket(a.items), 8.0, 8.0)
                entry.fuse_bucket = fusion_bucket(a.items)
            else:
                entry.fuse_key = ("traffic", a.items, 8.0, 8.0)
        return entry

    admitted, shed = replay_trace_lockstep(trace, loop, make_launch)
    return loop, admitted, shed


@pytest.mark.parametrize("policy", ["fifo", "wfq", "edf"])
@pytest.mark.parametrize("preempt", [False, True])
@pytest.mark.parametrize("fuse", [False, True])
@pytest.mark.parametrize("depth", [1, 2])
def test_lockstep_parity_real_vs_sim(policy, preempt, fuse, depth):
    """Acceptance (structure): identical trace + config + serve order =
    identical accept/shed decision log and identical fusion groupings on
    the real engine and the DES — and the real results stay exact.
    ``pipeline_depth`` is part of the matrix: the DES models pipelining
    as a recorded-timeline overlay on a serial decision clock, so depth
    must never perturb structural parity."""
    cfg = lockstep_cfg(policy, preempt, fuse)
    trace = lockstep_trace()

    real_loop, real_adm, real_shed, datas = run_lockstep_real(trace, cfg)
    sim_loop, sim_adm, sim_shed = run_lockstep_sim(trace, cfg, depth=depth)

    assert real_loop.admission.decision_log == \
        sim_loop.admission.decision_log
    assert real_loop.admission.fusion_log == sim_loop.admission.fusion_log
    assert len(real_shed) == len(sim_shed) > 0
    assert len(real_adm) == len(sim_adm) > 0
    assert real_loop.admission.fused_batches == \
        sim_loop.admission.fused_batches
    if fuse:
        assert real_loop.admission.fused_batches > 0
    # bucketed fusion de-muxes every admitted launch bitwise-exactly
    for launch in real_adm:
        np.testing.assert_array_equal(launch.handle.result(timeout=5),
                                      datas[launch.id] * 2.0)


def test_lockstep_1k_arrival_accept_shed_sequence():
    """Acceptance (scale): a 1k-arrival trace reproduces the DES event
    pump's accept/shed sequence on the real backend, launch for launch."""
    cfg = AdmissionConfig(policy="edf", preempt=True, shed=True,
                          shed_rate=2000.0, shed_budget=0.5, slo_ms=40.0)
    trace = synthesize_trace(1000, 50.0, tenants=8, items=64, seed=17,
                             slo_ms=40.0)

    real_loop, real_adm, real_shed, _ = run_lockstep_real(trace, cfg)
    sim = replay_trace_sim(trace, sim_units(speed=1000.0), admission=cfg)

    assert real_loop.admission.decision_log == sim.decisions
    assert len(real_shed) == len(sim.result.shed) > 50
    assert len(real_adm) == len(sim.result.launches)
    # shed records carry the same tenants, in the same order
    assert [t for v, t in sim.decisions if v == "shed"] == \
        [s.tenant for s in sim.result.shed]


# ---------------------------------------------------------------------------
# Statistical harness: monotone miss rate, bounded shedding
# ---------------------------------------------------------------------------

def test_miss_rate_monotone_in_offered_load():
    """Scale-stable synthesis makes this a one-seed assertion: the same
    arrival sequence offered faster can only miss more deadlines."""
    units = sim_units(speed=5000.0)
    cap = capacity_items_per_s(units)
    misses = []
    for load in (0.6, 1.2, 1.8):
        trace = synthesize_trace(300, load * cap / 256, tenants=8,
                                 items=256, slo_ms=120.0, seed=21)
        rep = replay_trace_sim(
            trace, units,
            admission=AdmissionConfig(policy="wfq", preempt=True,
                                      slo_ms=120.0))
        misses.append(rep.miss_rate())
    assert misses == sorted(misses)
    assert misses[-1] > misses[0]


@settings(max_examples=10, deadline=None)
@given(case=st.fixed_dictionaries({
    "policy": st.sampled_from(["fifo", "wfq", "edf"]),
    "preempt": st.booleans(),
    "budget": st.floats(min_value=0.0, max_value=0.9),
    "load": st.floats(min_value=0.5, max_value=2.5),
    "seed": st.integers(min_value=0, max_value=999),
}))
def test_shed_within_budget_and_reproducible(case):
    """Property: for any (policy, preempt, budget, load, seed), replay
    decisions are reproducible, one per arrival in arrival order, and
    the shed fraction never exceeds the configured budget."""
    units = sim_units(speed=5000.0)
    cap = capacity_items_per_s(units)
    trace = synthesize_trace(80, case["load"] * cap / 128, tenants=4,
                             items=128, slo_ms=30.0, seed=case["seed"])
    cfg = AdmissionConfig(policy=case["policy"], preempt=case["preempt"],
                          shed=True, shed_budget=case["budget"],
                          shed_rate=0.8 * cap, slo_ms=30.0)
    a = replay_trace_sim(trace, units, admission=cfg)
    b = replay_trace_sim(trace, units, admission=cfg)
    assert a.decisions == b.decisions
    assert len(a.decisions) == len(trace)
    assert [t for _, t in a.decisions] == [x.tenant for x in trace.arrivals]
    assert a.shed_fraction() <= case["budget"] + 1e-9


# ---------------------------------------------------------------------------
# Acceptance scenario: 32 tenants at >=1.2x capacity on the DES
# ---------------------------------------------------------------------------

def test_edf_shed_beats_wfq_at_32_tenants_overload():
    """Acceptance: at 32 tenants under 1.2x modeled capacity, EDF credit
    boosts + bounded shedding improve admitted-launch p99 latency and
    deadline-miss rate over plain preemptive WFQ (the benchmarked claim
    in BENCH_traffic.json, asserted here with wide margins)."""
    units = sim_units()                       # 2 x 50k items/s
    cap = capacity_items_per_s(units)
    trace = synthesize_trace(1200, 1.2 * cap / 512, tenants=32,
                             items=512, slo_ms=80.0, seed=11)
    wfq = replay_trace_sim(
        trace, units,
        admission=AdmissionConfig(policy="wfq", preempt=True, slo_ms=80.0))
    edf = replay_trace_sim(
        trace, units,
        admission=AdmissionConfig(policy="edf", preempt=True, shed=True,
                                  shed_budget=0.5, shed_rate=0.8 * cap,
                                  slo_ms=80.0))
    assert wfq.shed_fraction() == 0.0
    assert 0.0 < edf.shed_fraction() <= 0.5
    assert edf.p99_ms() < 0.5 * wfq.p99_ms()
    assert edf.miss_rate() < wfq.miss_rate() - 0.2
    # per-tenant rows fold the same replay without losing arrivals
    rows = tenant_rows(trace, edf.result)
    assert len(rows) == 32
    assert sum(r.arrivals for r in rows) == len(trace)
    assert sum(r.admitted for r in rows) == len(edf.result.launches)
    assert sum(r.shed for r in rows) == len(edf.result.shed)


def test_edf_serves_urgent_deadlines_first():
    """EDF's boosted credit orders service by deadline: tight-SLO
    launches finish measurably earlier than loose-SLO peers, where plain
    WFQ interleaves them evenly."""
    def latencies(policy):
        specs = []
        for i in range(8):
            tight = i % 2 == 0
            specs.append(LaunchSpec(
                Workload("uni", 512, 8.0, 8.0, 1e4),
                build_scheduler("dyn8", 512, NUNITS),
                tenant=f"{'tight' if tight else 'loose'}{i}",
                deadline_s=0.02 if tight else 100.0))
        res = simulate_multi(
            specs, sim_units(speed=1000.0),
            admission=AdmissionConfig(policy=policy, preempt=True))
        lat = {"tight": [], "loose": []}
        for r in res.launches:
            lat[r.tenant.rstrip("0123456789")].append(r.latency_s)
        return (float(np.mean(lat["tight"])),
                float(np.mean(lat["loose"])))

    edf_tight, edf_loose = latencies("edf")
    wfq_tight, wfq_loose = latencies("wfq")
    assert edf_tight < 0.8 * edf_loose
    assert edf_tight < wfq_tight
    assert abs(wfq_tight - wfq_loose) < 0.2 * max(wfq_tight, wfq_loose)


# ---------------------------------------------------------------------------
# Bucketed fusion: eligibility, grouping, bitwise de-mux, exact counters
# ---------------------------------------------------------------------------

def test_fusion_bucket_helper():
    assert [fusion_bucket(n) for n in (1, 2, 3, 100, 128, 129, 230)] == \
        [1, 2, 4, 128, 128, 256, 256]


def test_bucket_fuse_key_eligibility_per_kernel():
    """Only all-split kernels bucket-fuse: broadcast operands (matmul,
    ray) and halos (gaussian) cannot stack along a member axis."""
    cfg = AdmissionConfig(fuse=True, fuse_buckets=True,
                          fuse_threshold=1024)
    keys = {}
    for name in ("taylor", "mandelbrot", "rap", "gaussian", "matmul",
                 "ray"):
        kernel = build_kernel(name)
        inputs = kernel.bind(kernel_demo_inputs(name, 100, seed=1))
        sched = build_scheduler("dyn8", 100, NUNITS)
        out = kernel.alloc_out(100, inputs)
        keys[name] = _fuse_key(cfg, sched, kernel, inputs, out)
    for name in ("taylor", "mandelbrot", "rap"):
        assert keys[name] is not None and "bucket" in keys[name], name
    for name in ("gaussian", "matmul", "ray"):
        assert keys[name] is None, name
    # near-identical sizes share a bucket key; distant sizes do not
    kernel = build_kernel("taylor")
    def key_for(n):
        inputs = kernel.bind(kernel_demo_inputs("taylor", n, seed=1))
        return _fuse_key(cfg, build_scheduler("dyn8", n, NUNITS), kernel,
                         inputs, kernel.alloc_out(n, inputs))
    assert key_for(100) == key_for(120)
    assert key_for(100) != key_for(200)


def fused_spec():
    return CoexecSpec(
        admission=CoexecSpec().admission.replace(
            fuse=True, fuse_buckets=True, fuse_threshold=1024,
            fuse_wait_s=0.5))


@pytest.mark.parametrize("name", ["taylor", "mandelbrot", "rap"])
def test_bucket_fusion_demux_exact_per_kernel(name):
    """Mixed-size launches of one registered kernel coalesce into
    power-of-2 buckets on the real engine and de-mux to each member's
    exact extent — with padded members' counters still summing back to
    the batch totals. Values are held to 1 ulp of the whole-array call
    (XLA contracts FMAs differently per compiled shape, so padded-bucket
    execution is not bitwise against an unpadded reference; the bitwise
    de-mux guarantee itself is pinned by the shape-insensitive kernel
    below and by the lockstep parity tests)."""
    sizes = (100, 120, 200, 230)
    kernel = build_kernel(name)
    cases = []
    with CoexecEngine(real_units(), spec=fused_spec()) as engine:
        handles = []
        for i, n in enumerate(sizes):
            inputs = kernel.bind(kernel_demo_inputs(name, n, seed=30 + i))
            cases.append((n, inputs))
            handles.append(engine.submit(
                build_scheduler("dyn8", n, NUNITS), kernel, inputs,
                kernel.alloc_out(n, inputs)))
        for h, (n, inputs) in zip(handles, cases):
            expected = np.asarray(kernel.fn(0, *inputs))
            got = h.result(timeout=120)
            assert got.shape == expected.shape and got.shape[0] == n
            np.testing.assert_allclose(got, expected, rtol=3e-7,
                                       atol=3e-7)
        # two buckets (128 and 256), every launch served fused
        assert engine.admission.fused_batches == 2
        assert engine.admission.fused_members == 4
        dispatched = engine.admission.dispatched
    assert sum(h.stats.data.dispatches for h in handles) == dispatched


def test_bucket_fusion_demux_bitwise_vs_unfused():
    """The de-mux itself is bitwise: for a kernel whose values cannot
    vary with compiled shape (x * 2.0 is exact in FP), a bucketed-fused
    run reproduces the unfused run bit for bit — padding never leaks
    into any member's committed output."""
    sizes = (100, 120, 200, 230)
    datas = [np.random.default_rng(50 + i).normal(size=n)
             .astype(np.float32) for i, n in enumerate(sizes)]

    def run(spec):
        with CoexecEngine(real_units(), spec=spec) as engine:
            handles = [engine.submit(
                build_scheduler("dyn8", len(d), NUNITS), KERNEL, [d],
                np.zeros(len(d), np.float32)) for d in datas]
            outs = [h.result(timeout=120).copy() for h in handles]
        return outs, engine.admission.fused_batches

    fused, batches = run(fused_spec())
    plain, none = run(CoexecSpec(
        admission=CoexecSpec().admission.replace(fuse=False)))
    assert batches == 2 and none == 0
    for f, p, d in zip(fused, plain, datas):
        np.testing.assert_array_equal(f, p)
        np.testing.assert_array_equal(f, d * 2.0)


def test_mixed_shape_trace_fuses_into_bucket_count_batches():
    """A simultaneous mixed-shape burst fuses into exactly one batch per
    occupied bucket on the DES, grouped by bucket."""
    sizes = [100, 120, 90, 110, 200, 230, 220, 210]
    arrivals = tuple(Arrival(t=0.0, tenant=f"b{fusion_bucket(n)}.{i}",
                             items=n)
                     for i, n in enumerate(sizes))
    trace = Trace(arrivals)
    cfg = AdmissionConfig(fuse=True, fuse_buckets=True,
                          fuse_threshold=1024, fuse_wait_s=0.0)
    rep = replay_trace_sim(trace, sim_units(speed=1000.0), admission=cfg)
    assert rep.result.fused_batches == 2
    assert rep.result.fused_members == 8
    assert sorted(len(g) for g in rep.fusion_groups) == [4, 4]
    for group in rep.fusion_groups:
        buckets = {t.split(".")[0] for t in group}
        assert len(buckets) == 1, group


# ---------------------------------------------------------------------------
# LaunchShed regression: shed handles resolve immediately
# ---------------------------------------------------------------------------

def test_shed_launch_raises_immediately_not_wait_timeout():
    """Latent-bug regression: a shed launch's handle carries a pre-set
    LaunchShed, so result(timeout=...) raises at once instead of
    blocking until the wait times out — on the blocking and the
    non-blocking submit paths alike."""
    T = 1024
    spec = (CoexecSpec.builder()
            .admission("edf")
            .slo(50.0, shed=True, shed_budget=1.0, shed_rate=10.0)
            .build())
    data = np.ones(T, np.float32)
    with CoexecEngine(real_units(), spec=spec) as engine:
        # generous deadline: admitted (and must still complete normally)
        ok = engine.submit(build_scheduler("dyn8", T, NUNITS),
                           double_kernel, [data],
                           np.zeros(T, np.float32), deadline_s=10_000.0)
        t0 = time.monotonic()
        shed_blocking = engine.submit(
            build_scheduler("dyn8", T, NUNITS), double_kernel, [data],
            np.zeros(T, np.float32), deadline_s=0.05)
        with pytest.raises(LaunchShed):
            shed_blocking.result(timeout=30)
        shed_nonblocking = engine.submit(
            build_scheduler("dyn8", T, NUNITS), double_kernel, [data],
            np.zeros(T, np.float32), deadline_s=0.05, block=False)
        with pytest.raises(LaunchShed):
            shed_nonblocking.result(timeout=30)
        elapsed = time.monotonic() - t0
        assert elapsed < 5.0, (
            f"shed handles took {elapsed:.1f}s to resolve — they must "
            f"raise immediately, not block to the wait timeout")
        np.testing.assert_allclose(ok.result(timeout=120), data * 2.0)
        assert engine.admission.shed_count == 2
    assert issubclass(LaunchShed, AdmissionFull)


# ---------------------------------------------------------------------------
# Surface: TrafficSpec/CLI round trips, serve rows, artifact schema
# ---------------------------------------------------------------------------

def test_traffic_spec_cli_round_trip():
    """TrafficSpec and the SLO admission fields ride the derived-flag
    machinery: both CLIs grow the flags with no per-tool edits, and the
    spec round-trips through JSON and argv."""
    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    ns = ap.parse_args(["--arrival", "burst", "--rate", "12",
                        "--burst", "3", "--burst-duty", "0.25",
                        "--traffic-seed", "5", "--slo-ms", "80",
                        "--shed", "--shed-budget", "0.4",
                        "--fuse", "--fuse-buckets"])
    spec = spec_from_args(ns).validate()
    assert spec.traffic.arrival == "burst"
    assert spec.traffic.rate == 12.0 and spec.traffic.seed == 5
    assert spec.traffic.burst == 3.0 and spec.traffic.burst_duty == 0.25
    assert spec.admission.slo_ms == 80.0 and spec.admission.shed
    assert spec.admission.shed_budget == 0.4
    assert spec.admission.fuse_buckets
    assert CoexecSpec.from_json(spec.to_json()) == spec
    argv = args_from_spec(spec)
    assert "--arrival" in argv and "--shed" in argv
    assert "--fuse-buckets" in argv
    cfg = spec.admission_config()
    assert cfg.slo_ms == 80.0 and cfg.shed and cfg.fuse_buckets

    for bad_traffic in (dict(arrival="closed-loop"), dict(rate=-1.0),
                        dict(load=0.0), dict(arrivals=0),
                        dict(burst=0.5), dict(burst_duty=1.5),
                        dict(burst=8.0, burst_duty=0.2),
                        dict(item_jitter=-0.1)):
        with pytest.raises(ValueError):
            spec.replace(
                traffic=spec.traffic.replace(**bad_traffic)).validate()


def test_traffic_builder_shortcuts():
    spec = (CoexecSpec.builder()
            .slo(60.0, shed=True, shed_budget=0.3, edf_boost=2.0)
            .traffic("poisson", rate=7.0, arrivals=128)
            .build())
    assert spec.admission.slo_ms == 60.0
    assert spec.admission.shed and spec.admission.shed_budget == 0.3
    assert spec.admission.edf_boost == 2.0
    assert spec.traffic.arrival == "poisson"
    assert spec.traffic.rate == 7.0 and spec.traffic.arrivals == 128


def small_traffic_spec():
    from repro.launch.serve import default_serve_spec

    base = default_serve_spec()
    return base.replace(
        workload=base.workload.replace(name="taylor", tenants=4,
                                       items=4096),
        admission=base.admission.replace(slo_ms=50.0),
        traffic=base.traffic.replace(arrival="poisson", arrivals=40,
                                     load=1.2, seed=2))


def test_traffic_rows_and_bench_artifact_schema():
    """serve's traffic sweep rows satisfy the committed artifact schema
    the docs job enforces (same checker code, no subprocess)."""
    from repro.launch.serve import traffic_rows

    sys.path.insert(0, str(REPO / "scripts"))
    try:
        import check_bench_schema as cbs
    finally:
        sys.path.pop(0)

    spec = small_traffic_spec()
    rows = traffic_rows(spec, admissions=(
        {"policy": "wfq", "preempt": True},
        {"policy": "edf", "preempt": True, "shed": True}))
    assert len(rows) == 2
    doc = {"schema_version": cbs.SCHEMA_VERSION, "suite": "traffic",
           "spec": spec.to_dict(), "rows": rows}
    assert cbs.check_doc("BENCH_traffic.json", doc) == []
    for row in rows:
        assert row["arrivals"] == 40
        assert row["admitted"] + row["shed_count"] == row["arrivals"]
    bad = dict(doc, rows=[{k: v for k, v in rows[0].items()
                           if k != "miss_rate"}])
    assert any("miss_rate" in e for e in cbs.check_doc("b.json", bad))


def test_serve_traffic_prints_per_tenant_columns(capsys):
    """`serve --arrival poisson` routes to the open-loop path and prints
    the aggregate row plus one per-tenant p50/p99/miss/shed row."""
    from repro.launch.serve import serve_coexec_sim

    serve_coexec_sim(small_traffic_spec())
    out = capsys.readouterr().out
    assert "[serve/traffic]" in out
    assert "p99=" in out and "miss=" in out and "shed" in out
    for tenant in ("t0", "t1", "t2", "t3"):
        assert tenant in out


def test_trace_from_spec_loads_committed_trace():
    from repro.launch.serve import trace_from_spec

    path = REPO / "benchmarks" / "traces" / "example_trace.json"
    spec = small_traffic_spec()
    spec = spec.replace(traffic=spec.traffic.replace(trace=str(path)))
    trace = trace_from_spec(spec, 10_000.0)
    assert trace == Trace.load(path)
