"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from repro.kernels import (demo_spheres, flash_attention, gaussian_blur,
                           linear_attention, mandelbrot, matmul, rap,
                           raytrace, ref, taylor_sin)

rng = np.random.default_rng(42)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (300, 200, 260),
                                   (128, 512, 128), (37, 129, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul(m, k, n, dtype):
    a = jnp.asarray(rng.normal(size=(m, k)), dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype)
    got = matmul(a, b, bm=128, bn=128, bk=128)
    want = ref.matmul(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert got.dtype == want.dtype
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("h,w,bm", [(64, 128, 16), (200, 256, 64),
                                    (33, 130, 128)])
def test_gaussian(h, w, bm):
    img = jnp.asarray(rng.normal(size=(h, w)), jnp.float32)
    assert_allclose(gaussian_blur(img, bm=bm), ref.gaussian_blur(img),
                    rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,terms", [(100, 8), (1000, 12), (4096, 16)])
def test_taylor(n, terms):
    x = jnp.asarray(rng.uniform(-3, 3, size=(n,)), jnp.float32)
    assert_allclose(taylor_sin(x, terms=terms, bm=4),
                    ref.taylor_sin(x, terms=terms), rtol=1e-5, atol=1e-6)
    if terms >= 12:
        assert_allclose(taylor_sin(x, terms=terms), np.sin(x),
                        rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("side,it", [(32, 32), (64, 48)])
def test_mandelbrot(side, it):
    re_ = np.linspace(-2.2, 0.8, side, dtype=np.float32)
    im = np.linspace(-1.4, 1.4, side, dtype=np.float32)
    cre, cim = [jnp.asarray(g) for g in np.meshgrid(re_, im)]
    got = mandelbrot(cre, cim, max_iter=it, bm=8)
    want = ref.mandelbrot(cre, cim, max_iter=it)
    assert_allclose(got, want, atol=0)


@pytest.mark.parametrize("n,spheres", [(1000, 4), (4000, 8)])
def test_raytrace(n, spheres):
    dx, dy = rng.uniform(-.4, .4, (2, n)).astype(np.float32)
    dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, .5)).astype(np.float32)
    sph = demo_spheres(spheres)
    got = raytrace(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   sph, bm=8)
    want = ref.raytrace(jnp.asarray(dx), jnp.asarray(dy),
                        jnp.asarray(dz), sph)
    assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("n,l", [(100, 32), (500, 96)])
def test_rap(n, l):
    vals = jnp.asarray(rng.normal(size=(n, l)), jnp.float32)
    lens = jnp.asarray(rng.integers(0, l + 1, size=(n,)), jnp.int32)
    assert_allclose(rap(vals, lens, bm=64), ref.rap(vals, lens),
                    rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                           (False, None)])
def test_flash_attention(hq, hkv, causal, window):
    B, T, D = 2, 128, 64
    q = jnp.asarray(rng.normal(size=(B, hq, T, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, hkv, T, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, hkv, T, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64)
    want = ref.attention(q, k, v, causal=causal, window=window)
    assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,chunk", [(64, 64), (200, 64), (256, 128)])
@pytest.mark.parametrize("dk,dv", [(16, 16), (32, 48)])
def test_linear_attention(t, chunk, dk, dv):
    BH = 3
    q = jnp.asarray(rng.normal(size=(BH, t, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, t, dk)) * 0.2, jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, t, dv)), jnp.float32)
    ld = jnp.asarray(-np.abs(rng.normal(size=(BH, t)) * 0.1), jnp.float32)
    got = linear_attention(q, k, v, ld, chunk=chunk)
    want = ref.linear_attention(q, k, v, ld)
    assert_allclose(got, want, rtol=3e-4, atol=3e-4)
    # chunked jnp twin (training path) matches too
    got2 = ref.chunked_linear_attention(q, k, v, ld, chunk=chunk)
    assert_allclose(got2, want, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    B, T, D = 1, 128, 128
    q = jnp.asarray(rng.normal(size=(B, 4, T, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, 2, T, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, 2, T, D)), jnp.bfloat16)
    got = flash_attention(q, k, v)
    want = ref.attention(q, k, v)
    assert got.dtype == jnp.bfloat16
    assert_allclose(np.asarray(got, np.float32),
                    np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)
