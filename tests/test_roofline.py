"""Roofline machinery: HLO collective parser, xscan multipliers, analytic
FLOPs sanity."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.roofline import (PEAK_FLOPS, cell_flops, collective_bytes,
                            forward_flops_per_token)
from repro.xscan import xscan

HLO_SAMPLE = """
  %ar = f32[16,1024]{1,0} all-reduce(%x), metadata={op_name="jit(f)/foo"}
  %ag.1 = bf16[8,256]{1,0} all-gather-start(%y), metadata={op_name="jit(f)/layers.xscan[28]/while/body/bar"}
  %rs = (f32[4,4]{1,0}, f32[4,4]{1,0}) reduce-scatter(%a, %b), metadata={op_name="jit(f)/t"}
  %aa = f32[2,2]{1,0} all-to-all(%c), metadata={op_name="jit(f)/layers.xscan[4]/while/body/attn.xscan[8]/while/body/q"}
  %done = f32[16,1024]{1,0} all-reduce-done(%ar)
"""


def test_collective_parser_kinds_and_multipliers():
    got = collective_bytes(HLO_SAMPLE)
    assert got["all-reduce"] == 16 * 1024 * 4            # -done skipped
    assert got["all-gather"] == 8 * 256 * 2 * 28         # xscan x28
    assert got["reduce-scatter"] == 2 * 16 * 4           # tuple summed
    assert got["all-to-all"] == 4 * 4 * (4 * 8)          # nested scans


def test_xscan_tag_appears_in_hlo():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        c, _ = xscan(body, x, ws, name="lyr")
        return c.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    assert "lyr.xscan[7]" in txt


def test_analytic_flops_train_factor():
    """Remat'd train step = 4x the forward pass at the same shape."""
    cfg = get_config("qwen3-0.6b")
    t1 = cell_flops(cfg, SHAPES["train_4k"])["total_flops"]
    fwd = 256 * 4096 * forward_flops_per_token(cfg, 4096)
    assert t1 / fwd == pytest.approx(4.0, rel=0.01)
    # prefill spends more FLOPs per token (longer attended context)
    pref = cell_flops(cfg, SHAPES["prefill_32k"])["total_flops"]
    assert pref / (32 * 32768) > fwd / (256 * 4096)


def test_analytic_flops_close_to_6nd():
    """For dense models at moderate seq, layer flops/token ≈ 6·N_layer."""
    cfg = get_config("qwen1.5-110b")
    fwd = forward_flops_per_token(cfg, 4096)
    n = cfg.n_params()
    # fwd ≈ 2·N + attention term; ratio in [2, 3.2]
    assert 1.8 <= fwd / n <= 3.2


def test_moe_flops_use_active_params():
    moe = get_config("qwen3-moe-235b-a22b")
    fwd = forward_flops_per_token(moe, 4096)
    n_active = moe.n_active_params()
    n_total = moe.n_params()
    assert fwd < 0.15 * 2 * n_total         # nowhere near dense compute
    assert fwd == pytest.approx(2 * n_active, rel=0.5)


def test_decode_flops_much_smaller():
    cfg = get_config("h2o-danube3-4b")
    dec = cell_flops(cfg, SHAPES["decode_32k"])["total_flops"]
    pref = cell_flops(cfg, SHAPES["prefill_32k"])["total_flops"]
    assert dec < pref / 1000


def test_roofline_terms_positive():
    from repro.roofline import Roofline
    r = Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                 flops_per_dev=1e15, bytes_per_dev=1e9,
                 coll_bytes_per_dev=1e9, coll_breakdown={},
                 model_flops=2e17)
    assert r.t_compute == pytest.approx(1e15 / PEAK_FLOPS)
    assert r.bottleneck == "compute"
    assert 0 < r.roofline_frac <= 1.0
