"""Persistent CoexecEngine: lifecycle, concurrency, per-launch isolation."""
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import CoexecSpec, build_scheduler
from repro.core import (CoexecEngine, CoexecutorRuntime, counits_from_devices,
                        validate_cover)

N = 1 << 13
POLICIES = ["static", "dyn16", "hguided", "work_stealing"]


def two_units():
    devs = jax.local_devices()[:1] * 2
    return counits_from_devices(devs, kinds=["cpu", "cpu"],
                                speed_hints=[0.4, 0.6])


def sched_for(policy, total, num_units=2, granularity=1):
    kw = {}
    if policy in ("static", "hguided", "work_stealing"):
        kw["speeds"] = [0.4, 0.6][:num_units]
    return build_scheduler(policy, total, num_units,
                          granularity=granularity, **kw)


def affine_kernel(offset, chunk):
    idx = jnp.arange(chunk.shape[0], dtype=jnp.float32) + offset
    return chunk * 2.0 + idx


def expected(data):
    return data * 2.0 + np.arange(len(data), dtype=np.float32)


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------

def test_engine_start_submit_shutdown():
    engine = CoexecEngine(two_units())
    assert not engine.running
    engine.start()
    assert engine.running
    data = np.arange(N, dtype=np.float32)
    out = np.zeros(N, np.float32)
    h = engine.submit(sched_for("dyn16", N), affine_kernel, [data], out)
    got = h.result(timeout=60)
    assert got is out
    np.testing.assert_allclose(got, expected(data))
    engine.shutdown()
    assert not engine.running
    with pytest.raises(RuntimeError):
        engine.submit(sched_for("dyn16", N), affine_kernel, [data], out)
    with pytest.raises(RuntimeError):
        engine.start()          # a shut-down engine cannot be revived


def test_engine_requires_start():
    engine = CoexecEngine(two_units())
    with pytest.raises(RuntimeError):
        engine.submit(sched_for("dyn16", N), affine_kernel,
                      [np.zeros(N, np.float32)], np.zeros(N, np.float32))


def test_engine_context_manager_drains():
    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        handles = [engine.submit(sched_for("dyn16", N), affine_kernel,
                                 [data], np.zeros(N, np.float32))
                   for _ in range(3)]
    # __exit__ drains all in-flight launches before joining workers
    for h in handles:
        assert h.done()
        np.testing.assert_allclose(h.result(), expected(data))


def test_engine_rejects_reused_scheduler():
    """A drained scheduler hands out no packages, so its launch could
    never complete (and would wedge shutdown): submit must reject it."""
    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        sched = sched_for("dyn4", N)
        engine.submit(sched, affine_kernel, [data],
                      np.zeros(N, np.float32)).result(timeout=60)
        with pytest.raises(ValueError, match="already issued"):
            engine.submit(sched, affine_kernel, [data],
                          np.zeros(N, np.float32))
    # the context manager exits promptly — no wedged drain


def test_engine_rejects_mismatched_scheduler():
    with CoexecEngine(two_units()) as engine:
        with pytest.raises(ValueError):
            engine.submit(sched_for("dyn16", N, num_units=3), affine_kernel,
                          [np.zeros(N, np.float32)], np.zeros(N, np.float32))


def test_engine_threads_persist_across_launches():
    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        before = threading.active_count()
        for _ in range(4):
            out = engine.submit(sched_for("hguided", N), affine_kernel,
                                [data], np.zeros(N, np.float32)).result()
            np.testing.assert_allclose(out, expected(data))
        # no per-launch thread spawn: worker count is constant
        assert threading.active_count() == before


# ---------------------------------------------------------------------------
# concurrency & isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
def test_concurrent_launches_match_sequential_bitwise(policy):
    """N concurrent launch_async calls produce bitwise-identical outputs
    to N sequential launches (the acceptance-criterion invariant)."""
    datas = [np.random.default_rng(i).normal(size=N).astype(np.float32)
             for i in range(8)]
    with CoexecEngine(two_units()) as engine:
        seq = []
        for d in datas:
            seq.append(engine.submit(sched_for(policy, N), affine_kernel,
                                     [d], np.zeros(N, np.float32)).result())
        handles = [engine.submit(sched_for(policy, N), affine_kernel,
                                 [d], np.zeros(N, np.float32))
                   for d in datas]
        conc = [h.result(timeout=120) for h in handles]
    for s, c in zip(seq, conc):
        assert np.array_equal(s, c)          # bitwise, not approx


def test_eight_concurrent_launches_two_units_exact_cover():
    """Acceptance: 8 concurrent launches on a 2-unit engine all complete
    with exact index-space cover and per-launch isolated stats."""
    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        handles = [engine.submit(sched_for("work_stealing", N),
                                 affine_kernel, [data],
                                 np.zeros(N, np.float32))
                   for _ in range(8)]
        outs = [h.result(timeout=120) for h in handles]
    want = expected(data)
    for h, o in zip(handles, outs):
        np.testing.assert_allclose(o, want)
        assert h.stats is not None
        validate_cover(h.stats.packages, N)
        assert sum(p.size for p in h.stats.packages) == N
        # busy seconds derive from this launch's packages only
        assert sum(h.stats.unit_busy_s.values()) > 0


def test_mixed_policies_interleave():
    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        handles = [engine.submit(sched_for(p, N), affine_kernel, [data],
                                 np.zeros(N, np.float32))
                   for p in POLICIES * 2]
        for h in handles:
            np.testing.assert_allclose(h.result(timeout=120), expected(data))
            validate_cover(h.stats.packages, N)


def test_failing_launch_does_not_poison_neighbors():
    def bad_kernel(offset, chunk):
        raise RuntimeError("boom")

    data = np.arange(N, dtype=np.float32)
    with CoexecEngine(two_units()) as engine:
        good1 = engine.submit(sched_for("dyn16", N), affine_kernel, [data],
                              np.zeros(N, np.float32))
        bad = engine.submit(sched_for("dyn16", N), bad_kernel, [data],
                            np.zeros(N, np.float32))
        good2 = engine.submit(sched_for("dyn16", N), affine_kernel, [data],
                              np.zeros(N, np.float32))
        with pytest.raises(RuntimeError, match="boom"):
            bad.result(timeout=120)
        np.testing.assert_allclose(good1.result(timeout=120), expected(data))
        np.testing.assert_allclose(good2.result(timeout=120), expected(data))


# ---------------------------------------------------------------------------
# runtime integration
# ---------------------------------------------------------------------------

def test_runtime_launch_async_and_blocking_agree():
    data = np.random.default_rng(0).normal(size=N).astype(np.float32)
    spec = CoexecSpec.builder().policy("work_stealing").dist(0.4).build()
    with CoexecutorRuntime.from_spec(spec, units=two_units()) as rt:
        blocking = rt.launch(N, affine_kernel, [data]).copy()
        handles = [rt.launch_async(N, affine_kernel, [data])
                   for _ in range(4)]
        for h in handles:
            assert np.array_equal(h.result(timeout=120), blocking)
            # per-launch stats isolation: each handle has its own
            assert h.stats is not None and h.stats.num_packages >= 2
    assert rt.engine is None             # context exit shut the engine down


def test_runtime_reuses_engine_across_launches():
    spec = CoexecSpec.builder().policy("dyn8").build()
    with CoexecutorRuntime.from_spec(spec, units=two_units()) as rt:
        rt.launch(N, affine_kernel, [np.zeros(N, np.float32)])
        engine = rt.engine
        rt.launch(N, affine_kernel, [np.zeros(N, np.float32)])
        assert rt.engine is engine       # persistent, not per-launch
        rt.configure(spec, units=two_units())   # reconfigure invalidates
        assert rt.engine is None
        rt.launch(N, affine_kernel, [np.zeros(N, np.float32)])
        assert rt.engine is not engine


# ---------------------------------------------------------------------------
# Director facade: error surfacing and teardown hygiene
# ---------------------------------------------------------------------------

def test_director_surfaces_unexpected_kernel_exception():
    """A kernel bug must raise out of `launch`, not vanish — and the
    Director must stay serviceable for the next launch."""
    from repro.core.director import Director

    def exploding(offset, chunk):
        raise RuntimeError("boom: kernel bug")

    data = np.arange(1 << 10, dtype=np.float32)
    with Director(two_units()) as d:
        with pytest.raises(RuntimeError, match="boom"):
            d.launch(sched_for("dyn16", len(data)), exploding, [data],
                     np.zeros_like(data))
        out = np.zeros_like(data)
        pkgs = d.launch(sched_for("dyn16", len(data)), affine_kernel,
                        [data], out)
        np.testing.assert_allclose(out, expected(data))
        assert pkgs


def test_director_del_reports_unexpected_shutdown_error(monkeypatch, caplog):
    """__del__ swallows only interpreter-teardown RuntimeError; anything
    else is a real bug in the shutdown path and must stay visible."""
    from repro.core.director import Director

    d = Director(two_units())
    monkeypatch.setattr(d.engine, "shutdown",
                        lambda wait=True: (_ for _ in ()).throw(
                            OSError("socket vanished")))
    with caplog.at_level("ERROR", logger="repro.core.director"):
        d.__del__()
    assert "unexpected error shutting down" in caplog.text
    assert "socket vanished" in caplog.text


def test_director_del_tolerates_interpreter_teardown(monkeypatch, caplog):
    from repro.core.director import Director

    d = Director(two_units())
    monkeypatch.setattr(d.engine, "shutdown",
                        lambda wait=True: (_ for _ in ()).throw(
                            RuntimeError("can't create new thread")))
    with caplog.at_level("ERROR", logger="repro.core.director"):
        d.__del__()                      # swallowed: teardown race
    assert caplog.text == ""
