"""`repro.api` — the declarative configuration surface of the runtime.

One typed object, :class:`CoexecSpec`, configures every layer: the real
persistent engine, the paper-facing runtime, the discrete-event
simulators, and the CLIs (whose flags are derived from the spec fields).
Schedulers, workloads and co-executable kernels plug in by name through
:mod:`repro.api.registry` so third-party policies, profiles and kernels
register without editing core (``build_kernel``/``kernel_demo_inputs``
resolve kernels; ``registry_listing`` powers the CLIs' ``--list``).

    from repro.api import CoexecSpec

    spec = (CoexecSpec.builder()
            .policy("hguided")
            .admission(wfq=True, max_inflight=64)
            .fuse(True)
            .build())
    rt = spec.runtime()                     # real CoexecEngine underneath
    text = spec.to_json()                   # lossless round trip
    assert CoexecSpec.from_json(text) == spec

See ``docs/api.md`` for the schema table, builder examples and the
registry how-to. The legacy kwarg surfaces (``rt.config(...)``,
``make_scheduler(...)``, ``package_kernel(...)``, engine admission
kwargs) were removed when their deprecation window closed.
"""
from . import registry
from .cli import (SPEC_SECTIONS, add_spec_args, args_from_spec,
                  registry_listing, spec_from_args)
from .registry import (KernelPlugin, SchedulerPlugin, WorkloadPlugin,
                       build_kernel, build_scheduler, build_workload,
                       kernel_demo_inputs, kernel_names, kernel_plugin,
                       register_kernel, register_scheduler,
                       register_workload, scheduler_names,
                       speed_hint_policies, temporary_plugins,
                       validate_scheduler_options, workload_names,
                       workload_plugin)
from .spec import (SPEC_VERSION, AdmissionSpec, ClusterSpec, CoexecSpec,
                   CoexecSpecBuilder, MemorySpec, SchedulerSpec,
                   TrafficSpec, UnitsSpec, WorkloadSpec)

__all__ = [
    "AdmissionSpec", "ClusterSpec", "CoexecSpec", "CoexecSpecBuilder",
    "KernelPlugin",
    "MemorySpec", "SPEC_SECTIONS", "SPEC_VERSION", "SchedulerPlugin",
    "SchedulerSpec", "TrafficSpec", "UnitsSpec", "WorkloadPlugin",
    "WorkloadSpec",
    "add_spec_args", "args_from_spec", "build_kernel", "build_scheduler",
    "build_workload", "kernel_demo_inputs", "kernel_names",
    "kernel_plugin", "register_kernel", "register_scheduler",
    "register_workload", "registry", "registry_listing", "scheduler_names",
    "spec_from_args", "speed_hint_policies", "temporary_plugins",
    "validate_scheduler_options", "workload_names", "workload_plugin",
]
