"""Argparse derivation from ``CoexecSpec`` fields.

``serve`` and ``benchmarks.run`` used to duplicate ~10 hand-rolled flags
each; every new knob meant editing both in sync with the runtime kwargs.
Here the flags are *derived* from the spec dataclasses instead: each
sub-spec field carries its flag name/help/choices in dataclass field
metadata (see ``_cli`` in :mod:`repro.api.spec`), and

* :func:`add_spec_args` walks those fields and adds one argparse flag
  per field — a new spec field becomes a new CLI flag everywhere, free;
* :func:`spec_from_args` folds a parsed namespace back into a
  :class:`~repro.api.spec.CoexecSpec`;
* :func:`args_from_spec` emits the minimal argv that reproduces a spec,
  so CLI-args → spec → CLI-args is a round trip (pinned by tests).

Tuple fields parse as comma lists (``--dist 0.4,0.6``); policy-specific
scheduler options ride a repeatable ``--scheduler-opt key=value`` flag
whose values are JSON-decoded (``--scheduler-opt num_packages=8``). The
literal ``none`` resets an Optional field (``--max-inflight none``) or
clears accumulated options (``--scheduler-opt none``), so every spec is
reachable from argv even over a non-default base.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import typing
from typing import Any, Optional, Sequence

from .spec import CoexecSpec

__all__ = ["SPEC_SECTIONS", "add_spec_args", "spec_from_args",
           "args_from_spec", "registry_listing"]

# section order fixes flag ordering in --help and in args_from_spec output
SPEC_SECTIONS = ("scheduler", "admission", "workload", "units", "memory",
                 "traffic", "cluster")


def _section_class(section: str) -> type:
    field = {f.name: f for f in dataclasses.fields(CoexecSpec)}[section]
    return field.default_factory  # every section has a dataclass factory


def _cli_fields(sections: Sequence[str]):
    """Yield ``(section, field, resolved_type)`` for every CLI field."""
    for section in sections:
        cls = _section_class(section)
        hints = typing.get_type_hints(cls)
        for f in dataclasses.fields(cls):
            if "cli" not in f.metadata:
                continue
            yield section, f, hints[f.name]


def _scalar_type(tp) -> Optional[type]:
    """The concrete scalar parser for a field type (None = not scalar)."""
    if tp in (int, float, str):
        return tp
    origin = typing.get_origin(tp)
    if origin is typing.Union:           # Optional[int] and friends
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        if len(args) == 1 and args[0] in (int, float, str):
            return args[0]
    return None


def _is_optional(tp) -> bool:
    """Whether the field type admits ``None`` (``Optional[...]``)."""
    return (typing.get_origin(tp) is typing.Union
            and type(None) in typing.get_args(tp))


class _OptionalScalar:
    """Argparse ``type=`` for Optional fields: the literal ``none`` resets.

    Makes every value of an Optional spec field expressible on the
    command line (``--max-inflight none`` clears a base spec's cap), so
    ``args_from_spec`` stays a true inverse of ``spec_from_args`` even
    over a non-default base. Parsed ``None`` is carried as a sentinel —
    argparse's "flag not given" is already plain ``None``.
    """

    RESET = "\0reset"    # sentinel: flag given, value is None

    def __init__(self, elem: type):
        self.elem = elem
        self.__name__ = elem.__name__    # argparse error messages

    def __call__(self, raw: str):
        if raw.lower() in ("none", ""):
            return self.RESET
        return self.elem(raw)


def _tuple_elem(tp) -> Optional[type]:
    """Element parser for ``tuple[elem, ...]`` fields (None otherwise)."""
    if typing.get_origin(tp) is tuple:
        args = typing.get_args(tp)
        if args and args[0] in (int, float, str):
            return args[0]
    return None


def _parse_kv(item: str) -> Optional[tuple[str, Any]]:
    """Parse one ``key=value`` option; value is JSON, else a raw string.

    The literal ``none`` (no ``=``) clears previously accumulated
    options — the kv analogue of ``--max-inflight none``.
    """
    if item.lower() == "none":
        return None
    key, sep, raw = item.partition("=")
    if not sep or not key:
        raise argparse.ArgumentTypeError(
            f"expected key=value (or the literal none), got {item!r}")
    try:
        value = json.loads(raw)
    except json.JSONDecodeError:
        value = raw
    return key, value


def add_spec_args(parser: argparse.ArgumentParser, *,
                  sections: Sequence[str] = SPEC_SECTIONS) -> None:
    """Add one flag per spec field to ``parser``.

    Every flag defaults to ``None`` (= "not given"): only flags the user
    actually passed override the base spec in :func:`spec_from_args`, so
    the same parser serves different base specs.

    Args:
        parser: the argparse parser to extend.
        sections: which ``CoexecSpec`` sections to derive flags for.
    """
    for section, f, tp in _cli_fields(sections):
        flag = "--" + f.metadata["cli"]
        help_ = f.metadata.get("help", "")
        choices = f.metadata.get("choices")
        if f.metadata.get("kv"):
            parser.add_argument(flag, action="append", default=None,
                                type=_parse_kv, metavar="KEY=VALUE",
                                help=help_)
        elif tp is bool:
            parser.add_argument(flag, action=argparse.BooleanOptionalAction,
                                default=None, help=help_)
        elif _tuple_elem(tp) is not None:
            parser.add_argument(flag, default=None, metavar="V[,V...]",
                                help=help_)
        else:
            scalar = _scalar_type(tp) or str
            if _is_optional(tp):
                scalar = _OptionalScalar(scalar)
            parser.add_argument(flag, type=scalar, default=None,
                                choices=choices, help=help_)


def _dest(f: dataclasses.Field) -> str:
    return f.metadata["cli"].replace("-", "_")


def spec_from_args(args: argparse.Namespace, *,
                   base: Optional[CoexecSpec] = None,
                   sections: Sequence[str] = SPEC_SECTIONS) -> CoexecSpec:
    """Fold a parsed namespace into a spec (unset flags keep the base).

    Args:
        args: namespace from a parser built with :func:`add_spec_args`.
        base: spec supplying values for flags the user did not pass.
        sections: sections to read (must match ``add_spec_args``).

    Returns:
        The merged :class:`CoexecSpec`.
    """
    spec = base if base is not None else CoexecSpec()
    for section, f, tp in _cli_fields(sections):
        value = getattr(args, _dest(f), None)
        if value is None:
            continue
        if f.metadata.get("kv"):
            # a literal `none` item clears everything accumulated so far
            pairs: list = []
            for item in value:
                pairs = [] if item is None else pairs + [item]
            value = tuple(pairs)
        elif _tuple_elem(tp) is not None:
            elem = _tuple_elem(tp)
            value = tuple(elem(v) for v in str(value).split(",") if v != "")
        elif value == _OptionalScalar.RESET:
            value = None
        sub = getattr(spec, section).replace(**{f.name: value})
        spec = spec.replace(**{section: sub})
    return spec


def registry_listing() -> str:
    """Human-readable dump of every registered plugin (``--list``).

    One line per registered scheduler, workload, kernel and
    static-analysis pass with its declared option fields — the
    introspection surface both CLIs print, so a freshly registered
    third-party plugin is discoverable without reading code. Kernels
    additionally show their per-argument partition semantics (split
    axis/halo, broadcast, defaults); analysis passes show their rule ids.

    Returns:
        The formatted multi-line listing.
    """
    from . import registry

    lines = ["schedulers:"]
    for name in registry.scheduler_names():
        plugin, _ = registry.resolve_scheduler(name)
        extra = "  [takes a speeds hint]" if plugin.speed_hint else ""
        lines.append(f"  {name:14s} options: "
                     f"{', '.join(sorted(plugin.fields)) or '-'}{extra}")
    lines.append("workloads:")
    for name in registry.workload_names():
        fields = registry.workload_plugin(name).fields
        lines.append(f"  {name:14s} options: "
                     f"{', '.join(sorted(fields)) or '-'}")
    lines.append("kernels:")
    for name in registry.kernel_names():
        plugin = registry.kernel_plugin(name)
        try:
            kernel = plugin.factory()
            args = []
            for a in kernel.args:
                if a.role.value == "split":
                    halo = f"+halo{a.halo}" if a.halo else ""
                    axis = f"@axis{a.axis}" if a.axis else ""
                    args.append(f"{a.name}[split{axis}{halo}]")
                else:
                    dflt = "=default" if a.default is not None else ""
                    args.append(f"{a.name}[broadcast{dflt}]")
            args_desc = ", ".join(args)
        except (TypeError, ValueError, KeyError):
            # a factory with required options cannot be probed for its
            # argument semantics; still list the kernel itself
            args_desc = "(factory needs options)"
        lines.append(f"  {name:14s} args: {args_desc}; options: "
                     f"{', '.join(sorted(plugin.fields)) or '-'}")
    from repro import analysis

    lines.append("analysis:")
    for name in analysis.pass_names():
        plugin = analysis.pass_plugin(name)
        rules = ", ".join(r.id for r in plugin.rules)
        lines.append(f"  {name:14s} [{plugin.scope}] rules: {rules}")
    return "\n".join(lines)


def _format_kv(key: str, value: Any) -> str:
    if isinstance(value, tuple):
        value = list(value)
    return f"{key}={json.dumps(value)}"


def args_from_spec(spec: CoexecSpec, *,
                   base: Optional[CoexecSpec] = None,
                   sections: Sequence[str] = SPEC_SECTIONS) -> list[str]:
    """The minimal argv reproducing ``spec`` over ``base``.

    The inverse of :func:`spec_from_args`:
    ``spec_from_args(parse(args_from_spec(s)), base=base) == s`` for any
    spec expressible through the derived flags.

    Args:
        spec: the spec to serialize to CLI tokens.
        base: baseline whose values need no flags (default: all-default).
        sections: sections to emit (must match the parser).

    Returns:
        Flat argv token list (``["--policy", "hguided", ...]``).
    """
    base = base if base is not None else CoexecSpec()
    argv: list[str] = []
    for section, f, tp in _cli_fields(sections):
        value = getattr(getattr(spec, section), f.name)
        if value == getattr(getattr(base, section), f.name):
            continue
        flag = "--" + f.metadata["cli"]
        if f.metadata.get("kv"):
            if not value:               # clear a base spec's options
                argv += [flag, "none"]
            for key, v in value:
                argv += [flag, _format_kv(key, v)]
        elif tp is bool:
            argv.append(flag if value else "--no-" + f.metadata["cli"])
        elif _tuple_elem(tp) is not None:
            argv += [flag, ",".join(str(v) for v in value)]
        else:
            argv += [flag, str(value)]
    return argv
