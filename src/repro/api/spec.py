"""Declarative, typed configuration for a co-execution: ``CoexecSpec``.

The paper's runtime is configured through a tiny imperative surface
(``rt.config(policy, units, dist, memory)`` — §3.3, Listing 1). As the
repo grew a persistent engine (PR 1) and an admission layer (PR 2), that
surface smeared into four uncoordinated places: ``make_scheduler(**kw)``,
``AdmissionConfig``, ``CoexecutorRuntime.config`` kwargs, and hand-rolled
argparse flags in ``serve``/``benchmarks.run``. ``CoexecSpec`` is the
single replacement: a frozen dataclass tree that

* is the *one* source of truth — the real engine, the discrete-event
  simulator, the serve CLI and the benchmark driver all construct from
  the same object;
* round-trips losslessly: ``CoexecSpec.from_dict(spec.to_dict()) == spec``
  and likewise through JSON, so experiment configs are artifacts;
* validates against the plugin registry
  (:mod:`repro.api.registry`) — unknown policies raise ``KeyError``,
  unknown/misspelled policy options raise ``ValueError`` naming the key
  and the accepted fields;
* builds fluently::

      spec = (CoexecSpec.builder()
              .policy("hguided")
              .admission(wfq=True, max_inflight=64)
              .fuse(True)
              .build())

Sub-spec field metadata carries the CLI derivation (flag name, help,
choices) consumed by :mod:`repro.api.cli`, which is how ``serve`` and
``benchmarks.run`` grow one flag per new field with no per-tool edits.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional, Sequence

from ..core.admission import ADMISSION_POLICIES, AdmissionConfig
from ..core.memory import MemoryModel
from . import registry

__all__ = [
    "UnitsSpec", "SchedulerSpec", "AdmissionSpec", "MemorySpec",
    "WorkloadSpec", "TrafficSpec", "ClusterSpec", "CoexecSpec",
    "CoexecSpecBuilder", "SPEC_VERSION",
]

SPEC_VERSION = 1


def _freeze(value: Any) -> Any:
    """Recursively turn lists into tuples (hashable, frozen-friendly)."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    return value


def _thaw(value: Any) -> Any:
    """Recursively turn tuples into lists (JSON-friendly)."""
    if isinstance(value, tuple):
        return [_thaw(v) for v in value]
    return value


def _cli(flag: str, help_: str, **extra) -> dict:
    """Dataclass field metadata block consumed by :mod:`repro.api.cli`."""
    return {"cli": flag, "help": help_, **extra}


def _sub_from_dict(cls, data: dict):
    """Build one sub-spec from a plain dict, freezing list values."""
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(data) - names)
    if unknown:
        raise ValueError(f"unknown {cls.__name__} field(s) {unknown!r}; "
                         f"accepted: {sorted(names)}")
    return cls(**{k: _freeze(v) for k, v in data.items()})


class _SubSpec:
    """Shared dict/round-trip plumbing for the frozen sub-specs."""

    def to_dict(self) -> dict:
        """Plain-dict form (JSON-safe: tuples become lists)."""
        return {f.name: _thaw(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, data: dict):
        """Inverse of :meth:`to_dict` (lists re-frozen to tuples).

        Args:
            data: mapping of field names to values.

        Returns:
            A new instance equal to the one ``to_dict`` was called on.

        Raises:
            ValueError: unknown field names.
        """
        return _sub_from_dict(cls, data)

    def replace(self, **changes):
        """A copy with the given fields replaced (frozen-safe)."""
        return dataclasses.replace(self, **{k: _freeze(v)
                                            for k, v in changes.items()})


@dataclasses.dataclass(frozen=True)
class UnitsSpec(_SubSpec):
    """Which Coexecution Units to build, and their computing-power hint.

    ``count=None`` means one unit per local jax device (the paper's
    CPU+GPU pair on its platform). A ``count`` larger than the device
    pool replicates the first device — the CPU-only container's two-unit
    setup. ``dist`` is the paper's ``dist(0.35)``: a single value is the
    first unit's share (remainder spread evenly), a full tuple is
    per-unit shares.
    """

    count: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(
            "units", "number of Coexecution Units (default: one per "
                     "local device)"))
    kinds: tuple[str, ...] = dataclasses.field(
        default=(), metadata=_cli(
            "unit-kinds", "per-unit energy-model kind (comma list, e.g. "
                          "cpu,gpu)"))
    speed_hints: tuple[float, ...] = dataclasses.field(
        default=(), metadata=_cli(
            "speed-hints", "per-unit relative speed hints (comma list)"))
    dist: tuple[float, ...] = dataclasses.field(
        default=(), metadata=_cli(
            "dist", "computing-power shares: one value = first unit's "
                    "share (paper's dist(0.35)), or per-unit comma list"))
    pipeline_depth: int = dataclasses.field(
        default=1, metadata=_cli(
            "pipeline-depth", "packages a unit may have in flight at "
                              "once (1 = serial stage/compute/collect)"))

    def resolve_dist(self, num_units: int) -> Optional[list[float]]:
        """Expand ``dist`` into per-unit shares for ``num_units`` units.

        Args:
            num_units: unit count the shares must cover.

        Returns:
            Per-unit shares, or ``None`` when no hint was given.

        Raises:
            ValueError: a multi-value ``dist`` whose length mismatches
                ``num_units``, or non-positive shares.
        """
        if not self.dist:
            return None
        if any(not float(d) > 0 for d in self.dist):
            raise ValueError(f"dist shares must be positive, "
                             f"got {self.dist!r}")
        if len(self.dist) == 1:
            first = float(self.dist[0])
            rest = (1.0 - first) / max(num_units - 1, 1)
            return [first] + [rest] * (num_units - 1)
        if len(self.dist) != num_units:
            raise ValueError(f"dist has {len(self.dist)} shares for "
                             f"{num_units} units")
        return [float(d) for d in self.dist]

    def build(self) -> list:
        """Materialize the described :class:`~repro.core.units.JaxUnit`\\ s.

        Returns:
            One unit per requested slot; a count beyond the local device
            pool replicates the first device.
        """
        import jax

        from ..core.runtime import counits_from_devices

        devices = list(jax.local_devices())
        if self.count is not None:
            if self.count <= len(devices):
                devices = devices[:self.count]
            else:
                devices = devices[:1] * self.count
        kinds = list(self.kinds) if self.kinds else None
        hints = [float(h) for h in self.speed_hints] \
            if self.speed_hints else None
        return counits_from_devices(devices, kinds=kinds, speed_hints=hints)


@dataclasses.dataclass(frozen=True)
class SchedulerSpec(_SubSpec):
    """Intra-launch load-balancing policy and its options.

    ``options`` holds policy-specific knobs (``num_packages``,
    ``chunks_per_unit``, ``divisor``, ...) as a sorted tuple of pairs so
    the spec stays frozen and order-insensitively equal; use
    :meth:`options_dict` / :meth:`with_options` to work with them.
    """

    policy: str = dataclasses.field(
        default="hguided", metadata=_cli(
            "policy", "intra-launch scheduling policy (or 'all' to sweep "
                      "every registered policy)"))
    granularity: int = dataclasses.field(
        default=1, metadata=_cli(
            "granularity", "package alignment in work-items (local work "
                           "size)"))
    options: tuple[tuple[str, Any], ...] = dataclasses.field(
        default=(), metadata=_cli(
            "scheduler-opt", "policy-specific option as key=value "
                             "(repeatable)", kv=True))

    def __post_init__(self) -> None:
        normalized = tuple(sorted((str(k), _freeze(v))
                                  for k, v in self.options))
        object.__setattr__(self, "options", normalized)

    def options_dict(self) -> dict:
        """The policy options as a plain dict."""
        return {k: v for k, v in self.options}

    def with_options(self, **options) -> "SchedulerSpec":
        """A copy with the given options merged in (None removes a key)."""
        merged = self.options_dict()
        for k, v in options.items():
            if v is None:
                merged.pop(k, None)
            else:
                merged[k] = v
        return self.replace(options=tuple(merged.items()))

    def to_dict(self) -> dict:
        """Plain-dict form; ``options`` becomes a mapping."""
        d = super().to_dict()
        d["options"] = {k: _thaw(v) for k, v in self.options}
        return d

    @classmethod
    def from_dict(cls, data: dict) -> "SchedulerSpec":
        """Inverse of :meth:`to_dict` (mapping options re-frozen).

        Args:
            data: mapping of field names to values; ``options`` may be a
                mapping or a pair sequence.

        Returns:
            The reconstructed spec.
        """
        data = dict(data)
        opts = data.get("options", {})
        if isinstance(opts, dict):
            data["options"] = tuple(opts.items())
        return _sub_from_dict(cls, data)

    def validate(self) -> None:
        """Check the policy exists and every option is accepted.

        Raises:
            KeyError: unknown policy.
            ValueError: unknown option key (named, with accepted fields)
                or non-positive granularity.
        """
        if self.granularity <= 0:
            raise ValueError("granularity must be positive")
        if self.policy != "all":
            registry.validate_scheduler_options(self.policy,
                                                self.options_dict())

    def build(self, total: int, num_units: int, *,
              speeds: Optional[Sequence[float]] = None):
        """Build a fresh one-shot scheduler from this spec.

        Args:
            total: size of the 1-D index space.
            num_units: Coexecution Unit count.
            speeds: computing-power hint, applied only when the policy's
                plugin declares it takes one and the spec's options do
                not already pin ``speeds``.

        Returns:
            The constructed scheduler.
        """
        plugin, _ = registry.resolve_scheduler(self.policy)
        kw = self.options_dict()
        kw.setdefault("granularity", self.granularity)
        if speeds is not None and plugin.speed_hint:
            kw.setdefault("speeds", list(speeds))
        return registry.build_scheduler(self.policy, total, num_units, **kw)


@dataclasses.dataclass(frozen=True)
class AdmissionSpec(_SubSpec):
    """Cross-launch queueing discipline (mirrors ``AdmissionConfig``)."""

    policy: str = dataclasses.field(
        default="fifo", metadata=_cli(
            "admission", "cross-launch queueing: FIFO drain or "
                         "weighted-fair deficit round robin",
            choices=ADMISSION_POLICIES))
    fuse: bool = dataclasses.field(
        default=False, metadata=_cli(
            "fuse", "coalesce small same-shaped concurrent launches into "
                    "shared dispatches"))
    fuse_threshold: int = dataclasses.field(
        default=1 << 12, metadata=_cli(
            "fuse-threshold", "largest launch (work-items) eligible for "
                              "fusion"))
    fuse_limit: int = dataclasses.field(
        default=64, metadata=_cli(
            "fuse-limit", "maximum members per fused batch"))
    fuse_wait_s: float = dataclasses.field(
        default=0.002, metadata=_cli(
            "fuse-wait-s", "fusion batching window in seconds"))
    max_inflight: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(
            "max-inflight", "backpressure cap on admitted launches"))
    quantum: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(
            "quantum", "WFQ deficit-round-robin credit per round "
                       "(work-items; default derives from package hints)"))
    preempt: bool = dataclasses.field(
        default=False, metadata=_cli(
            "preempt", "WFQ reclaims credit mid-launch by capping "
                       "per-pull package sizes of over-served tenants"))
    fuse_buckets: bool = dataclasses.field(
        default=False, metadata=_cli(
            "fuse-buckets", "pad near-identical launch shapes up to "
                            "power-of-2 buckets so mixed traffic still "
                            "fuses"))
    slo_ms: Optional[float] = dataclasses.field(
        default=None, metadata=_cli(
            "slo-ms", "default per-launch deadline in milliseconds "
                      "(EDF urgency + shedding reference)"))
    shed: bool = dataclasses.field(
        default=False, metadata=_cli(
            "shed", "reject launches whose estimated finish misses the "
                    "deadline (bounded by --shed-budget)"))
    shed_budget: float = dataclasses.field(
        default=0.25, metadata=_cli(
            "shed-budget", "maximum fraction of offered launches the "
                           "shedder may reject"))
    shed_rate: Optional[float] = dataclasses.field(
        default=None, metadata=_cli(
            "shed-rate", "service-rate estimate in items/s for the shed "
                         "finish predictor (default: derived capacity)"))
    edf_boost: float = dataclasses.field(
        default=1.0, metadata=_cli(
            "edf-boost", "EDF credit boost factor for deadline-ranked "
                         "refills (0 disables the boost)"))

    def to_config(self) -> AdmissionConfig:
        """The equivalent :class:`~repro.core.admission.AdmissionConfig`.

        Returns:
            A validated config (construction runs its checks).

        Raises:
            ValueError: invalid policy or limits.
        """
        return AdmissionConfig(
            policy=self.policy, fuse=self.fuse,
            fuse_threshold=self.fuse_threshold, fuse_limit=self.fuse_limit,
            fuse_wait_s=self.fuse_wait_s, max_inflight=self.max_inflight,
            quantum=self.quantum, preempt=self.preempt,
            fuse_buckets=self.fuse_buckets, slo_ms=self.slo_ms,
            shed=self.shed, shed_budget=self.shed_budget,
            shed_rate=self.shed_rate, edf_boost=self.edf_boost)

    @classmethod
    def from_config(cls, config: AdmissionConfig) -> "AdmissionSpec":
        """Lift an imperative config into the declarative spec.

        Args:
            config: an existing admission configuration.

        Returns:
            The equivalent spec (``to_config`` inverts it).
        """
        return cls(policy=config.policy, fuse=config.fuse,
                   fuse_threshold=config.fuse_threshold,
                   fuse_limit=config.fuse_limit,
                   fuse_wait_s=config.fuse_wait_s,
                   max_inflight=config.max_inflight,
                   quantum=config.quantum, preempt=config.preempt,
                   fuse_buckets=config.fuse_buckets, slo_ms=config.slo_ms,
                   shed=config.shed, shed_budget=config.shed_budget,
                   shed_rate=config.shed_rate, edf_boost=config.edf_boost)

    def validate(self) -> None:
        """Check policy/limits by constructing the config once.

        Raises:
            ValueError: invalid policy or limits.
        """
        self.to_config()


@dataclasses.dataclass(frozen=True)
class MemorySpec(_SubSpec):
    """Memory model governing package data movement (paper §3.1)."""

    model: str = dataclasses.field(
        default="usm", metadata=_cli(
            "memory", "collection semantics: unified shared memory or "
                      "per-package buffers",
            choices=tuple(m.value for m in MemoryModel)))

    def to_model(self) -> MemoryModel:
        """The equivalent :class:`~repro.core.memory.MemoryModel`.

        Returns:
            The enum member for :attr:`model`.

        Raises:
            ValueError: unknown model name.
        """
        return MemoryModel(str(self.model).lower())

    def validate(self) -> None:
        """Check the model name maps to a known memory model.

        Raises:
            ValueError: unknown model name.
        """
        self.to_model()


@dataclasses.dataclass(frozen=True)
class WorkloadSpec(_SubSpec):
    """What to run: profile, kernel, per-launch size, and serving shape."""

    name: str = dataclasses.field(
        default="taylor", metadata=_cli(
            "workload", "registered workload profile (paper Table 1 "
                        "benchmarks, or a plugin)"))
    kernel: str = dataclasses.field(
        default="", metadata=_cli(
            "kernel", "registered package kernel for the real engine "
                      "(default: the workload's same-named kernel, "
                      "falling back to taylor)"))
    kernel_impl: str = dataclasses.field(
        default="auto", metadata=_cli(
            "kernel-impl", "kernel implementation variant to serve "
                           "(auto = pallas on TPU, xla elsewhere)",
            choices=("auto", "pallas", "xla", "ref")))
    size_scale: float = dataclasses.field(
        default=1.0, metadata=_cli(
            "size-scale", "problem-size multiplier for the profile "
                          "(Fig. 8 sweeps)"))
    items: int = dataclasses.field(
        default=1 << 16, metadata=_cli(
            "n", "work-items per real co-execution request"))
    requests: int = dataclasses.field(
        default=16, metadata=_cli(
            "requests", "number of requests to serve per policy"))
    concurrent: int = dataclasses.field(
        default=8, metadata=_cli(
            "concurrent", "max in-flight launch_async requests"))
    tenants: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(
            "tenants", "concurrent tenants for the multi-tenant DES sweep"))

    def validate(self) -> None:
        """Check the profile/kernel exist and the serving shape is sane.

        Raises:
            KeyError: unknown workload profile, or an explicitly named
                kernel that is not registered.
            ValueError: non-positive sizes/counts.
        """
        if self.name not in registry.workload_names():
            raise KeyError(f"unknown workload {self.name!r}; choose from "
                           f"{list(registry.workload_names())}")
        if self.kernel and self.kernel not in registry.kernel_names():
            raise KeyError(f"unknown kernel {self.kernel!r}; choose from "
                           f"{list(registry.kernel_names())}")
        if self.kernel_impl not in ("auto", "pallas", "xla", "ref"):
            raise ValueError(
                f"unknown kernel_impl {self.kernel_impl!r}; choose from "
                f"['auto', 'pallas', 'xla', 'ref']")
        if self.items <= 0 or self.requests <= 0 or self.concurrent <= 0:
            raise ValueError("items/requests/concurrent must be positive")
        if self.size_scale <= 0:
            raise ValueError("size_scale must be positive")
        if self.tenants is not None and self.tenants < 1:
            raise ValueError("tenants must be a positive integer (or None)")

    def build(self):
        """Materialize the profile via the workload registry.

        Returns:
            ``(Workload, cpu_unit, gpu_unit)`` for the built-ins.
        """
        return registry.build_workload(self.name,
                                       size_scale=self.size_scale)

    def resolve_kernel(self) -> str:
        """The kernel name real co-execution paths should serve.

        Returns:
            The explicit :attr:`kernel` when set; otherwise the
            workload's same-named registered kernel, falling back to
            ``"taylor"`` for profiles with no kernel twin.
        """
        if self.kernel:
            return self.kernel
        if self.name in registry.kernel_names():
            return self.name
        return "taylor"

    def build_kernel(self):
        """Resolve the served kernel through the kernel registry.

        The :attr:`kernel_impl` axis is passed through, so ``--kernel-impl
        pallas`` serves the Pallas body of the selected kernel on both
        backends (``auto`` defers to the kernel's backend-aware default).

        Returns:
            The registered :class:`~repro.core.dataplane.CoexecKernel`.
        """
        return registry.build_kernel(self.resolve_kernel(),
                                     impl=self.kernel_impl)


@dataclasses.dataclass(frozen=True)
class TrafficSpec(_SubSpec):
    """Open-loop arrival process feeding the serving loop.

    ``arrival="closed"`` keeps today's closed-loop sweeps (submit a
    fixed batch, drain). ``"poisson"`` and ``"burst"`` synthesize a
    seeded open-loop trace via :func:`repro.core.traffic.synthesize_trace`
    — the same trace replays identically on the real engine and the DES,
    which is what the parity harness pins.
    """

    arrival: str = dataclasses.field(
        default="closed", metadata=_cli(
            "arrival", "arrival process: closed-loop batch, Poisson, or "
                       "bursty on/off Poisson",
            choices=("closed", "poisson", "burst")))
    rate: float = dataclasses.field(
        default=0.0, metadata=_cli(
            "rate", "mean offered arrival rate in launches/s (0 derives "
                    "from --load and measured capacity)"))
    load: float = dataclasses.field(
        default=1.2, metadata=_cli(
            "load", "offered load as a multiple of serving capacity, "
                    "used when --rate is 0"))
    arrivals: int = dataclasses.field(
        default=2048, metadata=_cli(
            "arrivals", "number of arrivals to synthesize per replay"))
    burst: float = dataclasses.field(
        default=4.0, metadata=_cli(
            "burst", "on-phase rate multiplier for --arrival burst"))
    burst_duty: float = dataclasses.field(
        default=0.2, metadata=_cli(
            "burst-duty", "fraction of each burst cycle spent in the "
                          "on phase (burst*duty must stay below 1)"))
    item_jitter: float = dataclasses.field(
        default=0.0, metadata=_cli(
            "item-jitter", "log-uniform spread of per-arrival item "
                           "counts (0 = uniform size)"))
    seed: int = dataclasses.field(
        default=0, metadata=_cli(
            "traffic-seed", "PRNG seed for trace synthesis"))
    trace: str = dataclasses.field(
        default="", metadata=_cli(
            "trace", "replay a saved JSON trace instead of synthesizing "
                     "one (overrides the arrival/rate knobs)"))

    def validate(self) -> None:
        """Check the arrival process and its knobs.

        Raises:
            ValueError: unknown arrival name, non-positive counts, or a
                burst shape whose off-phase rate would go negative.
        """
        if self.arrival not in ("closed", "poisson", "burst"):
            raise ValueError(
                f"unknown arrival {self.arrival!r}; choose from "
                f"['closed', 'poisson', 'burst']")
        if self.rate < 0:
            raise ValueError("rate must be >= 0")
        if self.load <= 0:
            raise ValueError("load must be positive")
        if self.arrivals < 1:
            raise ValueError("arrivals must be a positive integer")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")
        if not 0 < self.burst_duty < 1:
            raise ValueError("burst_duty must be in (0, 1)")
        if self.burst * self.burst_duty >= 1:
            raise ValueError("burst * burst_duty must be < 1 so the "
                             "off-phase rate stays positive")
        if self.item_jitter < 0:
            raise ValueError("item_jitter must be >= 0")


@dataclasses.dataclass(frozen=True)
class ClusterSpec(_SubSpec):
    """Elastic cluster tier: pool sizing, failure detection, autoscaling.

    Configures :mod:`repro.core.cluster`: the provisioned pool ceiling
    and active floor, the supervisor's heartbeat/grace/straggler knobs,
    an optional committed :class:`~repro.core.cluster.FailurePlan` to
    inject, and the admission-depth autoscaler's hysteresis band.
    Disabled by default — the static unit set of the paper's runtime.
    """

    enabled: bool = dataclasses.field(
        default=False, metadata=_cli(
            "cluster", "serve through the elastic cluster tier "
                       "(resizable pool + failure recovery)"))
    min_units: int = dataclasses.field(
        default=1, metadata=_cli(
            "cluster-min-units", "active units at start and the "
                                 "scale-in floor"))
    max_units: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(
            "cluster-max-units", "provisioned pool ceiling (default: "
                                 "the built unit count)"))
    heartbeat_s: float = dataclasses.field(
        default=0.05, metadata=_cli(
            "cluster-heartbeat-s", "expected liveness beat interval in "
                                   "seconds"))
    grace_s: float = dataclasses.field(
        default=0.2, metadata=_cli(
            "cluster-grace-s", "silence beyond this declares a unit "
                               "dead"))
    straggler_factor: float = dataclasses.field(
        default=4.0, metadata=_cli(
            "cluster-straggler-factor", "outstanding-age multiple of the "
                                        "EWMA package service time that "
                                        "flags a straggler"))
    failure_plan: str = dataclasses.field(
        default="", metadata=_cli(
            "cluster-failure-plan", "JSON FailurePlan to inject "
                                    "(scripted kill/join timeline)"))
    autoscale: bool = dataclasses.field(
        default=False, metadata=_cli(
            "cluster-autoscale", "resize the pool from admission queue "
                                 "depth between min and max units"))
    scale_up_depth: int = dataclasses.field(
        default=8, metadata=_cli(
            "cluster-scale-up-depth", "queue depth that (sustained) "
                                      "triggers scale-out"))
    scale_down_depth: int = dataclasses.field(
        default=1, metadata=_cli(
            "cluster-scale-down-depth", "queue depth at or below which "
                                        "(sustained) the pool scales in"))
    sustain_s: float = dataclasses.field(
        default=0.1, metadata=_cli(
            "cluster-sustain-s", "seconds the backlog must persist "
                                 "before scale-out"))
    idle_s: float = dataclasses.field(
        default=0.5, metadata=_cli(
            "cluster-idle-s", "seconds of idleness before scale-in"))
    cooldown_s: float = dataclasses.field(
        default=0.25, metadata=_cli(
            "cluster-cooldown-s", "minimum seconds between consecutive "
                                  "resizes"))

    def validate(self) -> None:
        """Check pool bounds, detector intervals and the hysteresis band.

        Raises:
            ValueError: inverted pool bounds, non-positive intervals, or
                a hysteresis band with scale_down >= scale_up.
        """
        if self.min_units < 1:
            raise ValueError("min_units must be >= 1")
        if self.max_units is not None and self.max_units < self.min_units:
            raise ValueError(f"max_units ({self.max_units}) must be >= "
                             f"min_units ({self.min_units})")
        if self.heartbeat_s <= 0 or self.grace_s <= 0:
            raise ValueError("heartbeat_s and grace_s must be positive")
        if self.straggler_factor <= 0:
            raise ValueError("straggler_factor must be positive")
        if self.scale_down_depth >= self.scale_up_depth:
            raise ValueError("hysteresis needs scale_down_depth < "
                             "scale_up_depth")
        if self.sustain_s < 0 or self.idle_s < 0 or self.cooldown_s < 0:
            raise ValueError("sustain_s/idle_s/cooldown_s must be >= 0")

    def load_plan(self):
        """The configured failure plan, loaded (``None`` when unset).

        Returns:
            A :class:`~repro.core.cluster.FailurePlan`, or ``None``.
        """
        if not self.failure_plan:
            return None
        from ..core.cluster import FailurePlan

        return FailurePlan.load(self.failure_plan)

    def autoscaler_opts(self) -> dict:
        """Keyword arguments for :class:`~repro.core.cluster.Autoscaler`."""
        return dict(scale_up_depth=self.scale_up_depth,
                    scale_down_depth=self.scale_down_depth,
                    sustain_s=self.sustain_s, idle_s=self.idle_s,
                    cooldown_s=self.cooldown_s)


@dataclasses.dataclass(frozen=True)
class CoexecSpec(_SubSpec):
    """The single declarative description of one co-execution setup.

    One object configures everything the runtime stack needs: the real
    :class:`~repro.core.engine.CoexecEngine` (via
    :meth:`~repro.core.engine.CoexecEngine.from_spec`), the paper-facing
    :class:`~repro.core.runtime.CoexecutorRuntime` (via ``configure``),
    the simulators (``simulate(..., spec=...)`` /
    ``simulate_multi(..., spec=...)``) and the CLIs (which derive their
    flags from these fields). Frozen; use :meth:`replace`, the builder,
    or the sub-spec ``replace`` methods to derive variants.
    """

    units: UnitsSpec = dataclasses.field(default_factory=UnitsSpec)
    scheduler: SchedulerSpec = dataclasses.field(
        default_factory=SchedulerSpec)
    admission: AdmissionSpec = dataclasses.field(
        default_factory=AdmissionSpec)
    memory: MemorySpec = dataclasses.field(default_factory=MemorySpec)
    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    traffic: TrafficSpec = dataclasses.field(default_factory=TrafficSpec)
    cluster: ClusterSpec = dataclasses.field(default_factory=ClusterSpec)

    # -- round-trip serialization ------------------------------------------
    def to_dict(self) -> dict:
        """Nested plain-dict form, tagged with a schema version."""
        return {
            "version": SPEC_VERSION,
            "units": self.units.to_dict(),
            "scheduler": self.scheduler.to_dict(),
            "admission": self.admission.to_dict(),
            "memory": self.memory.to_dict(),
            "workload": self.workload.to_dict(),
            "traffic": self.traffic.to_dict(),
            "cluster": self.cluster.to_dict(),
        }

    @classmethod
    def from_dict(cls, data: dict) -> "CoexecSpec":
        """Lossless inverse of :meth:`to_dict`.

        Args:
            data: a :meth:`to_dict` result (missing sections default).

        Returns:
            A spec equal to the serialized one.

        Raises:
            ValueError: unsupported schema version or unknown fields.
        """
        version = data.get("version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise ValueError(f"unsupported CoexecSpec version {version!r} "
                             f"(this build reads version {SPEC_VERSION})")
        return cls(
            units=UnitsSpec.from_dict(data.get("units", {})),
            scheduler=SchedulerSpec.from_dict(data.get("scheduler", {})),
            admission=AdmissionSpec.from_dict(data.get("admission", {})),
            memory=MemorySpec.from_dict(data.get("memory", {})),
            workload=WorkloadSpec.from_dict(data.get("workload", {})),
            traffic=TrafficSpec.from_dict(data.get("traffic", {})),
            cluster=ClusterSpec.from_dict(data.get("cluster", {})),
        )

    def to_json(self, **dumps_kw) -> str:
        """JSON form of :meth:`to_dict` (sorted keys by default)."""
        dumps_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, text: str) -> "CoexecSpec":
        """Inverse of :meth:`to_json`.

        Args:
            text: a JSON document produced by :meth:`to_json`.

        Returns:
            A spec equal to the serialized one.
        """
        return cls.from_dict(json.loads(text))

    # -- validation ---------------------------------------------------------
    def validate(self) -> "CoexecSpec":
        """Validate every section against the registry and core checks.

        Returns:
            The spec itself, for chaining.

        Raises:
            KeyError: unknown policy or workload profile.
            ValueError: unknown policy option (named, with accepted
                fields) or invalid values anywhere in the tree.
        """
        self.scheduler.validate()
        self.admission.validate()
        self.memory.validate()
        self.workload.validate()
        self.traffic.validate()
        self.cluster.validate()
        if self.units.dist:
            n = self.units.count if self.units.count is not None \
                else max(len(self.units.dist), 1)
            self.units.resolve_dist(n)
        if int(self.units.pipeline_depth) < 1:
            raise ValueError(f"pipeline_depth must be >= 1, "
                             f"got {self.units.pipeline_depth!r}")
        return self

    # -- builders -----------------------------------------------------------
    @classmethod
    def builder(cls, base: Optional["CoexecSpec"] = None
                ) -> "CoexecSpecBuilder":
        """A fluent builder, optionally seeded from an existing spec.

        Args:
            base: spec to start from (default: all defaults).

        Returns:
            A :class:`CoexecSpecBuilder`.
        """
        return CoexecSpecBuilder(base if base is not None else cls())

    # -- materialization ----------------------------------------------------
    def speeds_for(self, num_units: int) -> Optional[list[float]]:
        """Per-unit computing-power shares for ``num_units`` units."""
        return self.units.resolve_dist(num_units)

    def build_scheduler(self, total: int, num_units: int):
        """Scheduler for one launch, with the spec's ``dist`` hint wired.

        Args:
            total: launch index-space size.
            num_units: Coexecution Unit count.

        Returns:
            A fresh one-shot scheduler.
        """
        return self.scheduler.build(total, num_units,
                                    speeds=self.speeds_for(num_units))

    def build_units(self) -> list:
        """The described real Coexecution Units (see ``UnitsSpec.build``)."""
        return self.units.build()

    def build_workload(self):
        """The described workload profile (see ``WorkloadSpec.build``)."""
        return self.workload.build()

    def build_kernel(self):
        """The served kernel (see ``WorkloadSpec.build_kernel``)."""
        return self.workload.build_kernel()

    def admission_config(self) -> AdmissionConfig:
        """The admission section as a core ``AdmissionConfig``."""
        return self.admission.to_config()

    def memory_model(self) -> MemoryModel:
        """The memory section as a core ``MemoryModel``."""
        return self.memory.to_model()

    def runtime(self, units: Optional[Sequence] = None):
        """A :class:`~repro.core.runtime.CoexecutorRuntime` on this spec.

        Args:
            units: pre-built units overriding the ``units`` section.

        Returns:
            A configured (not yet started) runtime.
        """
        from ..core.runtime import CoexecutorRuntime

        return CoexecutorRuntime.from_spec(self, units=units)

    def engine(self, units: Optional[Sequence] = None):
        """A :class:`~repro.core.engine.CoexecEngine` on this spec.

        Args:
            units: pre-built units overriding the ``units`` section.

        Returns:
            A constructed (not yet started) engine.
        """
        from ..core.engine import CoexecEngine

        return CoexecEngine.from_spec(self, units=units)


class CoexecSpecBuilder:
    """Fluent construction of a :class:`CoexecSpec`.

    Every method returns the builder; :meth:`build` freezes and validates.
    Example::

        spec = (CoexecSpec.builder()
                .policy("work_stealing", chunks_per_unit=4)
                .units(count=2, speed_hints=(0.4, 0.6))
                .dist(0.4)
                .admission(wfq=True, max_inflight=64)
                .fuse(True)
                .build())
    """

    def __init__(self, base: CoexecSpec):
        self._spec = base

    def _update(self, **changes) -> "CoexecSpecBuilder":
        self._spec = self._spec.replace(**changes)
        return self

    def policy(self, name: str, **options) -> "CoexecSpecBuilder":
        """Select the scheduling policy (plus policy-specific options)."""
        sched = self._spec.scheduler.replace(policy=str(name))
        if options:
            sched = sched.with_options(**options)
        return self._update(scheduler=sched)

    def scheduler_options(self, **options) -> "CoexecSpecBuilder":
        """Merge policy options without changing the policy."""
        return self._update(
            scheduler=self._spec.scheduler.with_options(**options))

    def granularity(self, granularity: int) -> "CoexecSpecBuilder":
        """Set the package alignment (local work size)."""
        return self._update(
            scheduler=self._spec.scheduler.replace(
                granularity=int(granularity)))

    def units(self, count: Optional[int] = None,
              kinds: Sequence[str] = (),
              speed_hints: Sequence[float] = (),
              pipeline_depth: Optional[int] = None) -> "CoexecSpecBuilder":
        """Describe the Coexecution Units to build."""
        depth = self._spec.units.pipeline_depth if pipeline_depth is None \
            else int(pipeline_depth)
        return self._update(units=self._spec.units.replace(
            count=count, kinds=tuple(kinds),
            speed_hints=tuple(speed_hints), pipeline_depth=depth))

    def pipeline_depth(self, depth: int) -> "CoexecSpecBuilder":
        """Set how many packages a unit may have in flight at once."""
        return self._update(units=self._spec.units.replace(
            pipeline_depth=int(depth)))

    def dist(self, *shares: float) -> "CoexecSpecBuilder":
        """Computing-power hint: one first-unit share, or per-unit shares."""
        return self._update(
            units=self._spec.units.replace(dist=tuple(shares)))

    def memory(self, model: str) -> "CoexecSpecBuilder":
        """Select the memory model (``"usm"`` / ``"buffers"``)."""
        return self._update(memory=self._spec.memory.replace(
            model=str(model)))

    def admission(self, policy: Optional[str] = None, *,
                  wfq: Optional[bool] = None,
                  max_inflight: Optional[int] = None,
                  quantum: Optional[int] = None,
                  preempt: Optional[bool] = None) -> "CoexecSpecBuilder":
        """Configure cross-launch admission.

        Args:
            policy: explicit policy name (``"fifo"`` / ``"wfq"``).
            wfq: shorthand — ``True`` selects ``"wfq"``, ``False``
                ``"fifo"`` (ignored when ``policy`` is given).
            max_inflight: backpressure cap (``None`` leaves it unchanged).
            quantum: WFQ credit per round (``None`` leaves it unchanged).
            preempt: WFQ mid-launch credit reclamation — cap per-pull
                package sizes of over-served tenants (``None`` leaves it
                unchanged).

        Returns:
            The builder.
        """
        adm = self._spec.admission
        if policy is not None:
            adm = adm.replace(policy=str(policy))
        elif wfq is not None:
            adm = adm.replace(policy="wfq" if wfq else "fifo")
        if max_inflight is not None:
            adm = adm.replace(max_inflight=int(max_inflight))
        if quantum is not None:
            adm = adm.replace(quantum=int(quantum))
        if preempt is not None:
            adm = adm.replace(preempt=bool(preempt))
        return self._update(admission=adm)

    def slo(self, slo_ms: Optional[float], *,
            shed: Optional[bool] = None,
            shed_budget: Optional[float] = None,
            shed_rate: Optional[float] = None,
            edf_boost: Optional[float] = None) -> "CoexecSpecBuilder":
        """Configure deadline-aware admission (SLO + load shedding).

        Args:
            slo_ms: default per-launch deadline in milliseconds
                (``None`` clears it).
            shed: reject predicted deadline misses (``None`` leaves it
                unchanged).
            shed_budget: maximum rejected fraction of offered launches.
            shed_rate: service-rate estimate in items/s for the finish
                predictor.
            edf_boost: EDF credit-boost factor for deadline-ranked
                refills.

        Returns:
            The builder.
        """
        adm = self._spec.admission.replace(slo_ms=slo_ms)
        if shed is not None:
            adm = adm.replace(shed=bool(shed))
        if shed_budget is not None:
            adm = adm.replace(shed_budget=float(shed_budget))
        if shed_rate is not None:
            adm = adm.replace(shed_rate=float(shed_rate))
        if edf_boost is not None:
            adm = adm.replace(edf_boost=float(edf_boost))
        return self._update(admission=adm)

    def traffic(self, arrival: Optional[str] = None,
                **changes) -> "CoexecSpecBuilder":
        """Configure the open-loop arrival process.

        Args:
            arrival: process name (``"closed"`` / ``"poisson"`` /
                ``"burst"``).
            **changes: any other :class:`TrafficSpec` field.

        Returns:
            The builder.
        """
        tr = self._spec.traffic
        if arrival is not None:
            tr = tr.replace(arrival=str(arrival))
        if changes:
            tr = tr.replace(**changes)
        return self._update(traffic=tr)

    def cluster(self, on: bool = True, *,
                min_units: Optional[int] = None,
                max_units: Optional[int] = None,
                autoscale: Optional[bool] = None,
                failure_plan: Optional[str] = None,
                **changes) -> "CoexecSpecBuilder":
        """Configure the elastic cluster tier.

        Args:
            on: serve through the resizable pool.
            min_units: active floor (``None`` leaves it unchanged).
            max_units: provisioned ceiling.
            autoscale: resize on admission queue depth.
            failure_plan: path to a committed FailurePlan JSON.
            **changes: any other :class:`ClusterSpec` field.

        Returns:
            The builder.
        """
        cl = self._spec.cluster.replace(enabled=bool(on))
        if min_units is not None:
            cl = cl.replace(min_units=int(min_units))
        if max_units is not None:
            cl = cl.replace(max_units=int(max_units))
        if autoscale is not None:
            cl = cl.replace(autoscale=bool(autoscale))
        if failure_plan is not None:
            cl = cl.replace(failure_plan=str(failure_plan))
        if changes:
            cl = cl.replace(**changes)
        return self._update(cluster=cl)

    def fuse(self, on: bool = True, *,
             threshold: Optional[int] = None,
             limit: Optional[int] = None,
             wait_s: Optional[float] = None) -> "CoexecSpecBuilder":
        """Toggle launch fusion (and optionally tune its window/limits)."""
        adm = self._spec.admission.replace(fuse=bool(on))
        if threshold is not None:
            adm = adm.replace(fuse_threshold=int(threshold))
        if limit is not None:
            adm = adm.replace(fuse_limit=int(limit))
        if wait_s is not None:
            adm = adm.replace(fuse_wait_s=float(wait_s))
        return self._update(admission=adm)

    def workload(self, name: Optional[str] = None, *,
                 kernel: Optional[str] = None,
                 kernel_impl: Optional[str] = None,
                 items: Optional[int] = None,
                 requests: Optional[int] = None,
                 concurrent: Optional[int] = None,
                 tenants: Optional[int] = None,
                 size_scale: Optional[float] = None) -> "CoexecSpecBuilder":
        """Describe what to run and the serving shape."""
        wl = self._spec.workload
        if name is not None:
            wl = wl.replace(name=str(name))
        if kernel is not None:
            wl = wl.replace(kernel=str(kernel))
        if kernel_impl is not None:
            wl = wl.replace(kernel_impl=str(kernel_impl))
        if items is not None:
            wl = wl.replace(items=int(items))
        if requests is not None:
            wl = wl.replace(requests=int(requests))
        if concurrent is not None:
            wl = wl.replace(concurrent=int(concurrent))
        if tenants is not None:
            wl = wl.replace(tenants=int(tenants))
        if size_scale is not None:
            wl = wl.replace(size_scale=float(size_scale))
        return self._update(workload=wl)

    def build(self) -> CoexecSpec:
        """Freeze and validate the spec.

        Returns:
            The validated :class:`CoexecSpec`.

        Raises:
            KeyError: unknown policy or workload profile.
            ValueError: invalid options anywhere in the tree.
        """
        return self._spec.validate()
