"""Plugin registry for schedulers, workloads and kernels (`CoexecSpec` backend).

The paper's runtime selects its load balancer by name (Listing 1's
``<hg>`` template parameter); PR 1–2 rendered that as an if-chain inside
``make_scheduler`` plus a parallel string dispatch in ``paper_workload``.
This module replaces both with one declarative registry so third-party
policies and workload profiles register *without editing core*:

* :func:`register_scheduler` — a policy name, its factory, the exact
  option fields its constructor accepts, and an optional per-policy
  validation hook. Unknown/misspelled options raise :class:`ValueError`
  naming the offending key and the accepted fields (never silently
  ignored, never a bare ``TypeError`` from deep inside a constructor).
* :func:`register_workload` — a profile name and a factory returning
  ``(Workload, cpu_unit, gpu_unit)``, the contract of
  :func:`repro.core.workloads.paper_workload`.
* :func:`register_kernel` — a kernel name and a factory returning a
  typed :class:`~repro.core.dataplane.CoexecKernel` (per-argument
  SPLIT/BROADCAST semantics + output slot), optionally with a demo-input
  generator so benchmarks and parity tests can drive any registered
  kernel. This replaces the ``package_kernel`` if-chain of hand-written
  closures: the paper's six kernels register in
  :mod:`repro.kernels.ops`, third-party kernels register here without
  editing core.
* shorthand resolvers — pattern aliases such as ``dyn5`` → Dynamic with 5
  packages register alongside the policy they expand to.

This module deliberately imports nothing from ``repro.core``: core
modules import *it* and register their built-ins at import time, which is
what keeps the dependency graph acyclic (`api.registry` ← `core.*` ←
`api.spec` ← `api`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

__all__ = [
    "KernelPlugin", "SchedulerPlugin", "WorkloadPlugin",
    "register_kernel", "register_scheduler", "register_workload",
    "kernel_names", "scheduler_names", "workload_names",
    "resolve_scheduler", "build_kernel", "build_scheduler",
    "build_workload", "kernel_demo_inputs", "kernel_plugin",
    "workload_plugin", "validate_scheduler_options",
    "speed_hint_policies", "temporary_plugins",
]


def _normalize(policy: str) -> str:
    return str(policy).lower().replace("-", "_")


@dataclasses.dataclass(frozen=True)
class SchedulerPlugin:
    """One registered load-balancing policy.

    Attributes:
        name: canonical policy name (lower-case, underscores).
        factory: ``factory(total, num_units, **options) -> Scheduler``.
        fields: option names the factory accepts beyond the positional
            ``(total, num_units)`` pair — the validation whitelist.
        speed_hint: whether the factory takes a ``speeds`` computing-power
            hint (the paper's ``dist(0.35)``).
        shorthand: optional ``fn(key) -> dict | None`` that recognizes
            alias spellings (``dyn5``) and returns the implied options.
        validate: optional ``fn(options: dict) -> None`` hook run before
            construction; raise :class:`ValueError` to reject a spec.
    """

    name: str
    factory: Callable
    fields: tuple[str, ...] = ()
    speed_hint: bool = False
    shorthand: Optional[Callable[[str], Optional[dict]]] = None
    validate: Optional[Callable[[dict], None]] = None


@dataclasses.dataclass(frozen=True)
class WorkloadPlugin:
    """One registered workload profile.

    Attributes:
        name: canonical profile name.
        factory: ``factory(**options) -> (Workload, cpu, gpu)``.
        fields: option names the factory accepts (e.g. ``size_scale``).
        validate: optional ``fn(options: dict) -> None`` pre-build hook.
    """

    name: str
    factory: Callable
    fields: tuple[str, ...] = ()
    validate: Optional[Callable[[dict], None]] = None


@dataclasses.dataclass(frozen=True)
class KernelPlugin:
    """One registered co-executable kernel.

    Attributes:
        name: canonical kernel name.
        factory: ``factory(**options) -> CoexecKernel`` — must return the
            *same* kernel object for the same options (cache it), so the
            engine's jit cache and fusion coalescing key stay warm across
            builds.
        fields: option names the factory accepts (the validation
            whitelist, e.g. ``terms`` for the Taylor kernel).
        demo_inputs: optional ``fn(n, rng) -> list[np.ndarray]``
            generating representative inputs for an ``n``-item launch —
            what lets benchmarks and parity tests drive *every*
            registered kernel without per-kernel glue.
        validate: optional ``fn(options: dict) -> None`` pre-build hook.
    """

    name: str
    factory: Callable
    fields: tuple[str, ...] = ()
    demo_inputs: Optional[Callable] = None
    validate: Optional[Callable[[dict], None]] = None


_SCHEDULERS: dict[str, SchedulerPlugin] = {}
_WORKLOADS: dict[str, WorkloadPlugin] = {}
_KERNELS: dict[str, KernelPlugin] = {}


def register_scheduler(name: str, factory: Callable, *,
                       fields: tuple[str, ...] = (),
                       speed_hint: bool = False,
                       shorthand: Optional[Callable] = None,
                       validate: Optional[Callable] = None,
                       overwrite: bool = False) -> SchedulerPlugin:
    """Register a scheduling policy under ``name``.

    Args:
        name: policy name; normalized to lower-case with underscores.
        factory: ``factory(total, num_units, **options) -> Scheduler``.
        fields: accepted option names (``granularity`` is implied — every
            scheduler takes it).
        speed_hint: the factory accepts a ``speeds`` hint.
        shorthand: alias matcher, e.g. ``dynN`` → implied options.
        validate: per-policy option validation hook.
        overwrite: allow replacing an existing registration.

    Returns:
        The stored :class:`SchedulerPlugin`.

    Raises:
        ValueError: duplicate name without ``overwrite``.
    """
    key = _normalize(name)
    if key in _SCHEDULERS and not overwrite:
        raise ValueError(f"scheduler policy {key!r} is already registered; "
                         f"pass overwrite=True to replace it")
    plugin = SchedulerPlugin(key, factory,
                             fields=tuple(dict.fromkeys(
                                 (*fields, "granularity"))),
                             speed_hint=speed_hint, shorthand=shorthand,
                             validate=validate)
    _SCHEDULERS[key] = plugin
    return plugin


def register_workload(name: str, factory: Callable, *,
                      fields: tuple[str, ...] = (),
                      validate: Optional[Callable] = None,
                      overwrite: bool = False) -> WorkloadPlugin:
    """Register a workload profile under ``name``.

    Args:
        name: profile name; normalized like policy names.
        factory: ``factory(**options) -> (Workload, cpu, gpu)``.
        fields: accepted option names.
        validate: per-profile option validation hook.
        overwrite: allow replacing an existing registration.

    Returns:
        The stored :class:`WorkloadPlugin`.

    Raises:
        ValueError: duplicate name without ``overwrite``.
    """
    key = _normalize(name)
    if key in _WORKLOADS and not overwrite:
        raise ValueError(f"workload {key!r} is already registered; "
                         f"pass overwrite=True to replace it")
    plugin = WorkloadPlugin(key, factory, fields=tuple(fields),
                            validate=validate)
    _WORKLOADS[key] = plugin
    return plugin


def register_kernel(name: str, factory: Callable, *,
                    fields: tuple[str, ...] = (),
                    demo_inputs: Optional[Callable] = None,
                    validate: Optional[Callable] = None,
                    overwrite: bool = False) -> KernelPlugin:
    """Register a co-executable kernel under ``name``.

    Args:
        name: kernel name; normalized like policy names.
        factory: ``factory(**options) -> CoexecKernel`` (should memoize).
        fields: accepted option names.
        demo_inputs: ``fn(n, rng) -> list[np.ndarray]`` demo generator.
        validate: per-kernel option validation hook.
        overwrite: allow replacing an existing registration.

    Returns:
        The stored :class:`KernelPlugin`.

    Raises:
        ValueError: duplicate name without ``overwrite``.
    """
    key = _normalize(name)
    if key in _KERNELS and not overwrite:
        raise ValueError(f"kernel {key!r} is already registered; "
                         f"pass overwrite=True to replace it")
    plugin = KernelPlugin(key, factory, fields=tuple(fields),
                          demo_inputs=demo_inputs, validate=validate)
    _KERNELS[key] = plugin
    return plugin


def _ensure_builtins() -> None:
    """Make sure core's built-in policies/workloads have registered.

    Importing ``repro.core.scheduler`` / ``repro.core.workloads`` runs
    their registration side effects; lazy so `repro.api` alone works.
    """
    if not _SCHEDULERS:
        import repro.core.scheduler  # noqa: F401  (registers built-ins)
    if not _WORKLOADS:
        import repro.core.workloads  # noqa: F401


def _ensure_kernels() -> None:
    """Make sure the paper's built-in kernels have registered.

    Separate from :func:`_ensure_builtins` because the kernel package is
    the heavy import (Pallas modules); sim-only flows never pay it.
    """
    if not _KERNELS:
        import repro.kernels.ops  # noqa: F401  (registers built-ins)


def scheduler_names() -> tuple[str, ...]:
    """Registered policy names, sorted (shorthand aliases excluded)."""
    _ensure_builtins()
    return tuple(sorted(_SCHEDULERS))


def workload_names() -> tuple[str, ...]:
    """Registered workload profile names, sorted."""
    _ensure_builtins()
    return tuple(sorted(_WORKLOADS))


def kernel_names() -> tuple[str, ...]:
    """Registered co-executable kernel names, sorted."""
    _ensure_kernels()
    return tuple(sorted(_KERNELS))


def workload_plugin(name: str) -> WorkloadPlugin:
    """Look one workload plugin up by name.

    Args:
        name: registered profile name (case/hyphen-insensitive).

    Returns:
        The stored :class:`WorkloadPlugin`.

    Raises:
        KeyError: no workload of that name is registered.
    """
    _ensure_builtins()
    key = _normalize(name)
    plugin = _WORKLOADS.get(key)
    if plugin is None:
        raise KeyError(f"unknown workload {name!r}; "
                       f"choose from {sorted(_WORKLOADS)}")
    return plugin


def kernel_plugin(name: str) -> KernelPlugin:
    """Look one kernel plugin up by name.

    Args:
        name: registered kernel name (case/hyphen-insensitive).

    Returns:
        The stored :class:`KernelPlugin`.

    Raises:
        KeyError: no kernel of that name is registered.
    """
    _ensure_kernels()
    key = _normalize(name)
    plugin = _KERNELS.get(key)
    if plugin is None:
        raise KeyError(f"unknown kernel {name!r}; "
                       f"choose from {sorted(_KERNELS)}")
    return plugin


def build_kernel(name: str, *, impl: Optional[str] = None, **options):
    """Build (resolve) a registered kernel by name.

    Args:
        name: registered kernel name.
        impl: implementation variant to select (``"pallas"`` / ``"xla"``
            / ``"ref"``). ``None`` or ``"auto"`` leaves the choice to the
            kernel's backend-aware default. Anything else requires the
            plugin to declare an ``impl`` field — kernels without
            variants reject the request loudly instead of silently
            serving their only body.
        **options: kernel options (validated against declared fields).

    Returns:
        The kernel object the factory returns — for the paper's
        built-ins, a :class:`~repro.core.dataplane.CoexecKernel`.

    Raises:
        KeyError: unknown kernel.
        ValueError: unknown option key (named, with accepted fields), or
            an impl request against a kernel with no ``impl`` field.
    """
    plugin = kernel_plugin(name)
    if impl not in (None, "auto"):
        if "impl" not in plugin.fields:
            raise ValueError(
                f"kernel {plugin.name!r} has no implementation variants "
                f"(no 'impl' field); cannot select impl={impl!r}")
        options["impl"] = impl
    unknown = sorted(set(options) - set(plugin.fields))
    if unknown:
        raise ValueError(
            f"unknown option(s) {unknown!r} for kernel {plugin.name!r}; "
            f"accepted fields: {sorted(plugin.fields)}")
    if plugin.validate is not None:
        plugin.validate(dict(options))
    return plugin.factory(**options)


def kernel_demo_inputs(name: str, n: int, *, seed: int = 0) -> list:
    """Representative inputs for an ``n``-item launch of one kernel.

    Args:
        name: registered kernel name.
        n: launch index-space size.
        seed: RNG seed (vary it for independent requests).

    Returns:
        Host input arrays acceptable to the kernel's declared arguments.

    Raises:
        KeyError: unknown kernel.
        ValueError: the kernel registered no demo-input generator.
    """
    import numpy as np

    plugin = kernel_plugin(name)
    if plugin.demo_inputs is None:
        raise ValueError(f"kernel {plugin.name!r} registered no "
                         f"demo-input generator")
    return plugin.demo_inputs(int(n), np.random.default_rng(seed))


def speed_hint_policies() -> tuple[str, ...]:
    """Names of policies whose factory takes a ``speeds`` hint."""
    _ensure_builtins()
    return tuple(sorted(k for k, p in _SCHEDULERS.items() if p.speed_hint))


def resolve_scheduler(policy: str) -> tuple[SchedulerPlugin, dict]:
    """Look a policy name up, expanding shorthand aliases.

    Args:
        policy: registered name (case/hyphen-insensitive) or an alias a
            plugin's shorthand matcher recognizes (``dyn5``).

    Returns:
        ``(plugin, implied_options)`` — implied options come from the
        shorthand expansion and are overridable by explicit options.

    Raises:
        KeyError: no registered policy or shorthand matches.
    """
    _ensure_builtins()
    key = _normalize(policy)
    plugin = _SCHEDULERS.get(key)
    if plugin is not None:
        return plugin, {}
    for plugin in _SCHEDULERS.values():
        if plugin.shorthand is not None:
            implied = plugin.shorthand(key)
            if implied is not None:
                return plugin, dict(implied)
    raise KeyError(f"unknown scheduling policy {policy!r}; "
                   f"choose from {sorted(_SCHEDULERS)}")


def validate_scheduler_options(policy: str, options: dict) -> None:
    """Reject unknown/misspelled options for a policy, loudly.

    Args:
        policy: registered policy name or shorthand alias.
        options: candidate keyword options.

    Raises:
        KeyError: unknown policy.
        ValueError: an option the policy's factory does not accept — the
            message names the offending key and the accepted fields.
    """
    plugin, _ = resolve_scheduler(policy)
    unknown = sorted(set(options) - set(plugin.fields))
    if unknown:
        raise ValueError(
            f"unknown option(s) {unknown!r} for scheduling policy "
            f"{plugin.name!r}; accepted fields: {sorted(plugin.fields)}")
    if plugin.validate is not None:
        plugin.validate(dict(options))


def build_scheduler(policy: str, total: int, num_units: int, **options):
    """Build a load balancer by name — the registry-backed policy factory.

    The non-deprecated replacement for ``repro.core.make_scheduler``:
    exactly the same contract (``KeyError`` for unknown policies, the
    ``dynN`` shorthand, per-policy ``ValueError`` on bad sizes/speeds)
    plus strict option validation.

    Args:
        policy: registered policy name or shorthand alias.
        total: size of the 1-D index space to split.
        num_units: number of Coexecution Units the launch will run on.
        **options: policy-specific options (validated against the
            plugin's declared fields).

    Returns:
        A fresh one-shot scheduler for exactly one launch.

    Raises:
        KeyError: unknown policy.
        ValueError: unknown option key, or invalid sizes/speeds.
    """
    plugin, implied = resolve_scheduler(policy)
    merged = {**implied, **options}
    validate_scheduler_options(plugin.name, merged)
    return plugin.factory(total, num_units, **merged)


def build_workload(name: str, **options):
    """Build a registered workload profile by name.

    Args:
        name: registered profile name.
        **options: profile options (validated against declared fields).

    Returns:
        Whatever the profile factory returns — for the paper's built-ins,
        ``(Workload, cpu_unit, gpu_unit)``.

    Raises:
        KeyError: unknown profile.
        ValueError: unknown option key.
    """
    _ensure_builtins()
    key = _normalize(name)
    plugin = _WORKLOADS.get(key)
    if plugin is None:
        raise KeyError(f"unknown workload {name!r}; "
                       f"choose from {sorted(_WORKLOADS)}")
    unknown = sorted(set(options) - set(plugin.fields))
    if unknown:
        raise ValueError(
            f"unknown option(s) {unknown!r} for workload {plugin.name!r}; "
            f"accepted fields: {sorted(plugin.fields)}")
    if plugin.validate is not None:
        plugin.validate(dict(options))
    return plugin.factory(**options)


class temporary_plugins:
    """Context manager restoring the registry on exit (for tests/demos).

    Example::

        with temporary_plugins():
            register_scheduler("mine", MyScheduler, fields=("knob",))
            ...
        # "mine" is gone again
    """

    def __enter__(self) -> "temporary_plugins":
        self._sched = dict(_SCHEDULERS)
        self._work = dict(_WORKLOADS)
        self._kern = dict(_KERNELS)
        return self

    def __exit__(self, *exc) -> None:
        _SCHEDULERS.clear()
        _SCHEDULERS.update(self._sched)
        _WORKLOADS.clear()
        _WORKLOADS.update(self._work)
        _KERNELS.clear()
        _KERNELS.update(self._kern)


def _iter_scheduler_plugins() -> Iterator[SchedulerPlugin]:
    """Yield registered scheduler plugins (for the API snapshot tool)."""
    _ensure_builtins()
    yield from _SCHEDULERS.values()
