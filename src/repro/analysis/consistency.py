"""Spec/CLI/registry consistency pass (repo scope).

Three structural contracts, checked purely by parsing source — no
``repro`` imports, so the pass runs in milliseconds and without jax:

* ``con-spec-cli`` — every field of every ``*Spec`` dataclass in
  ``api/spec.py`` must carry ``field(metadata=_cli(...))``, which is what
  derives its CLI flag in ``serve`` / ``benchmarks.run``.
* ``con-spec-doc`` — every (section, field) pair reachable from
  ``CoexecSpec`` must have a schema row in ``docs/api.md``, and every
  schema row must point at a live field (no stale rows).
* ``con-plugin-fields`` — every ``register_scheduler`` /
  ``register_workload`` / ``register_kernel`` call whose factory is
  resolvable in the same module must declare only option ``fields`` the
  factory actually accepts (``granularity`` is implied for schedulers).

Factories the resolver cannot follow statically (attribute lookups,
multi-level indirection) are skipped rather than guessed at.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .core import Finding
from .registry import AnalysisPass, Rule, register_pass

__all__ = ["check_consistency", "check_spec_cli_docs",
           "check_plugin_registrations"]

_ROW_RE = re.compile(r"^\|\s*`(\w+)`\s*\|\s*`(\w+)`\s*\|")
_REGISTER_FUNCS = ("register_scheduler", "register_workload",
                   "register_kernel")

SPEC_PATH = "src/repro/api/spec.py"
DOC_PATH = "docs/api.md"
REGISTRY_GLOBS = ("src/repro/**/*.py",)


def _call_name(node: ast.expr) -> str:
    """Trailing name of a call target (``dataclasses.field`` -> field)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _is_cli_field(value: Optional[ast.expr]) -> bool:
    """True when a dataclass field value is ``field(metadata=_cli(...))``."""
    if not (isinstance(value, ast.Call)
            and _call_name(value.func) == "field"):
        return False
    for kw in value.keywords:
        if (kw.arg == "metadata" and isinstance(kw.value, ast.Call)
                and _call_name(kw.value.func) == "_cli"):
            return True
    return False


def _spec_classes(tree: ast.Module) -> Dict[str, ast.ClassDef]:
    return {node.name: node for node in tree.body
            if isinstance(node, ast.ClassDef)
            and node.name.endswith("Spec")}


def _class_fields(cls: ast.ClassDef) -> List[Tuple[str, int, bool]]:
    """(field name, line, has _cli metadata) for one spec dataclass."""
    out = []
    for stmt in cls.body:
        if (isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)):
            out.append((stmt.target.id, stmt.lineno,
                        _is_cli_field(stmt.value)))
    return out


def _coexec_sections(cls: ast.ClassDef) -> Dict[str, str]:
    """Map CoexecSpec section name -> sub-spec class name."""
    sections = {}
    for stmt in cls.body:
        if (isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
                and isinstance(stmt.annotation, ast.Name)
                and stmt.annotation.id.endswith("Spec")):
            sections[stmt.target.id] = stmt.annotation.id
    return sections


def check_spec_cli_docs(spec_path: "str | Path",
                        doc_path: "str | Path") -> List[Finding]:
    """Check the spec->CLI and spec<->docs/api.md schema contracts.

    Args:
        spec_path: Path to the ``*Spec`` dataclass module.
        doc_path: Path to the API doc holding the schema table.

    Returns:
        ``con-spec-cli`` and ``con-spec-doc`` findings.
    """
    spec_path, doc_path = Path(spec_path), Path(doc_path)
    tree = ast.parse(spec_path.read_text(encoding="utf-8"),
                     filename=str(spec_path))
    classes = _spec_classes(tree)
    findings: List[Finding] = []

    for name, cls in classes.items():
        if name == "CoexecSpec":
            continue
        for fname, line, has_cli in _class_fields(cls):
            if not has_cli:
                findings.append(Finding(
                    rule="con-spec-cli", path=str(spec_path), line=line,
                    message=(f"{name}.{fname} has no "
                             "field(metadata=_cli(...)) — it surfaces no "
                             "CLI flag"),
                    hint="declare the flag with the _cli helper"))

    coexec = classes.get("CoexecSpec")
    if coexec is None:
        return findings
    expected: Dict[Tuple[str, str], int] = {}
    for section, clsname in _coexec_sections(coexec).items():
        sub = classes.get(clsname)
        if sub is None:
            continue
        for fname, line, _ in _class_fields(sub):
            expected[(section, fname)] = line

    documented: Set[Tuple[str, str]] = set()
    doc_lines = doc_path.read_text(encoding="utf-8").splitlines()
    for i, line_text in enumerate(doc_lines, start=1):
        m = _ROW_RE.match(line_text.strip())
        if m is None:
            continue
        key = (m.group(1), m.group(2))
        documented.add(key)
        if key not in expected:
            findings.append(Finding(
                rule="con-spec-doc", path=str(doc_path), line=i,
                message=(f"schema row `{key[0]}.{key[1]}` has no matching "
                         "spec field"),
                hint="delete or rename the stale row"))
    for (section, fname), line in sorted(expected.items()):
        if (section, fname) not in documented:
            findings.append(Finding(
                rule="con-spec-doc", path=str(spec_path), line=line,
                message=(f"spec field `{section}.{fname}` has no schema "
                         f"row in {doc_path.name}"),
                hint="add a `| section | field | ... |` row to the table"))
    return findings


def _factory_params(module: ast.Module, node: ast.expr,
                    drop_positional: int = 0
                    ) -> Optional[Tuple[Set[str], bool]]:
    """Resolve a factory expression to (accepted params, has **kwargs).

    Follows same-module names one assignment deep (``f = wrap(inner)``)
    and ``functools.partial(f, <args>)`` calls.  Returns ``None`` when the
    factory cannot be resolved statically.
    """
    if isinstance(node, ast.Call):
        func_name = node.func.attr if isinstance(node.func, ast.Attribute) \
            else (node.func.id if isinstance(node.func, ast.Name) else "")
        if func_name == "partial" and node.args:
            return _factory_params(module, node.args[0],
                                   drop_positional=len(node.args) - 1)
        if node.args:  # wrapper(inner): assume pass-through to inner
            return _factory_params(module, node.args[0], drop_positional)
        return None
    if not isinstance(node, ast.Name):
        return None
    for stmt in module.body:
        if isinstance(stmt, ast.ClassDef) and stmt.name == node.id:
            for sub in stmt.body:
                if (isinstance(sub, ast.FunctionDef)
                        and sub.name == "__init__"):
                    return _signature(sub.args, drop_self=True,
                                      drop_positional=drop_positional)
            return None
        if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                and stmt.name == node.id):
            return _signature(stmt.args, drop_self=False,
                              drop_positional=drop_positional)
        if (isinstance(stmt, ast.Assign) and isinstance(stmt.value, ast.Call)
                and any(isinstance(t, ast.Name) and t.id == node.id
                        for t in stmt.targets)):
            return _factory_params(module, stmt.value, drop_positional)
    return None


def _signature(args: ast.arguments, drop_self: bool,
               drop_positional: int) -> Tuple[Set[str], bool]:
    positional = [a.arg for a in (*args.posonlyargs, *args.args)]
    if drop_self and positional and positional[0] == "self":
        positional = positional[1:]
    positional = positional[drop_positional:]
    accepted = set(positional) | {a.arg for a in args.kwonlyargs}
    return accepted, args.kwarg is not None


def _tuple_of_strings(node: Optional[ast.expr]) -> Optional[List[str]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
            else:
                return None
        return out
    return None


def check_plugin_registrations(paths: List[Path]) -> List[Finding]:
    """Check declared plugin ``fields`` against factory signatures.

    Args:
        paths: Python files to scan for ``register_*`` calls.

    Returns:
        ``con-plugin-fields`` findings for every declared option field the
        (statically resolvable) factory does not accept.
    """
    findings: List[Finding] = []
    for path in paths:
        tree = ast.parse(path.read_text(encoding="utf-8"),
                         filename=str(path))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else "")
            if name not in _REGISTER_FUNCS or len(node.args) < 2:
                continue
            declared: List[str] = []
            for kw in node.keywords:
                if kw.arg == "fields":
                    declared = _tuple_of_strings(kw.value) or []
            if name == "register_scheduler":
                declared = list(dict.fromkeys((*declared, "granularity")))
            resolved = _factory_params(tree, node.args[1])
            if resolved is None:
                continue
            accepted, has_kwargs = resolved
            if has_kwargs:
                continue
            for fname in declared:
                if fname not in accepted:
                    findings.append(Finding(
                        rule="con-plugin-fields", path=str(path),
                        line=node.lineno,
                        message=(f"{name} declares option field "
                                 f"{fname!r} the factory does not accept"),
                        hint="align fields=(...) with the builder "
                             "signature"))
    return findings


def check_consistency(root: Path) -> List[Finding]:
    """Run all three consistency contracts against a repo root.

    Args:
        root: Repository root containing ``src/`` and ``docs/``.

    Returns:
        All consistency findings (empty when the contracts hold).
    """
    findings: List[Finding] = []
    spec = root / SPEC_PATH
    doc = root / DOC_PATH
    if spec.exists() and doc.exists():
        findings.extend(check_spec_cli_docs(spec, doc))
    files: List[Path] = []
    for pattern in REGISTRY_GLOBS:
        files.extend(sorted(root.glob(pattern)))
    findings.extend(check_plugin_registrations(files))
    return findings


register_pass(AnalysisPass(
    name="consistency",
    checker=check_consistency,
    rules=(
        Rule("con-spec-cli", "spec field without a derived CLI flag"),
        Rule("con-spec-doc",
             "spec field missing from docs/api.md (or stale row)"),
        Rule("con-plugin-fields",
             "registry fields mismatch the factory signature"),
    ),
    description="spec fields <-> CLI flags <-> docs <-> registry builders",
    scope="repo",
))
