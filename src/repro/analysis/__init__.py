"""Static-analysis subsystem: AST invariant passes for the control plane.

``repro.analysis`` turns the repo's correctness conventions into checks
that run in milliseconds on every commit:

* **determinism** — no ambient clocks/RNG on the parity-critical decision
  path (clocks come from the injected ``Backend``, RNG from seeded
  generators).
* **locks** — ``# guarded-by: <lock>`` attributes of threaded classes are
  only touched under ``with self.<lock>:`` (a static race detector).
* **exceptions** — no silently swallowed failures, and never a dropped
  ``LaunchShed`` / ``LaunchWaitTimeout``.
* **consistency** — spec fields <-> CLI flags <-> ``docs/api.md`` rows
  <-> registry builder signatures stay structurally in sync.

Passes are plugins (:mod:`repro.analysis.registry`) sharing one reporting
core (:mod:`repro.analysis.core`); the driver is
``python -m repro.analysis`` and CI wraps it as
``scripts/check_static.py``.  This package never imports :mod:`repro.api`
or jax — it is pure stdlib and safe to run anywhere.
"""
from .core import SUPPRESSION_BUDGET, Finding, SourceFile, load_source, \
    run_passes
from .registry import AnalysisPass, Rule, all_rules, pass_names, \
    pass_plugin, register_pass, temporary_passes

__all__ = [
    "SUPPRESSION_BUDGET",
    "Finding",
    "SourceFile",
    "load_source",
    "run_passes",
    "AnalysisPass",
    "Rule",
    "register_pass",
    "pass_names",
    "pass_plugin",
    "all_rules",
    "temporary_passes",
]
