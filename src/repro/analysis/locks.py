"""Lock-discipline pass: a static race detector for threaded classes.

Convention (documented in ``docs/analysis.md``): a mutable attribute of a
threaded class declares its lock with a trailing comment on the line that
assigns it, e.g.::

    self._threads: list = []  # guarded-by: _cv

The pass then walks every *other* method of the class and flags any read
or write of a guarded attribute that is not lexically inside
``with self._cv:`` — unless the method's ``def`` line itself carries
``# guarded-by: _cv``, which documents a caller-holds-the-lock contract.

The special lock name ``caller`` marks a class as externally serialized
(the DES and the admission controller run under the engine's condition
variable); it documents the contract without enforcing a ``with`` block.

``__init__`` / ``__new__`` are exempt: construction happens-before any
other thread can see the object.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Sequence, Set

from .core import Finding, SourceFile
from .registry import AnalysisPass, Rule, register_pass

__all__ = ["check_locks"]

_GUARD_RE = re.compile(r"#.*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")

_EXEMPT_METHODS = ("__init__", "__new__")

LOCK_GLOBS = (
    "src/repro/core/engine.py",
    "src/repro/core/admission.py",
    "src/repro/core/exec.py",
    "src/repro/core/cluster.py",
)


def _guard_comment(lines: Sequence[str], lineno: int) -> "str | None":
    """Return the lock name from a ``# guarded-by:`` comment on a line."""
    if 1 <= lineno <= len(lines):
        m = _GUARD_RE.search(lines[lineno - 1])
        if m:
            return m.group(1)
    return None


def _self_attr(node: ast.AST) -> "str | None":
    """Return ``X`` when ``node`` is exactly ``self.X``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _collect_guards(cls: ast.ClassDef,
                    lines: Sequence[str]) -> Dict[str, str]:
    """Map guarded attribute name -> lock name for one class."""
    guards: Dict[str, str] = {}
    for node in ast.walk(cls):
        lock = None
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            lock = _guard_comment(lines, node.lineno)
            targets = list(node.targets)
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            lock = _guard_comment(lines, node.lineno)
            targets = [node.target]
        if not lock:
            continue
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                guards[attr] = lock
            elif isinstance(t, ast.Name):  # class-level attribute
                guards[t.id] = lock
    return guards


def _with_locks(node: ast.With) -> Set[str]:
    """Lock names acquired by a ``with self.X[, self.Y]:`` statement."""
    out: Set[str] = set()
    for item in node.items:
        attr = _self_attr(item.context_expr)
        if attr is not None:
            out.add(attr)
    return out


def _visit(node: ast.AST, held: Set[str], guards: Dict[str, str],
           path: str) -> Iterator[Finding]:
    """Yield findings for guarded self-attribute access outside its lock."""
    attr = _self_attr(node)
    if attr is not None and attr in guards:
        lock = guards[attr]
        if lock != "caller" and lock not in held:
            yield Finding(
                rule="lock-guard", path=path, line=node.lineno,
                message=(f"`self.{attr}` (guarded-by {lock}) accessed "
                         f"outside `with self.{lock}:`"),
                hint=(f"wrap in `with self.{lock}:` or annotate the "
                      f"method `# guarded-by: {lock}`"))
    if isinstance(node, ast.With):
        acquired = _with_locks(node)
        for item in node.items:
            yield from _visit(item.context_expr, held, guards, path)
        inner = held | acquired
        for child in node.body:
            yield from _visit(child, inner, guards, path)
        return
    if isinstance(node, ast.ClassDef):
        return  # nested classes declare their own discipline
    for child in ast.iter_child_nodes(node):
        yield from _visit(child, held, guards, path)


def check_locks(src: SourceFile) -> List[Finding]:
    """Check ``# guarded-by:`` discipline for every class in one file.

    Args:
        src: Parsed source file.

    Returns:
        One ``lock-guard`` finding per guarded attribute access that is
        neither under its ``with self.<lock>:`` block nor inside a method
        annotated as caller-holds.
    """
    findings: List[Finding] = []
    for cls in ast.walk(src.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        guards = _collect_guards(cls, src.lines)
        if not guards:
            continue
        for method in cls.body:
            if not isinstance(method, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                continue
            if method.name in _EXEMPT_METHODS:
                continue
            held: Set[str] = set()
            holds = _guard_comment(src.lines, method.lineno)
            if holds is not None:
                held.add(holds)
            for child in method.body:
                findings.extend(_visit(child, held, guards, src.path))
    return sorted(findings, key=lambda f: f.line)


register_pass(AnalysisPass(
    name="locks",
    checker=check_locks,
    rules=(
        Rule("lock-guard",
             "guarded-by attribute accessed outside its lock"),
    ),
    description="guarded-by attributes only touched under their lock",
    scope="file",
    default_globs=LOCK_GLOBS,
))
