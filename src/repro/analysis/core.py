"""Shared reporting core for the static-analysis passes.

This module owns the three pieces every pass shares:

* :class:`Finding` — one diagnostic: rule id, location, message, fix hint.
* :class:`SourceFile` — a parsed source file (text, split lines, AST) plus
  the ``# lint: disable=<rule>`` suppressions found in it.
* :func:`run_passes` — the driver loop: resolve which files each pass sees,
  invoke the checkers, apply suppressions, and enforce the suppression
  budget.

Suppression convention
----------------------
A trailing comment ``# lint: disable=rule-a,rule-b`` silences those rules
on that physical line only.  Each *used* suppression counts against a
repo-wide budget (:data:`SUPPRESSION_BUDGET`); exceeding the budget is
itself a finding (``suppression-budget``), and a suppression that silences
nothing is reported as ``unused-suppression``.  Neither meta rule can be
suppressed.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "SUPPRESSION_BUDGET",
    "Finding",
    "SourceFile",
    "load_source",
    "run_passes",
]

# Repo-wide ceiling on *used* `# lint: disable=` comments.  Deliberately
# small: suppressions are an escape hatch, not a lifestyle.
SUPPRESSION_BUDGET = 10

# Rules that the reporting core itself emits; they can never be suppressed.
_META_RULES = ("unused-suppression", "suppression-budget")

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\- ]+)")


@dataclass(frozen=True)
class Finding:
    """One diagnostic produced by an analysis pass.

    Attributes:
        rule: Rule id, e.g. ``"det-wall-clock"``.
        path: File the finding points at (repo-relative when possible).
        line: 1-based line number.
        message: What is wrong, in one sentence.
        hint: How to fix it, in one sentence.
    """

    rule: str
    path: str
    line: int
    message: str
    hint: str = ""

    def render(self) -> str:
        """Format as ``path:line: [rule] message (hint)`` for terminals."""
        text = f"{self.path}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            text += f" (hint: {self.hint})"
        return text


@dataclass(frozen=True)
class SourceFile:
    """A parsed Python source file handed to file-scope checkers.

    Attributes:
        path: Path the file was read from (string, as reported in findings).
        text: Full source text.
        lines: ``text.splitlines()``.
        tree: Parsed ``ast.Module``.
        suppressions: Mapping of 1-based line number to the set of rule ids
            disabled on that line via ``# lint: disable=...``.
    """

    path: str
    text: str
    lines: Tuple[str, ...]
    tree: ast.Module
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)


def _parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """Extract per-line rule suppressions from trailing lint comments."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m is None:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if rules:
            out[i] = rules
    return out


def load_source(path: "str | Path") -> SourceFile:
    """Read and parse one Python file into a :class:`SourceFile`.

    Args:
        path: File to load; must contain syntactically valid Python.

    Returns:
        The parsed :class:`SourceFile` with suppressions extracted.
    """
    p = Path(path)
    text = p.read_text(encoding="utf-8")
    lines = tuple(text.splitlines())
    tree = ast.parse(text, filename=str(p))
    return SourceFile(path=str(p), text=text, lines=lines, tree=tree,
                      suppressions=_parse_suppressions(lines))


def _apply_suppressions(
    findings: Iterable[Finding],
    src: SourceFile,
) -> Tuple[List[Finding], Set[Tuple[int, str]]]:
    """Split findings into (kept, used-suppression keys) for one file."""
    kept: List[Finding] = []
    used: Set[Tuple[int, str]] = set()
    for f in findings:
        disabled = src.suppressions.get(f.line, set())
        if f.rule in disabled and f.rule not in _META_RULES:
            used.add((f.line, f.rule))
        else:
            kept.append(f)
    return kept, used


def run_passes(
    passes: Sequence,
    root: "str | Path",
    paths: Optional[Sequence[str]] = None,
    budget: int = SUPPRESSION_BUDGET,
) -> List[Finding]:
    """Run analysis passes over a repo and return surviving findings.

    File-scope passes run per matching file with suppressions applied;
    repo-scope passes run once against ``root`` and are not suppressible
    (they point at cross-file contracts, not single lines of code).

    Args:
        passes: ``AnalysisPass`` plugins (see :mod:`repro.analysis.registry`).
        root: Repository root all ``default_globs`` resolve against.
        paths: Optional explicit file list overriding every file-scope
            pass's default globs (each pass still sees only ``.py`` files).
        budget: Maximum number of used suppressions before the
            ``suppression-budget`` meta finding fires.

    Returns:
        All findings that survived suppression, ordered by pass then file.
    """
    rootp = Path(root)
    findings: List[Finding] = []
    used_total: List[Tuple[str, int, str]] = []
    seen_files: Dict[str, SourceFile] = {}

    for p in passes:
        if p.scope == "repo":
            findings.extend(p.checker(rootp))
            continue
        if paths:
            files = [Path(x) for x in paths if str(x).endswith(".py")]
        else:
            files = []
            for pattern in p.default_globs:
                files.extend(sorted(rootp.glob(pattern)))
        for fp in files:
            key = str(fp)
            src = seen_files.get(key)
            if src is None:
                src = load_source(fp)
                seen_files[key] = src
            kept, used = _apply_suppressions(p.checker(src), src)
            findings.extend(kept)
            used_total.extend((key, line, rule) for line, rule in used)

    # Meta rule 1: suppressions that silenced nothing are themselves stale.
    used_by_file: Dict[str, Set[Tuple[int, str]]] = {}
    for key, line, rule in used_total:
        used_by_file.setdefault(key, set()).add((line, rule))
    checked_rules: Set[str] = set()
    for p in passes:
        checked_rules.update(r.id for r in p.rules)
    for key, src in sorted(seen_files.items()):
        used_here = used_by_file.get(key, set())
        for line, rules in sorted(src.suppressions.items()):
            for rule in sorted(rules):
                if rule in checked_rules and (line, rule) not in used_here:
                    findings.append(Finding(
                        rule="unused-suppression", path=key, line=line,
                        message=f"suppression for '{rule}' matches nothing",
                        hint="delete the stale `# lint: disable` comment"))

    # Meta rule 2: the repo-wide budget of used suppressions.
    if len(used_total) > budget:
        key, line, _ = used_total[budget]
        findings.append(Finding(
            rule="suppression-budget", path=key, line=line,
            message=(f"{len(used_total)} suppressions in use exceeds "
                     f"the budget of {budget}"),
            hint="fix the underlying findings instead of suppressing"))
    return findings
