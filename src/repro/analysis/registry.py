"""Plugin registry for static-analysis passes.

Mirrors the scheduler/workload/kernel registries in
:mod:`repro.api.registry`: passes are frozen dataclass plugins in a
module-level table, registered by name, with a context manager for
scoped test registrations.  The built-in passes self-register lazily on
first lookup so importing this module stays cheap and cycle-free.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, Tuple

__all__ = [
    "Rule",
    "AnalysisPass",
    "register_pass",
    "pass_names",
    "pass_plugin",
    "all_rules",
    "temporary_passes",
]


@dataclass(frozen=True)
class Rule:
    """One rule a pass can emit.

    Attributes:
        id: Stable rule id used in findings and suppression comments.
        summary: One-line description for ``--list`` output and docs.
    """

    id: str
    summary: str


@dataclass(frozen=True)
class AnalysisPass:
    """A registered static-analysis pass.

    Attributes:
        name: Registry key, e.g. ``"determinism"``.
        checker: For ``scope="file"`` a callable taking a
            :class:`repro.analysis.core.SourceFile` and yielding findings;
            for ``scope="repo"`` a callable taking the repo root ``Path``.
        rules: The rules this pass may emit.
        description: One-line description for ``--list`` output.
        scope: ``"file"`` (runs per source file, suppressible) or
            ``"repo"`` (runs once per repository, not suppressible).
        default_globs: Repo-relative globs selecting the files a
            file-scope pass analyses when no explicit paths are given.
    """

    name: str
    checker: Callable
    rules: Tuple[Rule, ...]
    description: str
    scope: str = "file"
    default_globs: Tuple[str, ...] = field(default_factory=tuple)


_PASSES: Dict[str, AnalysisPass] = {}
_BUILTINS_LOADED = False


def register_pass(plugin: AnalysisPass, overwrite: bool = False) -> None:
    """Register an analysis pass under its name.

    Args:
        plugin: The pass to register.
        overwrite: Allow replacing an existing pass of the same name.

    Raises:
        ValueError: If the name is taken and ``overwrite`` is false, or the
            scope is not ``"file"``/``"repo"``.
    """
    if plugin.scope not in ("file", "repo"):
        raise ValueError(f"unknown pass scope: {plugin.scope!r}")
    if plugin.name in _PASSES and not overwrite:
        raise ValueError(f"analysis pass already registered: {plugin.name}")
    _PASSES[plugin.name] = plugin


def _ensure_builtins() -> None:
    """Import the built-in pass modules once (they self-register)."""
    global _BUILTINS_LOADED
    if _BUILTINS_LOADED:
        return
    _BUILTINS_LOADED = True
    from . import consistency, determinism, exceptions, locks  # noqa: F401


def pass_names() -> Tuple[str, ...]:
    """Return registered pass names in registration order."""
    _ensure_builtins()
    return tuple(_PASSES)


def pass_plugin(name: str) -> AnalysisPass:
    """Look up one pass by name.

    Args:
        name: Registry key of the pass.

    Returns:
        The registered :class:`AnalysisPass`.

    Raises:
        KeyError: If no pass of that name is registered.
    """
    _ensure_builtins()
    if name not in _PASSES:
        known = ", ".join(sorted(_PASSES))
        raise KeyError(f"unknown analysis pass {name!r} (known: {known})")
    return _PASSES[name]


def all_rules() -> Tuple[Rule, ...]:
    """Return every rule declared by every registered pass."""
    _ensure_builtins()
    out = []
    for p in _PASSES.values():
        out.extend(p.rules)
    return tuple(out)


@contextmanager
def temporary_passes() -> Iterator[None]:
    """Scope pass registrations: restores the table on exit.

    Mirrors ``repro.api.registry.temporary_plugins`` for tests that
    register throwaway passes.
    """
    _ensure_builtins()
    saved = dict(_PASSES)
    try:
        yield
    finally:
        _PASSES.clear()
        _PASSES.update(saved)
