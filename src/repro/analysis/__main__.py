"""Command-line driver: ``python -m repro.analysis``.

Runs the registered static-analysis passes over the repository and
prints one line per finding (``path:line: [rule] message (hint)``) plus a
summary.  Exit status 0 means clean, 1 means findings survived
suppression.  ``--format json`` / ``--report`` emit the machine-readable
report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .core import SUPPRESSION_BUDGET, Finding, run_passes
from .registry import pass_names, pass_plugin

__all__ = ["main", "run", "report_dict"]


def run(root: "str | Path", select: Optional[List[str]] = None,
        paths: Optional[List[str]] = None,
        budget: int = SUPPRESSION_BUDGET) -> List[Finding]:
    """Run the selected passes and return their findings.

    Args:
        root: Repository root.
        select: Pass names to run (default: all registered passes).
        paths: Explicit files for file-scope passes (default: each pass's
            own globs).
        budget: Suppression budget forwarded to the reporting core.

    Returns:
        Findings surviving suppression, in pass order.
    """
    names = select or list(pass_names())
    passes = [pass_plugin(n) for n in names]
    return run_passes(passes, root, paths=paths, budget=budget)


def report_dict(findings: List[Finding], passes: List[str]) -> dict:
    """Build the JSON report structure written by ``--report``.

    Args:
        findings: Findings to serialize.
        passes: Names of the passes that ran.

    Returns:
        A JSON-serializable dict with schema version, pass list, counts,
        and one record per finding.
    """
    return {
        "schema_version": 1,
        "passes": list(passes),
        "count": len(findings),
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message, "hint": f.hint}
            for f in findings
        ],
    }


def main(argv: Optional[List[str]] = None) -> int:
    """Entry point for ``python -m repro.analysis``.

    Args:
        argv: Argument list (default ``sys.argv[1:]``).

    Returns:
        Process exit status: 0 when clean, 1 when findings remain.
    """
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the control plane.")
    ap.add_argument("paths", nargs="*",
                    help="explicit files for file-scope passes "
                         "(default: each pass's configured globs)")
    ap.add_argument("--select", action="append", metavar="PASS",
                    help="run only this pass (repeatable)")
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="stdout format")
    ap.add_argument("--report", metavar="PATH",
                    help="also write the JSON report to this file")
    ap.add_argument("--list", action="store_true",
                    help="list registered passes and rules, then exit")
    args = ap.parse_args(argv)

    if args.list:
        for name in pass_names():
            plugin = pass_plugin(name)
            print(f"{name}: {plugin.description} [{plugin.scope}]")
            for rule in plugin.rules:
                print(f"  {rule.id}: {rule.summary}")
        return 0

    names = args.select or list(pass_names())
    findings = run(args.root, select=names, paths=args.paths or None)
    report = report_dict(findings, names)
    if args.report:
        Path(args.report).write_text(json.dumps(report, indent=2) + "\n",
                                     encoding="utf-8")
    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f.render())
        ran = ", ".join(names)
        if findings:
            print(f"repro.analysis: {len(findings)} finding(s) from "
                  f"passes: {ran}", file=sys.stderr)
        else:
            print(f"repro.analysis: OK (passes: {ran})")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
