"""Exception-hygiene pass: no silently swallowed failures.

Three rules, scoped to the control plane (``core/``) and API surface
(``api/``):

* ``exc-bare-except`` — a bare ``except:`` that does not re-raise.
* ``exc-broad-except`` — ``except Exception`` / ``except BaseException``
  whose body neither re-raises, nor uses the bound exception (``as e``),
  nor calls a logging method; failures must at least be observable.
* ``exc-swallowed-control`` — catching the control-flow launch outcomes
  (``LaunchShed``, ``LaunchWaitTimeout``, ``AdmissionFull``) without
  re-raising or inspecting them; these carry admission decisions and must
  never be dropped on the floor.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Sequence

from .core import Finding, SourceFile
from .registry import AnalysisPass, Rule, register_pass

__all__ = ["check_exceptions"]

_BROAD = {"Exception", "BaseException"}
_CONTROL = {"LaunchShed", "LaunchWaitTimeout", "AdmissionFull"}
_LOG_METHODS = {"exception", "warning", "warn", "error", "critical", "log",
                "debug", "info"}

EXCEPTION_GLOBS = (
    "src/repro/core/*.py",
    "src/repro/api/*.py",
)


def _type_names(node: "ast.AST | None") -> List[str]:
    """Flatten an except clause's type expression into bare class names."""
    if node is None:
        return []
    if isinstance(node, ast.Tuple):
        out: List[str] = []
        for elt in node.elts:
            out.extend(_type_names(elt))
        return out
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    return []


def _has_raise(body: Sequence[ast.stmt]) -> bool:
    return any(isinstance(n, ast.Raise)
               for stmt in body for n in ast.walk(stmt))


def _uses_name(body: Sequence[ast.stmt], name: "str | None") -> bool:
    if name is None:
        return False
    return any(isinstance(n, ast.Name) and n.id == name
               for stmt in body for n in ast.walk(stmt))


def _has_logging(body: Sequence[ast.stmt]) -> bool:
    for stmt in body:
        for n in ast.walk(stmt):
            if not isinstance(n, ast.Call):
                continue
            func = n.func
            if isinstance(func, ast.Attribute) and func.attr in _LOG_METHODS:
                return True
            if isinstance(func, ast.Name) and func.id in _LOG_METHODS:
                return True
    return False


def _check_handler(h: ast.ExceptHandler) -> Iterator[Finding]:
    names = _type_names(h.type)
    raises = _has_raise(h.body)
    uses = _uses_name(h.body, h.name)
    logs = _has_logging(h.body)
    if h.type is None and not raises:
        yield Finding(
            rule="exc-bare-except", path="", line=h.lineno,
            message="bare `except:` without re-raise",
            hint="catch a specific exception type, or re-raise")
        return
    swallowed = sorted(_CONTROL.intersection(names))
    if swallowed and not (raises or uses):
        kinds = ", ".join(swallowed)
        yield Finding(
            rule="exc-swallowed-control", path="", line=h.lineno,
            message=f"launch-control exception(s) {kinds} swallowed",
            hint="re-raise, or record the decision the exception carries")
        return
    if _BROAD.intersection(names) and not (raises or uses or logs):
        yield Finding(
            rule="exc-broad-except", path="", line=h.lineno,
            message="broad `except` that neither re-raises, logs, nor "
                    "inspects the exception",
            hint="narrow the type, or log/re-raise the failure")


def check_exceptions(src: SourceFile) -> List[Finding]:
    """Run the exception-hygiene rules over one source file.

    Args:
        src: Parsed source file.

    Returns:
        Findings for every bare, over-broad, or control-flow-swallowing
        handler.
    """
    findings: List[Finding] = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ExceptHandler):
            for f in _check_handler(node):
                findings.append(Finding(
                    rule=f.rule, path=src.path, line=f.line,
                    message=f.message, hint=f.hint))
    return sorted(findings, key=lambda f: f.line)


register_pass(AnalysisPass(
    name="exceptions",
    checker=check_exceptions,
    rules=(
        Rule("exc-bare-except", "bare except without re-raise"),
        Rule("exc-broad-except",
             "except Exception with no re-raise/log/inspection"),
        Rule("exc-swallowed-control",
             "LaunchShed/LaunchWaitTimeout/AdmissionFull dropped"),
    ),
    description="no silently swallowed exceptions in core/ and api/",
    scope="file",
    default_globs=EXCEPTION_GLOBS,
))
