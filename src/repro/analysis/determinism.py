"""Determinism pass: no ambient clocks or RNG on the decision path.

The control plane's parity guarantees (real-vs-sim lockstep, exact-once
re-issue) hold only if every decision module takes time from the injected
``Backend`` clock and randomness from an explicitly seeded generator.
This pass flags the ambient alternatives:

* ``det-wall-clock`` — calls into :mod:`time` (``time``, ``perf_counter``,
  ``monotonic``, ``process_time`` and their ``_ns`` variants).
* ``det-unseeded-rng`` — any import of stdlib :mod:`random` (global,
  unseeded state) and ``numpy.random`` calls other than
  ``default_rng(<seed>)`` with an explicit argument.
* ``det-naive-datetime`` — argless ``datetime.now()`` / ``utcnow()`` /
  ``today()``.
* ``det-set-iteration`` — iterating a syntactic set literal,
  comprehension, or ``set(...)`` call, whose order is hash-randomized
  across processes (``sorted(set(...))`` is fine).

The set-iteration check is syntactic only: a set stored in a variable and
iterated later is not tracked.  That keeps the pass dependency-free and
false-positive-poor; the convention is to sort at the point of iteration.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Set

from .core import Finding, SourceFile
from .registry import AnalysisPass, Rule, register_pass

__all__ = ["check_determinism"]

_TIME_FUNCS = {
    "time", "perf_counter", "monotonic", "process_time",
    "time_ns", "perf_counter_ns", "monotonic_ns", "process_time_ns",
}
_DT_FUNCS = {"now", "utcnow", "today"}

DECISION_GLOBS = (
    "src/repro/core/exec.py",
    "src/repro/core/admission.py",
    "src/repro/core/traffic.py",
    "src/repro/core/sim.py",
    "src/repro/core/cluster.py",
)


def _is_set_expr(node: ast.AST) -> bool:
    """True for a syntactic set: literal, set comprehension, or set(...)."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "set")


class _Aliases:
    """Import aliases relevant to the determinism rules in one file."""

    def __init__(self) -> None:
        self.time_modules: Set[str] = set()
        self.time_funcs: Set[str] = set()
        self.numpy_modules: Set[str] = set()
        self.numpy_random: Set[str] = set()
        self.default_rng: Set[str] = set()
        self.datetime_modules: Set[str] = set()
        self.datetime_classes: Set[str] = set()

    def collect(self, tree: ast.Module) -> List[Finding]:
        """Walk imports; return findings for stdlib ``random`` imports."""
        findings: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name
                    if alias.name == "time":
                        self.time_modules.add(name)
                    elif alias.name == "numpy":
                        self.numpy_modules.add(name)
                    elif alias.name == "numpy.random":
                        self.numpy_random.add(name)
                    elif alias.name == "datetime":
                        self.datetime_modules.add(name)
                    elif alias.name == "random":
                        findings.append(_rng_import(node))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "time":
                    for alias in node.names:
                        if alias.name in _TIME_FUNCS:
                            self.time_funcs.add(alias.asname or alias.name)
                elif node.module == "random":
                    findings.append(_rng_import(node))
                elif node.module == "numpy":
                    for alias in node.names:
                        if alias.name == "random":
                            self.numpy_random.add(alias.asname or alias.name)
                elif node.module == "numpy.random":
                    for alias in node.names:
                        if alias.name == "default_rng":
                            self.default_rng.add(alias.asname or alias.name)
                elif node.module == "datetime":
                    for alias in node.names:
                        if alias.name in ("datetime", "date"):
                            self.datetime_classes.add(
                                alias.asname or alias.name)
        return findings


def _rng_import(node: ast.AST) -> Finding:
    return Finding(
        rule="det-unseeded-rng", path="", line=node.lineno,
        message="stdlib `random` (global unseeded state) on a decision path",
        hint="use numpy.random.default_rng(seed) threaded through the spec")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``np.random`` -> str)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _check_call(call: ast.Call, al: _Aliases) -> Iterator[Finding]:
    func = call.func
    if isinstance(func, ast.Name):
        if func.id in al.time_funcs:
            yield Finding(
                rule="det-wall-clock", path="", line=call.lineno,
                message=f"wall-clock call `{func.id}()` on a decision path",
                hint="read time from the injected Backend clock")
        elif func.id in al.default_rng and not (call.args or call.keywords):
            yield Finding(
                rule="det-unseeded-rng", path="", line=call.lineno,
                message="`default_rng()` without an explicit seed",
                hint="pass the spec seed: default_rng(seed)")
        return
    if not isinstance(func, ast.Attribute):
        return
    dotted = _dotted(func)
    base, _, attr = dotted.rpartition(".")
    if base in al.time_modules and attr in _TIME_FUNCS:
        yield Finding(
            rule="det-wall-clock", path="", line=call.lineno,
            message=f"wall-clock call `{dotted}()` on a decision path",
            hint="read time from the injected Backend clock")
        return
    np_random = (base in al.numpy_random
                 or (base.count(".") == 1
                     and base.split(".")[0] in al.numpy_modules
                     and base.split(".")[1] == "random"))
    if np_random:
        if attr == "default_rng":
            if not (call.args or call.keywords):
                yield Finding(
                    rule="det-unseeded-rng", path="", line=call.lineno,
                    message="`default_rng()` without an explicit seed",
                    hint="pass the spec seed: default_rng(seed)")
        else:
            yield Finding(
                rule="det-unseeded-rng", path="", line=call.lineno,
                message=(f"global numpy RNG call `{dotted}()` on a "
                         "decision path"),
                hint="use a seeded default_rng(seed) Generator instead")
        return
    if attr in _DT_FUNCS and not (call.args or call.keywords):
        root = dotted.split(".")[0]
        dt_class = (base in al.datetime_classes
                    or (root in al.datetime_modules
                        and base.endswith((".datetime", ".date"))))
        if dt_class:
            yield Finding(
                rule="det-naive-datetime", path="", line=call.lineno,
                message=f"ambient `{dotted}()` on a decision path",
                hint="derive timestamps from the Backend clock or the spec")


def _check_set_iteration(tree: ast.Module) -> Iterator[Finding]:
    def flag(node: ast.AST) -> Finding:
        return Finding(
            rule="det-set-iteration", path="", line=node.lineno,
            message="iteration over a set has hash-randomized order",
            hint="wrap in sorted(...) before iterating")

    for node in ast.walk(tree):
        if isinstance(node, ast.For) and _is_set_expr(node.iter):
            yield flag(node.iter)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    yield flag(gen.iter)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Name)
              and node.func.id in ("list", "tuple", "enumerate")
              and node.args and _is_set_expr(node.args[0])):
            yield flag(node.args[0])


def check_determinism(src: SourceFile) -> List[Finding]:
    """Run the determinism rules over one decision-path source file.

    Args:
        src: Parsed source file.

    Returns:
        Findings (with ``path`` filled in) for every ambient clock, RNG,
        naive datetime, and unordered set iteration.
    """
    aliases = _Aliases()
    findings = aliases.collect(src.tree)
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call):
            findings.extend(_check_call(node, aliases))
    findings.extend(_check_set_iteration(src.tree))
    out = [Finding(rule=f.rule, path=src.path, line=f.line,
                   message=f.message, hint=f.hint) for f in findings]
    return sorted(out, key=lambda f: (f.line, f.rule))


register_pass(AnalysisPass(
    name="determinism",
    checker=check_determinism,
    rules=(
        Rule("det-wall-clock",
             "time.time/perf_counter/... on a decision path"),
        Rule("det-unseeded-rng",
             "stdlib random or unseeded numpy RNG on a decision path"),
        Rule("det-naive-datetime",
             "argless datetime.now/utcnow/today on a decision path"),
        Rule("det-set-iteration",
             "iteration over a syntactic set (hash-randomized order)"),
    ),
    description="no ambient clocks/RNG in parity-critical decision code",
    scope="file",
    default_globs=DECISION_GLOBS,
))
