from .analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                       collective_bytes)
from .flops import cell_bytes, cell_flops, forward_flops_per_token

__all__ = ["HBM_BW", "LINK_BW", "PEAK_FLOPS", "Roofline", "cell_bytes",
           "cell_flops", "collective_bytes", "forward_flops_per_token"]
