"""Analytic FLOPs/bytes accounting per (arch × shape) cell.

XLA's cost_analysis counts while-loop bodies once (verified on this
backend), so scan-based models under-report by the layer count. Rather
than trusting a broken counter, the roofline uses first-principles
accounting from the config — matmul FLOPs are exact (2·m·n·k), attention
includes the quadratic term with causal/window correction, SSD/mLSTM use
the chunked-form math, and the train-step factor reflects the remat
policy (fwd+bwd = 3×, +1 fwd when remat is on ⇒ 4×). The HLO text is
still the source of truth for the *collective* schedule (analysis.py),
with xscan[N] loop multipliers.

Byte accounting (HBM traffic, per device):
  train   : 3 passes over the sharded params/grads/adam state (read
            p/m/v + write p/m/v ≈ 12 B/param f32) + activation traffic
            (ACT_RW rounds of B·T·d bf16 per layer) + logit traffic.
  prefill : 1 pass over sharded params + activation writes.
  decode  : 1 pass over sharded params + 1 pass over the sharded cache
            (the canonical decode bound) per token.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeConfig

ACT_RW_TRAIN = 24      # activation tensor r/w rounds per layer (fwd+bwd+remat)
ACT_RW_FWD = 8


def _attn_ctx(cfg: ModelConfig, T: int, decode: bool) -> float:
    """Average attended context length per query token."""
    full = T if decode else T / 2.0          # causal average
    if cfg.window is not None:
        full = min(full, cfg.window)
    return full


def _dense_block_flops_token(cfg: ModelConfig) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qkv = 2.0 * d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
    out = 2.0 * cfg.num_heads * hd * d
    if cfg.family == "moe":
        ffn = 6.0 * d * cfg.moe_d_ff * cfg.top_k + 2.0 * d * cfg.num_experts
    else:
        ffn = 6.0 * d * cfg.d_ff
    return qkv + out + ffn


def _attn_flops_token(cfg: ModelConfig, ctx: float) -> float:
    # QKᵀ + PV over the attended context
    return 4.0 * cfg.num_heads * cfg.resolved_head_dim * ctx


def _mamba_block_flops_token(cfg: ModelConfig, chunk: int = 128) -> float:
    d = cfg.d_model
    di = 2 * d
    s = cfg.ssm_state
    hd = cfg.ssm_head_dim
    H = di // hd
    proj = 2.0 * d * (2 * di + 2 * s + H) + 2.0 * di * d
    conv = 2.0 * 4 * (di + 2 * s)
    # chunked SSD per token per head: intra-chunk scores + AV rows over the
    # chunk, inter-chunk read + state update over (s × hd)
    ssd = H * (2.0 * chunk * (s + hd) + 4.0 * s * hd)
    return proj + conv + ssd


def _mlstm_block_flops_token(cfg: ModelConfig, chunk: int = 128) -> float:
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    hd = di // H
    proj = 2.0 * d * di * 2 + 2.0 * di * d          # up, gate, down
    qkv = 3 * 2.0 * di * di + 2.0 * di * 2 * H
    la = H * (2.0 * chunk * (hd + hd) + 4.0 * hd * hd)
    return proj + qkv + la


def _slstm_block_flops_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    hd = d // cfg.num_heads
    return 4 * 2.0 * d * d + 4 * 2.0 * d * hd + 2.0 * d * d


def forward_flops_per_token(cfg: ModelConfig, T: int,
                            decode: bool = False) -> float:
    """Layer-stack + head FLOPs for one token of context length T."""
    ctx = _attn_ctx(cfg, T, decode)
    if cfg.family in ("dense", "moe", "vlm"):
        per_block = _dense_block_flops_token(cfg) + \
            _attn_flops_token(cfg, ctx)
        stack = cfg.num_layers * per_block
    elif cfg.family == "encdec":
        dec_block = _dense_block_flops_token(cfg) + \
            _attn_flops_token(cfg, ctx) + \
            2.0 * cfg.d_model * cfg.resolved_head_dim * cfg.num_heads + \
            _attn_flops_token(cfg, cfg.encoder_seq)      # cross-attn
        stack = cfg.num_layers * dec_block
    elif cfg.family == "ssm":
        per_super = cfg.slstm_every
        n_super = cfg.num_layers // per_super
        stack = n_super * ((per_super - 1) * _mlstm_block_flops_token(cfg)
                           + _slstm_block_flops_token(cfg))
    elif cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
        shared = _dense_block_flops_token(cfg) + _attn_flops_token(cfg, ctx)
        stack = cfg.num_layers * _mamba_block_flops_token(cfg) + \
            n_attn * shared
    else:
        raise ValueError(cfg.family)
    head = 2.0 * cfg.d_model * cfg.vocab_size
    return stack + head


def encoder_flops(cfg: ModelConfig, batch: int) -> float:
    """Whisper encoder forward (non-causal: every query sees all S keys)."""
    if cfg.family != "encdec":
        return 0.0
    S = cfg.encoder_seq
    per_block = _dense_block_flops_token(cfg) + _attn_flops_token(cfg, S)
    return batch * S * cfg.encoder_layers * per_block


def cell_flops(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Whole-step FLOPs (all chips) for one (arch × shape) cell."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        fwd = B * T * forward_flops_per_token(cfg, T) + \
            encoder_flops(cfg, B)
        factor = 4.0 if cfg.remat else 3.0     # fwd + bwd (+ remat fwd)
        total = factor * fwd
    elif shape.kind == "prefill":
        total = B * T * forward_flops_per_token(cfg, T) + \
            encoder_flops(cfg, B)
    else:  # decode of 1 token against a T-deep context
        total = B * forward_flops_per_token(cfg, T, decode=True)
    return {"total_flops": total}


def cell_bytes(cfg: ModelConfig, shape: ShapeConfig, *,
               param_bytes_per_dev: float, cache_bytes_per_dev: float,
               chips: int, dp_shards: int) -> float:
    """Per-device HBM traffic per step (model; see module docstring)."""
    B, T = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers + cfg.encoder_layers
    if shape.kind == "train":
        B_loc = B / dp_shards
        acts = L * B_loc * T * d * 2 * ACT_RW_TRAIN
        logits = 3 * B_loc * T * cfg.vocab_size * 4 / max(
            chips / dp_shards, 1)
        opt = 12.0 * param_bytes_per_dev / 4.0   # p/m/v r+w (f32 counted 1x)
        return opt + acts + logits
    if shape.kind == "prefill":
        B_loc = B / dp_shards
        acts = L * B_loc * T * d * 2 * ACT_RW_FWD
        return param_bytes_per_dev + acts
    # decode: params + cache, once per token
    return param_bytes_per_dev + cache_bytes_per_dev
