"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = FLOPs_per_device / peak_FLOP/s
    memory term     = HBM_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

The compiled module is the per-device SPMD program, so HLO-derived numbers
are already per-device. Collective bytes are parsed from
``compiled.as_text()`` by summing result-shape sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, multiplied
by the enclosing loop trip counts (models tag every scan with
``xscan[N]`` in op_name — XLA cost_analysis counts while bodies once, a
verified limitation on this backend, so FLOPs/bytes use the analytic
accounting in flops.py and cost_analysis is reported as a cross-check).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (assignment brief).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16, per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}]+)\s+"
    r"(" + "|".join(_COLL_KINDS) + r")(-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_XSCAN_RE = re.compile(r"xscan\[(\d+)\]")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes per collective kind, loop-trip-count corrected."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if re.search(r"(" + "|".join(_COLL_KINDS) + r")-done\(", line):
            continue                      # count -start, skip -done
        bytes_ = _shape_bytes(m.group(1))
        mult = 1
        nm = _OPNAME_RE.search(line)
        if nm:
            for c in _XSCAN_RE.findall(nm.group(1)):
                mult *= int(c)
        kind = m.group(2)
        out[kind] = out.get(kind, 0.0) + float(bytes_ * mult)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float          # analytic, loop-aware
    bytes_per_dev: float          # analytic HBM traffic model
    coll_bytes_per_dev: float     # HLO-parsed, xscan-corrected
    coll_breakdown: dict[str, float]
    model_flops: float            # 6·N·D (train) / 2·N·D (serve), global
    xla_raw_flops: float = 0.0    # cost_analysis cross-check (loops-once)
    xla_raw_bytes: float = 0.0
    hbm_per_dev: Optional[float] = None   # memory_analysis footprint

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / accounted FLOPs — remat/redundancy waste."""
        total = self.flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Useful-compute time / bound time ∈ (0, 1]: the score."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        return t_useful / bound if bound > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "xla_raw_flops": self.xla_raw_flops,
            "xla_raw_bytes": self.xla_raw_bytes,
            "hbm_per_dev": self.hbm_per_dev,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }
