"""Deterministic sharded synthetic token pipeline with host prefetch.

Every (step, shard) cell is derived from a counter-based hash of
(seed, step, shard_index), so:
  * restarting from a checkpoint reproduces the exact token stream
    (fault-tolerance invariant, tested in tests/test_ft.py),
  * each data-parallel group reads only its shard (no host hot-spotting),
  * elastic resharding (G → G') re-partitions the same global stream.

A background thread keeps `prefetch` batches ahead of the training loop,
overlapping host batch synthesis with device compute — the data-pipeline
analogue of the Commander loop's compute/communication overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


def _batch_for(seed: int, step: int, shard: int, num_shards: int,
               batch_per_shard: int, seq_len: int, vocab: int
               ) -> dict[str, np.ndarray]:
    """Counter-based deterministic batch (Philox keyed by cell)."""
    key = np.uint64(seed) * np.uint64(1_000_003) + \
        np.uint64(step) * np.uint64(num_shards) + np.uint64(shard)
    rng = np.random.Generator(np.random.Philox(key=int(key)))
    # Markov-ish synthetic text: mixture of a few token "topics" per row
    # (gives a learnable distribution so e2e training loss decreases).
    topics = rng.integers(0, 8, size=(batch_per_shard, 1))
    base = (topics * (vocab // 8) +
            rng.integers(0, max(vocab // 8, 1),
                         size=(batch_per_shard, seq_len + 1)))
    tokens = np.asarray(base % vocab, dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class DataPipeline:
    """Sharded deterministic stream: `it = pipeline.shard_iterator(i)`."""

    def __init__(self, *, seed: int, global_batch: int, seq_len: int,
                 vocab: int, num_shards: int = 1, prefetch: int = 2,
                 start_step: int = 0):
        if global_batch % num_shards:
            raise ValueError("global_batch must divide into shards")
        self.seed = seed
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.vocab = vocab
        self.num_shards = num_shards
        self.prefetch = prefetch
        self.start_step = start_step

    def batch_at(self, step: int, shard: int = 0,
                 batch_override: Optional[int] = None) -> dict:
        bsz = batch_override or self.global_batch // self.num_shards
        return _batch_for(self.seed, step, shard, self.num_shards,
                          bsz, self.seq_len, self.vocab)

    def shard_iterator(self, shard: int = 0) -> Iterator[dict]:
        """Prefetching iterator for one shard, resumable at start_step."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = self.start_step
            while not stop.is_set():
                batch = self.batch_at(step, shard)
                while not stop.is_set():
                    try:
                        q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                step, batch = q.get()
                yield batch
        finally:
            stop.set()

    def reshard(self, num_shards: int, start_step: int) -> "DataPipeline":
        """Elastic re-partitioning of the same global stream."""
        return DataPipeline(seed=self.seed, global_batch=self.global_batch,
                            seq_len=self.seq_len, vocab=self.vocab,
                            num_shards=num_shards, prefetch=self.prefetch,
                            start_step=start_step)
