from .supervisor import (FailurePlan, InjectedFailure, Supervisor,
                         SupervisorReport)

__all__ = ["FailurePlan", "InjectedFailure", "Supervisor",
           "SupervisorReport"]
