"""Fault-tolerance supervisor: checkpoint/restart, failure injection,
straggler mitigation, elastic scale-down.

The supervisor wraps a HeteroTrainer (or any object with the same
train_step/state_tree protocol) in a restart loop:

  * periodic async checkpoints,
  * on a (injected or real) step failure: restore the latest checkpoint
    and replay — the deterministic data pipeline guarantees the replayed
    steps see identical batches, so recovery is exact,
  * on a group failure: elastic scale-down (drop the group, redistribute
    its share) without restart,
  * stragglers flagged by the monitor trigger an immediate policy update
    (HGuided absorbs them; Static by design does not — the paper's point).

At 1000+ node scale this loop runs per-controller with the checkpoint in
replicated object storage; the logic is identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from ..checkpoint import Checkpointer
# FailurePlan and InjectedFailure moved to the serving cluster tier
# (repro.core.cluster) when it absorbed this module's failure-injection
# machinery; re-exported here so training code keeps its import path.
# The step-keyed ``events`` dict this loop consumes is unchanged — the
# cluster adds the time-keyed ``timeline`` and JSON save/load on top.
from ..core.cluster import FailurePlan, InjectedFailure

__all__ = ["FailurePlan", "InjectedFailure", "Supervisor",
           "SupervisorReport"]


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int
    restarts: int
    groups_lost: list[str]
    stragglers_seen: list[str]
    losses: list[float]


class Supervisor:
    def __init__(self, trainer, checkpointer: Checkpointer, *,
                 ckpt_every: int = 10,
                 failure_plan: Optional[FailurePlan] = None,
                 on_straggler: Optional[Callable[[str], None]] = None):
        self.trainer = trainer
        self.ckpt = checkpointer
        self.ckpt_every = max(1, ckpt_every)
        self.plan = failure_plan or FailurePlan(events={})
        self.on_straggler = on_straggler
        self.restarts = 0
        self.groups_lost: list[str] = []
        self.stragglers_seen: list[str] = []
        self._crashed_once: set[int] = set()

    def _maybe_checkpoint(self) -> None:
        if self.trainer.step % self.ckpt_every == 0:
            self.ckpt.save_async(self.trainer.step,
                                 self.trainer.state_tree())

    def _restore(self) -> None:
        self.ckpt.wait()
        step, tree = self.ckpt.restore(self.trainer.state_tree())
        self.trainer.load_state_tree(tree)
        self.restarts += 1

    def run(self, total_steps: int) -> SupervisorReport:
        losses: list[float] = []
        # initial checkpoint so a step-0 crash can restore
        self.ckpt.save(self.trainer.step, self.trainer.state_tree())
        while self.trainer.step < total_steps:
            step = self.trainer.step
            action = self.plan.check(step)
            try:
                if action == "crash" and step not in self._crashed_once:
                    self._crashed_once.add(step)
                    raise InjectedFailure(f"injected crash at step {step}")
                if action and action.startswith("kill:"):
                    g = action.split(":", 1)[1]
                    if g not in self.groups_lost:
                        self.trainer.kill_group(g)
                        self.groups_lost.append(g)
                report = self.trainer.train_step()
                losses.append(report.loss)
                for s in self.trainer.monitor.stragglers():
                    if s not in self.stragglers_seen:
                        self.stragglers_seen.append(s)
                        if self.on_straggler:
                            self.on_straggler(s)
                self._maybe_checkpoint()
            except InjectedFailure:
                self._restore()
        self.ckpt.wait()
        return SupervisorReport(
            steps_run=self.trainer.step,
            restarts=self.restarts,
            groups_lost=self.groups_lost,
            stragglers_seen=self.stragglers_seen,
            losses=losses,
        )
