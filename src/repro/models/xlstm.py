"""xLSTM blocks (xlstm-1.3b): mLSTM (matrix memory, 7 of 8 blocks) and
sLSTM (scalar memory with recurrent mixing, 1 of 8).

mLSTM is a gated linear-attention recurrence:
    C_t = f_t · C_{t-1} + i_t · k_tᵀ v_t          (matrix memory)
    n_t = f_t · n_{t-1} + i_t · k_t               (normalizer)
    h_t = (q_t C_t) / max(|q_t n_t|, 1)
We run it through kernels/linear_attention by folding the input gate into
k and appending a ones-column to v so one kernel pass yields both the
numerator and the normalizer. Gates use sigmoid (rather than the paper's
exp + running-max stabilizer) — numerically equivalent up to the
stabilizer, noted in DESIGN.md §9.

sLSTM keeps per-head scalar memories with block-diagonal recurrent mixing
(R_z/R_i/R_f/R_o) and therefore cannot be parallelized over time — it is a
`lax.scan`, exactly as the original formulation demands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import linear_attention, ref as kref
from .layers import dense, init_dense, init_rmsnorm, rmsnorm

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, num_heads: int, expand: int = 2) -> dict:
    d_inner = expand * d_model
    ks = jax.random.split(key, 8)
    s = d_model ** -0.5
    return {
        "up_gate": init_dense(ks[0], d_model, d_inner),
        "up": init_dense(ks[1], d_model, d_inner),
        "wq": init_dense(ks[2], d_inner, d_inner),
        "wk": init_dense(ks[3], d_inner, d_inner),
        "wv": init_dense(ks[4], d_inner, d_inner),
        "w_if": init_dense(ks[5], d_inner, 2 * num_heads),
        "norm": init_rmsnorm(d_inner),
        "down": init_dense(ks[6], d_inner, d_model,
                           scale=d_inner ** -0.5),
    }


def mlstm_train(p: dict, x: Array, *, num_heads: int, expand: int = 2,
                impl: str = "ref") -> Array:
    B, T, d_model = x.shape
    d_inner = expand * d_model
    hd = d_inner // num_heads

    u = dense(p["up"], x)
    gate = dense(p["up_gate"], x)
    q = dense(p["wq"], u).reshape(B, T, num_heads, hd)
    k = dense(p["wk"], u).reshape(B, T, num_heads, hd) * hd ** -0.5
    v = dense(p["wv"], u).reshape(B, T, num_heads, hd)
    gif = dense(p["w_if"], u).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gif[..., :num_heads])        # (B,T,H)
    log_f = jax.nn.log_sigmoid(gif[..., num_heads:])     # (B,T,H)

    from .sharding import shard

    def hm(a):  # (B,T,H,D) -> (B*H,T,D)
        # batch-parallel recurrence: pin to batch-only sharding before the
        # head merge — the projections leave a "model" sharding on the
        # merged d_inner that the (B*H, T, hd) reshape cannot express,
        # which otherwise costs an all-reduce per chunk step
        a = shard(a, ("pod", "data"), None, None, None)
        return jnp.moveaxis(a, 2, 1).reshape(B * num_heads, T, a.shape[-1])

    # fold input gate into k; ones-column in v gives the normalizer n_t
    k_g = k * i_gate[..., None].astype(k.dtype)
    v_aug = jnp.concatenate(
        [v, jnp.ones((B, T, num_heads, 1), v.dtype)], axis=-1)
    ld = jnp.moveaxis(log_f, -1, 1).reshape(B * num_heads, T)

    if impl == "pallas":
        out = linear_attention(hm(q), hm(k_g), hm(v_aug), ld,
                               interpret=jax.default_backend() != "tpu")
    elif impl == "chunked":
        out = kref.chunked_linear_attention(hm(q), hm(k_g), hm(v_aug), ld)
    else:
        out = kref.linear_attention(hm(q), hm(k_g), hm(v_aug), ld)
    num = out[..., :hd]
    den = out[..., hd:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, num_heads, T, hd).swapaxes(1, 2).reshape(B, T, d_inner)
    h = rmsnorm(p["norm"], h) * jax.nn.silu(gate)
    return dense(p["down"], h)


def init_mlstm_cache(batch: int, d_model: int, num_heads: int,
                     expand: int = 2) -> dict:
    d_inner = expand * d_model
    hd = d_inner // num_heads
    return {"C": jnp.zeros((batch, num_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, num_heads, hd), jnp.float32)}


def mlstm_decode(p: dict, x: Array, cache: dict, *, num_heads: int,
                 expand: int = 2) -> tuple[Array, dict]:
    B, _, d_model = x.shape
    d_inner = expand * d_model
    hd = d_inner // num_heads

    u = dense(p["up"], x)
    gate = dense(p["up_gate"], x)
    q = dense(p["wq"], u).reshape(B, num_heads, hd).astype(jnp.float32)
    k = (dense(p["wk"], u) * hd ** -0.5).reshape(
        B, num_heads, hd).astype(jnp.float32)
    v = dense(p["wv"], u).reshape(B, num_heads, hd).astype(jnp.float32)
    gif = dense(p["w_if"], u).astype(jnp.float32)[:, 0]
    i_g = jax.nn.sigmoid(gif[:, :num_heads])             # (B,H)
    f_g = jax.nn.sigmoid(gif[:, num_heads:])

    C = cache["C"] * f_g[..., None, None] + \
        (i_g[..., None] * k)[..., :, None] * v[..., None, :]
    n = cache["n"] * f_g[..., None] + i_g[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.einsum("bhk,bhk->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    h = h.reshape(B, 1, d_inner).astype(x.dtype)
    h = rmsnorm(p["norm"], h) * jax.nn.silu(gate)
    return dense(p["down"], h), {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, num_heads: int) -> dict:
    hd = d_model // num_heads
    ks = jax.random.split(key, 9)
    s = d_model ** -0.5
    p = {"w_" + g: init_dense(k, d_model, d_model, bias=True)
         for g, k in zip("zifo", ks[:4])}
    # block-diagonal recurrent mixing: per head (hd, hd)
    for g, k in zip("zifo", ks[4:8]):
        p["r_" + g] = hd ** -0.5 * jax.random.normal(
            k, (num_heads, hd, hd), jnp.float32)
    p["norm"] = init_rmsnorm(d_model)
    p["down"] = init_dense(ks[8], d_model, d_model, scale=s)
    return p


def init_slstm_state(batch: int, d_model: int, num_heads: int) -> dict:
    hd = d_model // num_heads
    z = jnp.zeros((batch, num_heads, hd), jnp.float32)
    return {"c": z, "n": z, "h": z}


def _slstm_step(p: dict, st: dict, zx, ix, fx, ox, num_heads: int):
    """One timestep. zx/ix/fx/ox: (B, H, hd) pre-activations from x."""
    from .sharding import shard
    h_prev = st["h"]

    def mix(name):
        return jnp.einsum("bhk,hkj->bhj", h_prev, p["r_" + name])

    z = jnp.tanh(zx + mix("z"))
    i = jax.nn.sigmoid(ix + mix("i"))
    f = jax.nn.sigmoid(fx + mix("f"))
    o = jax.nn.sigmoid(ox + mix("o"))
    c = f * st["c"] + i * z
    n = f * st["n"] + i
    h = o * c / jnp.maximum(n, 1.0)
    # pin the carry sharding: unconstrained while-carries go replicated
    # and drag the whole time loop with them (§Perf iteration 3)
    bsh = lambda a: shard(a, ("pod", "data"), None, None)
    return {"c": bsh(c), "n": bsh(n), "h": bsh(h)}


def slstm_train(p: dict, x: Array, *, num_heads: int) -> Array:
    B, T, d_model = x.shape
    hd = d_model // num_heads

    from ..xscan import xscan
    from .sharding import shard

    def pre(name):
        a = dense(p["w_" + name], x).reshape(
            B, T, num_heads, hd).astype(jnp.float32)
        # CRITICAL: materialize the pre-activations batch-sharded-only
        # BEFORE entering the time scan. The projection output inherits a
        # "model" sharding on hd; the recurrent mix then contracts a
        # sharded dim → one all-reduce PER TIMESTEP (measured 4.2e6 ms of
        # collectives on xlstm-1.3b prefill_32k — EXPERIMENTS.md §Perf
        # iteration 2). One gather here replaces T of them.
        return shard(a, ("pod", "data"), None, None, None)

    zx, ix, fx, ox = pre("z"), pre("i"), pre("f"), pre("o")

    def step(st, t_in):
        st = _slstm_step(p, st, *t_in, num_heads=num_heads)
        return st, st["h"]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (zx, ix, fx, ox))
    st0 = init_slstm_state(B, d_model, num_heads)
    _, hs = xscan(step, st0, xs, name="slstm_steps")
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, d_model).astype(x.dtype)
    return dense(p["down"], rmsnorm(p["norm"], h))


def slstm_decode(p: dict, x: Array, state: dict, *, num_heads: int
                 ) -> tuple[Array, dict]:
    B, _, d_model = x.shape
    hd = d_model // num_heads

    def pre(name):
        return dense(p["w_" + name], x).reshape(
            B, num_heads, hd).astype(jnp.float32)

    st = _slstm_step(p, state, pre("z"), pre("i"), pre("f"), pre("o"),
                     num_heads=num_heads)
    h = st["h"].reshape(B, 1, d_model).astype(x.dtype)
    return dense(p["down"], rmsnorm(p["norm"], h)), st
