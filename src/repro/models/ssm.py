"""Mamba-2 (SSD) block — the sequence mixer of zamba2-7b.

Scalar-decay state space duality: per head h with state (d_state × d_head),
    decay_t = exp(-softplus(dt_t) · A_h)
    S_t     = decay_t · S_{t-1} + (softplus(dt_t) · B_t)ᵀ x_t
    y_t     = C_t · S_t + D_h · x_t
Training/prefill uses the chunked form (kernels/linear_attention — Pallas on
TPU, exact-oracle path otherwise); decode updates the (H, d_state, d_head)
state in place, O(1) per token — this is why zamba2 runs the long_500k
shape. The depthwise causal conv (width 4) before the SSD follows Mamba-2;
n_groups=1 (B/C shared across heads, GQA-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import linear_attention, ref as kref
from .layers import dense, init_dense, init_rmsnorm, rmsnorm

Array = jax.Array

CONV_WIDTH = 4


def init_mamba2(key, d_model: int, d_state: int, head_dim: int = 64,
                expand: int = 2) -> dict:
    d_inner = expand * d_model
    heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * d_state
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "in_proj": init_dense(ks[0], d_model,
                              2 * d_inner + 2 * d_state + heads),
        "conv_w": 0.5 * jax.random.normal(
            ks[1], (CONV_WIDTH, conv_dim), jnp.float32) / CONV_WIDTH,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),   # A_h > 0
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((heads,), 0.01))),
        "norm": init_rmsnorm(d_inner),
        "out_proj": init_dense(ks[2], d_inner, d_model,
                               scale=d_inner ** -0.5),
    }


def _split_proj(proj: Array, d_inner: int, d_state: int, heads: int):
    z, xc, B, C, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
               2 * d_inner + 2 * d_state], axis=-1)
    return z, xc, B, C, dt


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along time. x: (B, T, C); w: (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(W))
    return out + b.astype(x.dtype)


def mamba2_train(p: dict, x: Array, *, d_state: int, head_dim: int = 64,
                 expand: int = 2, impl: str = "ref") -> Array:
    """Full-sequence SSD. x: (B, T, d_model)."""
    Bsz, T, d_model = x.shape
    d_inner = expand * d_model
    heads = d_inner // head_dim

    proj = dense(p["in_proj"], x)
    z, xc, Bmat, Cmat, dt = _split_proj(proj, d_inner, d_state, heads)
    # conv is applied over [x, B, C] jointly (Mamba-2); dt bypasses it
    xbc = jnp.concatenate([xc, Bmat, Cmat], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    A = jnp.exp(p["A_log"])                                      # (H,)
    log_decay = -dt * A                                          # (B,T,H)

    # head-major layout for the chunked kernel: (B*H, T, ·)
    xh = xs.reshape(Bsz, T, heads, head_dim)
    q = jnp.broadcast_to(Cmat[:, :, None, :], (Bsz, T, heads, d_state))
    k = jnp.broadcast_to(Bmat[:, :, None, :], (Bsz, T, heads, d_state))
    k = k * dt[..., None].astype(k.dtype)
    ld = jnp.moveaxis(log_decay, -1, 1).reshape(Bsz * heads, T)

    from .sharding import shard

    def hm(a):  # (B,T,H,D) -> (B*H,T,D)
        # batch-parallel SSD: see xlstm.py — avoids per-chunk all-reduces
        a = shard(a, ("pod", "data"), None, None, None)
        return jnp.moveaxis(a, 2, 1).reshape(Bsz * heads, T, a.shape[-1])

    if impl == "pallas":
        y = linear_attention(hm(q), hm(k), hm(xh), ld,
                             interpret=jax.default_backend() != "tpu")
    elif impl == "chunked":
        y = kref.chunked_linear_attention(hm(q), hm(k), hm(xh), ld)
    else:
        y = kref.linear_attention(hm(q), hm(k), hm(xh), ld)
    y = y.reshape(Bsz, heads, T, head_dim).swapaxes(1, 2)        # (B,T,H,D)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bsz, T, d_inner)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return dense(p["out_proj"], y)


def init_mamba2_cache(batch: int, d_model: int, d_state: int,
                      head_dim: int = 64, expand: int = 2,
                      dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "state": jnp.zeros((batch, heads, d_state, head_dim), dtype),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_dim), dtype),
    }


def mamba2_decode(p: dict, x: Array, cache: dict, *, d_state: int,
                  head_dim: int = 64, expand: int = 2
                  ) -> tuple[Array, dict]:
    """One-token step. x: (B, 1, d_model)."""
    Bsz, _, d_model = x.shape
    d_inner = expand * d_model
    heads = d_inner // head_dim

    proj = dense(p["in_proj"], x)
    z, xc, Bmat, Cmat, dt = _split_proj(proj, d_inner, d_state, heads)
    xbc = jnp.concatenate([xc, Bmat, Cmat], axis=-1)

    # rolling conv buffer
    hist = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    w = p["conv_w"]
    conv = sum(hist[:, i, :] * w[i].astype(xbc.dtype)
               for i in range(CONV_WIDTH)) + p["conv_b"].astype(xbc.dtype)
    xc1 = jax.nn.silu(conv)[:, None, :]
    new_conv = hist[:, 1:, :].astype(cache["conv"].dtype)

    xs, Bm, Cm = jnp.split(xc1, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
    A = jnp.exp(p["A_log"])
    decay = jnp.exp(-dt * A)[..., 0, :]                           # (B,H)

    xh = xs.reshape(Bsz, heads, head_dim).astype(jnp.float32)
    Bv = Bm[:, 0, :].astype(jnp.float32)                          # (B,S)
    Cv = Cm[:, 0, :].astype(jnp.float32)
    dtv = dt[:, 0, :]                                             # (B,H)

    # S ← decay·S + (dt·B)ᵀ x
    S = cache["state"] * decay[..., None, None]
    S = S + (dtv[..., None] * Bv[:, None, :])[..., None] * xh[:, :, None, :]
    y = jnp.einsum("bs,bhsd->bhd", Cv, S)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(z)
    return dense(p["out_proj"], y), {"state": S, "conv": new_conv}
