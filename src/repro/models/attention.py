"""Attention layer: GQA, qk-norm (qwen3), QKV bias (qwen1.5), sliding
window (h2o-danube3 / zamba2-long), RoPE, KV cache for decode.

Training/prefill can route through the Pallas flash kernel
(`impl="flash"`) or the XLA einsum oracle (`impl="xla"`, differentiable —
the training default). Decode always uses the einsum path against the
cache (memory-bound; one q position).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels import flash_attention, ref as kref
from .layers import apply_rope, dense, init_dense, init_rmsnorm, rmsnorm

Array = jax.Array


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, qk_norm: bool = False,
                   qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, d_model, num_heads * head_dim, bias=qkv_bias),
        "wk": init_dense(kk, d_model, num_kv_heads * head_dim,
                         bias=qkv_bias),
        "wv": init_dense(kv, d_model, num_kv_heads * head_dim,
                         bias=qkv_bias),
        "wo": init_dense(ko, num_heads * head_dim, d_model,
                         scale=(num_heads * head_dim) ** -0.5),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(head_dim)
        p["k_norm"] = init_rmsnorm(head_dim)
    return p


def _project_qkv(p: dict, x: Array, num_heads: int, num_kv_heads: int,
                 head_dim: int, positions: Array, rope_freqs: Array,
                 ) -> tuple[Array, Array, Array]:
    B, T, _ = x.shape
    q = dense(p["wq"], x).reshape(B, T, num_heads, head_dim)
    k = dense(p["wk"], x).reshape(B, T, num_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(B, T, num_kv_heads, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    from .sharding import shard
    q = jnp.swapaxes(q, 1, 2)   # (B, H, T, D)
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    if rope_freqs is not None:
        q = apply_rope(q, positions[:, None, :], rope_freqs)
        k = apply_rope(k, positions[:, None, :], rope_freqs)
    # pin head sharding (TP) so remat/while boundaries can't drop it
    q = shard(q, ("pod", "data"), "model", None, None)
    k = shard(k, ("pod", "data"), "model", None, None)
    v = shard(v, ("pod", "data"), "model", None, None)
    return q, k, v


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window: Optional[int] = None,
                      chunk: int = 512) -> Array:
    """Blockwise (lax.scan over query chunks) attention, O(T·chunk) memory.

    The XLA analogue of the flash kernel: differentiable, no O(T²) logits
    materialization — this is what makes 32k-prefill lowering fit. Shapes
    as ref.attention: q (B,Hq,T,D); k,v (B,Hkv,T,D).
    """
    from .sharding import shard
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    scale = D ** -0.5
    if Hkv != Hq:
        # GQA as an explicit head broadcast: q head h reads kv head h//G,
        # so repeating kv kv-major keeps a plain (B, Hq, ·, ·) layout that
        # the TP head sharding maps onto directly. Splitting Hq into
        # (Hkv, G) instead breaks the mapping and makes GSPMD all-gather
        # the q chunks every loop iteration (measured: 6×32 MiB ×
        # layers×chunks on qwen3 — see EXPERIMENTS.md §Perf iteration 1).
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    k = shard(k, ("pod", "data"), "model", None, None)
    v = shard(v, ("pod", "data"), "model", None, None)
    if T % chunk:
        chunk = T  # fallback for odd sizes (smoke tests)
    qc = jnp.moveaxis(q.reshape(B, Hq, T // chunk, chunk, D), 2, 0)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    k_idx = jnp.arange(T)

    def one_chunk(ci, qblk):
        qf = qblk.astype(jnp.float32) * scale         # (B, Hq, c, D)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
        q_idx = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, T), dtype=bool)
        if causal:
            mask &= q_idx[:, None] >= k_idx[None, :]
        if window is not None:
            mask &= q_idx[:, None] - k_idx[None, :] < window
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", probs, vf)

    from ..xscan import xmap_seq
    out = xmap_seq(lambda args: one_chunk(*args),
                   (jnp.arange(T // chunk), qc), name="attn_chunks")
    out = jnp.moveaxis(out, 0, 2)                     # (B, Hq, nc, c, D)
    return out.reshape(B, Hq, T, D).astype(q.dtype)


def attention_train(p: dict, x: Array, *, num_heads: int, num_kv_heads: int,
                    head_dim: int, rope_freqs: Optional[Array],
                    window: Optional[int] = None, causal: bool = True,
                    impl: str = "xla") -> Array:
    """Full-sequence attention (training / prefill). x: (B, T, d)."""
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_freqs)
    if impl == "flash":
        out = flash_attention(q, k, v, causal=causal, window=window,
                              interpret=jax.default_backend() != "tpu")
    elif impl == "chunked":
        out = chunked_attention(q, k, v, causal=causal, window=window)
    else:
        out = kref.attention(q, k, v, causal=causal, window=window)
    out = jnp.swapaxes(out, 1, 2).reshape(B, T, num_heads * head_dim)
    return dense(p["wo"], out)


def init_kv_cache(batch: int, num_kv_heads: int, max_len: int,
                  head_dim: int, dtype=jnp.bfloat16) -> dict:
    """Ring-buffer cache. For SWA models max_len can be the window size."""
    return {
        "k": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
        "v": jnp.zeros((batch, num_kv_heads, max_len, head_dim), dtype),
        # filled length (== next write slot until the ring wraps)
        "len": jnp.zeros((), jnp.int32),
    }


def attention_decode(p: dict, x: Array, cache: dict, *, num_heads: int,
                     num_kv_heads: int, head_dim: int,
                     rope_freqs: Optional[Array],
                     window: Optional[int] = None) -> tuple[Array, dict]:
    """Single-token decode with cache update. x: (B, 1, d)."""
    B = x.shape[0]
    max_len = cache["k"].shape[2]
    pos = cache["len"]                       # scalar: absolute position
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_freqs)
    slot = jnp.mod(pos, max_len)             # ring write (SWA wraps)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))

    # valid positions: ages 0..min(pos, max_len)-1 relative to the new token
    idx = jnp.arange(max_len)
    age = jnp.mod(slot - idx, max_len)       # age of each slot
    valid = age <= jnp.minimum(pos, max_len - 1)
    if window is not None:
        valid &= age < window

    G = num_heads // num_kv_heads
    qf = q.astype(jnp.float32).reshape(B, num_kv_heads, G, head_dim) \
        * head_dim ** -0.5
    logits = jnp.einsum("bhgd,bhsd->bhgs", qf, ck.astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, cv.astype(jnp.float32))
    out = out.reshape(B, 1, num_heads * head_dim).astype(x.dtype)
    new_cache = {"k": ck, "v": cv, "len": pos + 1}
    return dense(p["wo"], out), new_cache
