"""Logical→physical sharding rules for params, activations and caches.

Conventions (GSPMD / pjit):
  * batch-like dims   → ("pod", "data")   (whichever axes the mesh has)
  * model-parallel    → "model": attention heads, FFN hidden, vocab,
                        expert (EP), mamba/mLSTM inner dims
  * everything else   → replicated

All rules are divisibility-checked against the active mesh: an axis that
does not divide the dim is dropped (GSPMD could pad, but clean factors keep
the collective schedule predictable — and vocab sizes like 122753 are not
16-divisible). `shard()` is a no-op outside a mesh context, so smoke tests
run unsharded.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

Array = jax.Array

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"

# FSDP (ZeRO-3): when enabled, parameter/optimizer leaves additionally
# shard their non-"model" dim over the data axes; GSPMD inserts the
# per-layer weight all-gathers inside the scan (and reduce-scatters the
# grads), trading collective traffic for the per-device residency that
# lets ≥100B-param configs fit a 16 GB v5e.
_FSDP = False


def set_fsdp(enabled: bool) -> None:
    global _FSDP
    _FSDP = bool(enabled)


def _active_mesh():
    """The ambient mesh, across jax versions (abstract or `with mesh:`)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.get_abstract_mesh()
    if mesh is not None and not getattr(mesh, "empty", True):
        return mesh
    return mesh_lib.thread_resources.env.physical_mesh


def _mesh_axis_sizes() -> dict[str, int]:
    mesh = _active_mesh()
    if mesh is None or mesh.empty:
        return {}
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def _resolve(spec_axes: Sequence, shape: tuple[int, ...],
             sizes: dict[str, int]):
    """Filter logical spec entries by mesh presence + divisibility."""
    out = []
    for dim, entry in zip(shape, spec_axes):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = [a for a in axes if a in sizes]
        factor = 1
        for a in axes:
            factor *= sizes[a]
        if axes and dim % factor == 0:
            out.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def shard(x: Array, *spec_axes) -> Array:
    """Activation sharding constraint; silently skipped with no mesh."""
    sizes = _mesh_axis_sizes()
    if not sizes:
        return x
    spec = _resolve(spec_axes, x.shape, sizes)
    return jax.lax.with_sharding_constraint(x, spec)


def batch_spec(x_shape: tuple[int, ...]) -> P:
    """(batch, ...) arrays: shard dim 0 over pod+data."""
    sizes = _mesh_axis_sizes()
    axes = [BATCH_AXES] + [None] * (len(x_shape) - 1)
    return _resolve(axes, x_shape, sizes) if sizes else P()


# ---------------------------------------------------------------------------
# Parameter rules: path regex → logical spec per dim (matched in order).
# Paths look like "layers/attn/wq/kernel", "layers/moe/wi_gate", ...
# ---------------------------------------------------------------------------

_PARAM_RULES: list[tuple[str, list]] = [
    # embeddings / unembeddings: vocab over model (fallback d handled by
    # divisibility: if vocab % model != 0 the axis is dropped; then the
    # second rule with d sharded would not match the same path, so we give
    # vocab-first spec with d fallback baked in via tuple-of-options below)
    (r"embed/table$", [MODEL_AXIS, None]),
    (r"lm_head/kernel$", [None, MODEL_AXIS]),
    # attention: out-features of q/k/v over model, in-features of o
    (r"(attn|self_attn|cross_attn|shared_attn)/w[qkv]/kernel$",
     [None, MODEL_AXIS]),
    (r"(attn|self_attn|cross_attn|shared_attn)/w[qkv]/bias$", [MODEL_AXIS]),
    (r"(attn|self_attn|cross_attn|shared_attn)/wo/kernel$",
     [MODEL_AXIS, None]),
    # dense MLPs
    (r"mlp/wi(_gate|_up)?/kernel$", [None, MODEL_AXIS]),
    (r"mlp/wo/kernel$", [MODEL_AXIS, None]),
    (r"mlp/wi/bias$", [MODEL_AXIS]),
    # MoE: expert-parallel over model
    (r"moe/router/kernel$", [None, None]),
    (r"moe/wi_(gate|up)$", [MODEL_AXIS, None, None]),
    (r"moe/wo$", [MODEL_AXIS, None, None]),
    # Mamba2 / mLSTM inner projections
    (r"(mamba|mlstm)/in_proj/kernel$", [None, MODEL_AXIS]),
    (r"(mamba|mlstm)/(out_proj|down)/kernel$", [MODEL_AXIS, None]),
    (r"mlstm/(up|up_gate|wq|wk|wv|w_if)/kernel$", [None, MODEL_AXIS]),
    # everything else replicated
]


def param_path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params, *, extra_leading_dims: int = 0):
    """PartitionSpec pytree for a parameter tree.

    `extra_leading_dims` accounts for scan-stacked layer dims (the leading
    (L,) axis of stacked block params is never sharded).
    """
    sizes = _mesh_axis_sizes()

    def spec_for(path, leaf):
        pstr = param_path_str(path)
        ndim = leaf.ndim
        lead = 0
        # stacked layer axes: any path under "layers"/"blocks" has one
        if re.search(r"(^|/)(layers|blocks|encoder_layers|superblocks|"
                     r"tail_blocks)(/|$)", pstr):
            lead = 1
        for pattern, axes in _PARAM_RULES:
            if re.search(pattern, pstr):
                body = axes
                if lead + len(body) != ndim:
                    # rule arity mismatch (e.g. stacked bias): best effort
                    body = axes[-(ndim - lead):] if ndim > lead else []
                full = [None] * lead + list(body)
                if _FSDP and ndim - lead >= 2:
                    # shard the first free dim over the data axes
                    for i in range(lead, ndim):
                        if full[i] is None:
                            full[i] = BATCH_AXES
                            break
                if not sizes:
                    return P()
                return _resolve(full, leaf.shape, sizes)
        full = [None] * ndim
        if _FSDP and ndim - lead >= 2:
            full[lead] = BATCH_AXES
        return P() if not sizes else _resolve(full, leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_specs(cache):
    """KV/state caches: batch dim over pod+data, head dims over model.

    Caches are scan-stacked over layers: leaves are (L, B, H, S, D) KV
    rings, (L, B, H, s, d) SSM states, (L, B, W, C) conv buffers, or
    scalar lengths. The stacked layer dim is never sharded.
    """
    sizes = _mesh_axis_sizes()

    model_size = sizes.get(MODEL_AXIS, 1)

    def spec_for(path, leaf):
        if not sizes:
            return P()
        ndim = leaf.ndim
        if ndim <= 1:
            return P() if ndim == 0 else _resolve([None], leaf.shape, sizes)
        axes: list = [None, BATCH_AXES] + [None] * (ndim - 2)
        # (L, B, H, S, D) KV rings / (L, B, H, s, d) SSM states: shard the
        # first trailing dim the model axis divides — heads when possible,
        # else sequence (ring decode = sequence-parallel attention), else
        # the state dim (mLSTM matrix memories with few heads).
        for d in range(2, ndim):
            if leaf.shape[d] % model_size == 0 and leaf.shape[d] >= \
                    model_size:
                axes[d] = MODEL_AXIS
                break
        return _resolve(axes, leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(spec_for, cache)
