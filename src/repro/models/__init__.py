"""Model zoo: every assigned architecture behind `build_model(cfg)`."""
from .model import Model, build_model, count_params
from .sharding import batch_spec, cache_specs, param_specs, shard

__all__ = ["Model", "batch_spec", "build_model", "cache_specs",
           "count_params", "param_specs", "shard"]
