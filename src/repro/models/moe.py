"""Mixture-of-Experts layer (qwen3-moe 128e/top-8, phi3.5-moe 16e/top-2).

Sort-based capacity dispatch (no (N,E,C) one-hot blow-up):
  1. top-k routing with renormalized gates,
  2. flat (token, k) slots sorted by expert id,
  3. rank-within-expert → capacity slot; overflow tokens are dropped
     (their combine weight is zeroed, residual passes through),
  4. gathered (E, C, d) activations → per-expert gated-SiLU MLP via
     batched einsum over the expert axis,
  5. scatter-add back through the inverse permutation.

Sharding: the expert axis maps to the "model" mesh axis (EP); token axes
map to ("pod","data"). GSPMD turns the gather/scatter into all-to-alls —
exactly the dispatch/combine collective pattern of GShard/Switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, init_dense

Array = jax.Array


def init_moe(key, d_model: int, moe_d_ff: int, num_experts: int,
             *, router_scale: float = None) -> dict:
    kr, kg, ku, ko = jax.random.split(key, 4)
    scale = d_model ** -0.5
    return {
        "router": init_dense(kr, d_model, num_experts, scale=scale),
        "wi_gate": scale * jax.random.normal(
            kg, (num_experts, d_model, moe_d_ff), jnp.float32),
        "wi_up": scale * jax.random.normal(
            ku, (num_experts, d_model, moe_d_ff), jnp.float32),
        "wo": moe_d_ff ** -0.5 * jax.random.normal(
            ko, (num_experts, moe_d_ff, d_model), jnp.float32),
    }


def moe_layer(p: dict, x: Array, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25) -> tuple[Array, Array]:
    """x: (B, T, d) -> (out, aux_loss). Router in f32."""
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)

    logits = dense(p["router"], xf.astype(jnp.float32))        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)         # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], num_experts), axis=0)
    router_mean = probs.mean(axis=0)
    aux = num_experts * jnp.sum(density * router_mean)

    capacity = max(1, int(capacity_factor * N * top_k / num_experts))

    # ---- dispatch ----------------------------------------------------
    flat_expert = expert_idx.reshape(-1)                        # (N*k,)
    flat_token = jnp.repeat(jnp.arange(N), top_k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                            # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # rank within expert = position - start of that expert's run
    counts = jnp.bincount(sorted_expert, length=num_experts)    # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(N * top_k) - starts[sorted_expert]
    keep = rank < capacity
    slot = sorted_expert * capacity + jnp.where(keep, rank, 0)

    # gather tokens into (E*C, d); dropped slots are zeroed
    from .sharding import shard
    gathered = jnp.where(keep[:, None], xf[sorted_token], 0.0)
    gathered = shard(gathered, ("pod", "data"), None)
    # the scatter target must be born sharded: an unconstrained zeros
    # operand makes GSPMD replicate the whole scatter (and its transpose),
    # all-gathering every (N·k, d) token tensor per layer (§Perf iter. 4)
    buf = shard(jnp.zeros((num_experts * capacity, d), x.dtype),
                "model", None)
    buf = buf.at[slot].add(jnp.where(keep[:, None], gathered, 0.0))
    buf = shard(buf.reshape(num_experts, capacity, d),
                "model", None, None)        # EP: dispatch all-to-all here

    # ---- expert MLPs (batched over E; EP-sharded) ---------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               p["wi_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"].astype(x.dtype))
    h = shard(h, "model", None, None)
    out_e = shard(jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype)),
                  "model", None, None)
    out_flat = out_e.reshape(num_experts * capacity, d)

    # ---- combine ------------------------------------------------------
    expert_out = shard(out_flat[slot], ("pod", "data"), None)   # (N*k, d)
    contrib = expert_out * (sorted_gate * keep)[:, None]
    combined = shard(jnp.zeros((N, d), x.dtype), ("pod", "data"), None)
    combined = combined.at[sorted_token].add(contrib)
    return combined.reshape(B, T, d), aux
