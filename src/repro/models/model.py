"""Model builder: every assigned architecture behind one interface.

    model = build_model(cfg)
    params = model.init(rng)
    loss, aux = model.loss(params, batch)             # training forward
    logits = model.prefill_logits(params, batch)      # last-pos logits
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, tokens, cache)

Layer stacks are `lax.scan` over stacked block params (MaxText-style) to
keep HLO size O(1) in depth; `cfg.remat` wraps blocks in jax.checkpoint.
Families: dense (minicpm/qwen3/qwen1.5/h2o), moe (qwen3-moe/phi3.5-moe),
encdec (whisper), ssm (xlstm), hybrid (zamba2), vlm (internvl2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..xscan import xmap_seq, xscan
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (cross_entropy, dense, embed, gelu_mlp, init_dense,
                     init_embedding, init_gelu_mlp, init_layernorm, init_mlp,
                     init_rmsnorm, layernorm, mlp, rmsnorm,
                     rope_frequencies, sinusoidal_positions, unembed)
from .sharding import shard

Array = jax.Array
PyTree = Any


def _stack_init(init_fn: Callable, key, n: int) -> PyTree:
    """vmap an init over layer keys → stacked (n, ...) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _maybe_remat(fn: Callable, enable: bool) -> Callable:
    return jax.checkpoint(fn) if enable else fn


# ===========================================================================
# Decoder block (dense / moe / vlm families share it)
# ===========================================================================

def _init_decoder_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, hd,
                                    qk_norm=cfg.qk_norm,
                                    qkv_bias=cfg.qkv_bias),
        "ln2": init_rmsnorm(cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(k2, cfg.d_model, cfg.moe_d_ff,
                                    cfg.num_experts)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _decoder_block_train(p: dict, x: Array, cfg: ModelConfig,
                         rope: Optional[Array]) -> tuple[Array, Array]:
    h = attn.attention_train(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_freqs=rope,
        window=cfg.window, impl=cfg.attn_impl)
    x = x + h
    x = shard(x, ("pod", "data"), "model", None)
    hn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        h2, aux = moe_mod.moe_layer(p["moe"], hn,
                                    num_experts=cfg.num_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor)
    else:
        h2, aux = mlp(p["mlp"], hn), jnp.zeros((), jnp.float32)
    x = x + h2
    return shard(x, ("pod", "data"), "model", None), aux


def _decoder_block_decode(p: dict, x: Array, cache: dict, cfg: ModelConfig,
                          rope: Optional[Array]) -> tuple[Array, dict]:
    h, cache = attn.attention_decode(
        p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cache,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_freqs=rope, window=cfg.window)
    x = x + h
    hn = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        h2, _ = moe_mod.moe_layer(p["moe"], hn,
                                  num_experts=cfg.num_experts,
                                  top_k=cfg.top_k, capacity_factor=2.0)
    else:
        h2 = mlp(p["mlp"], hn)
    return x + h2, cache


# ===========================================================================
# Model object
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[Array], PyTree]
    forward: Callable[..., tuple[Array, Array]]   # (params, batch) -> logits, aux
    init_cache: Callable[..., PyTree]
    decode_step: Callable[..., tuple[Array, PyTree]]
    prefill: Optional[Callable[..., PyTree]] = None

    # ---- derived entry points -------------------------------------------
    def loss(self, params: PyTree, batch: dict) -> tuple[Array, dict]:
        logits, aux = self.forward(params, batch)
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"),
                           valid_vocab=self.cfg.vocab_size)
        total = ce + 0.01 * aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    def prefill_logits(self, params: PyTree, batch: dict) -> Array:
        """Serving prefill: logits at the final position only."""
        logits, _ = self.forward(params, batch)
        return logits[:, -1, :]


def count_params(params: PyTree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


def _pad_vocab(cfg: ModelConfig) -> Optional[int]:
    """Pad the unembedding vocab to 16·128 alignment so logits shard over
    the "model" axis (unshardable vocabs force replicated (B,T,V) f32
    logits — 32 GB/device for minicpm train_4k)."""
    V = cfg.vocab_size
    if V % 2048 == 0:
        return None
    return -(-V // 2048) * 2048


def _mask_pad_cols(logits: Array, valid: int) -> Array:
    if logits.shape[-1] == valid:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                   logits.ndim - 1)
    return jnp.where(col < valid, logits, -jnp.inf)


# ===========================================================================
# dense / moe / vlm decoder-only LM
# ===========================================================================

def _build_decoder_lm(cfg: ModelConfig) -> Model:
    rope = (rope_frequencies(cfg.resolved_head_dim, cfg.rope_theta)
            if cfg.rope_theta else None)

    def init(key) -> PyTree:
        ke, kl, kh = jax.random.split(key, 3)
        p = {
            "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model),
            "layers": _stack_init(
                lambda k: _init_decoder_block(k, cfg), kl, cfg.num_layers),
            "final_norm": init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init_dense(kh, cfg.d_model, cfg.vocab_size)
        if cfg.family == "vlm":
            # stub projector for the (frozen, external) InternViT features
            p["vision_proj"] = init_dense(kh, cfg.d_model, cfg.d_model)
        return p

    def embed_inputs(params, batch) -> Array:
        x = embed(params["embed"], batch["tokens"])
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = dense(params["vision_proj"],
                       batch["vision_embeds"].astype(x.dtype))
            nv = ve.shape[1]
            x = jnp.concatenate([ve, x[:, nv:, :]], axis=1)
        return shard(x, ("pod", "data"), "model", None)

    def forward(params, batch):
        x = embed_inputs(params, batch)

        def body(carry, layer_p):
            x, aux = carry
            x, a = _decoder_block_train(layer_p, x, cfg, rope)
            return (x, aux + a), None

        (x, aux), _ = xscan(body, (x, jnp.zeros((), jnp.float32)),
                            params["layers"], name="layers",
                            remat=cfg.remat)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, pad_to=_pad_vocab(cfg))
        else:
            logits = dense(params["lm_head"],
                           x.astype(jnp.float32))
        logits = shard(logits, ("pod", "data"), None, "model")
        return logits, aux / cfg.num_layers

    def init_cache(batch: int, max_len: int) -> PyTree:
        eff = min(max_len, cfg.window) if cfg.window else max_len
        one = lambda _: attn.init_kv_cache(batch, cfg.num_kv_heads, eff,
                                           cfg.resolved_head_dim)
        caches = jax.vmap(one)(jnp.arange(cfg.num_layers))
        return caches

    def decode_step(params, tokens: Array, cache: PyTree
                    ) -> tuple[Array, PyTree]:
        """tokens: (B, 1) int32 → (B, vocab) logits + new cache."""
        x = embed(params["embed"], tokens)

        def body(x, scanned):
            layer_p, layer_cache = scanned
            x, new_cache = _decoder_block_decode(layer_p, x, layer_cache,
                                                 cfg, rope)
            return x, new_cache

        x, new_caches = xscan(body, x, (params["layers"], cache),
                              name="layers")
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, pad_to=_pad_vocab(cfg))
        else:
            logits = dense(params["lm_head"], x.astype(jnp.float32))
        logits = _mask_pad_cols(logits, cfg.vocab_size)
        return logits[:, 0, :], new_caches

    return Model(cfg=cfg, init=init, forward=forward,
                 init_cache=init_cache, decode_step=decode_step)


# ===========================================================================
# enc-dec (whisper)
# ===========================================================================

def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "ln1": init_layernorm(cfg.d_model),
        "attn": attn.init_attention(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, hd),
        "ln2": init_layernorm(cfg.d_model),
        "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    return {
        "ln1": init_layernorm(cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg.d_model, cfg.num_heads,
                                         cfg.num_kv_heads, hd),
        "ln_x": init_layernorm(cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg.d_model, cfg.num_heads,
                                          cfg.num_kv_heads, hd),
        "ln2": init_layernorm(cfg.d_model),
        "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def _cross_attend(p, x, enc_k, enc_v, cfg):
    """Cross-attention against precomputed encoder K/V."""
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x).reshape(B, T, cfg.num_heads, hd)
    q = jnp.swapaxes(q, 1, 2)
    from ..kernels import ref as kref
    out = kref.attention(q, enc_k, enc_v, causal=False)
    out = jnp.swapaxes(out, 1, 2).reshape(B, T, cfg.num_heads * hd)
    return dense(p["wo"], out)


def _encoder_kv(p, enc_out, cfg):
    B, S, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = dense(p["wk"], enc_out).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense(p["wv"], enc_out).reshape(B, S, cfg.num_kv_heads, hd)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)


def _build_encdec(cfg: ModelConfig) -> Model:
    def init(key) -> PyTree:
        ke, kenc, kdec, kh = jax.random.split(key, 4)
        return {
            "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model),
            "encoder_layers": _stack_init(
                lambda k: _init_enc_block(k, cfg), kenc, cfg.encoder_layers),
            "enc_norm": init_layernorm(cfg.d_model),
            "layers": _stack_init(
                lambda k: _init_dec_block(k, cfg), kdec, cfg.num_layers),
            "final_norm": init_layernorm(cfg.d_model),
        }

    def encode(params, frames: Array) -> Array:
        """frames: (B, S_enc, d) stub embeddings from the conv frontend."""
        S = frames.shape[1]
        x = frames + sinusoidal_positions(S, cfg.d_model,
                                          frames.dtype)[None]
        x = shard(x, ("pod", "data"), "model", None)

        def body(x, p):
            h = attn.attention_train(
                p["attn"], layernorm(p["ln1"], x),
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_freqs=None,
                causal=False, impl=cfg.attn_impl)
            x = x + h
            x = x + gelu_mlp(p["mlp"], layernorm(p["ln2"], x))
            return shard(x, ("pod", "data"), "model", None), None

        x, _ = xscan(body, x, params["encoder_layers"],
                     name="enc_layers", remat=cfg.remat)
        return layernorm(params["enc_norm"], x)

    def forward(params, batch):
        enc = encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = embed(params["embed"], tokens)
        x = x + sinusoidal_positions(T, cfg.d_model, x.dtype)[None]

        def body(x, p):
            h = attn.attention_train(
                p["self_attn"], layernorm(p["ln1"], x),
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_freqs=None,
                impl=cfg.attn_impl)
            x = x + h
            ek, ev = _encoder_kv(p["cross_attn"], enc, cfg)
            x = x + _cross_attend(p["cross_attn"],
                                  layernorm(p["ln_x"], x), ek, ev, cfg)
            x = x + gelu_mlp(p["mlp"], layernorm(p["ln2"], x))
            return shard(x, ("pod", "data"), "model", None), None

        x, _ = xscan(body, x, params["layers"], name="dec_layers",
                     remat=cfg.remat)
        x = layernorm(params["final_norm"], x)
        logits = unembed(params["embed"], x, pad_to=_pad_vocab(cfg))
        logits = shard(logits, ("pod", "data"), None, "model")
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(batch: int, max_len: int) -> PyTree:
        hd = cfg.resolved_head_dim
        self_c = jax.vmap(lambda _: attn.init_kv_cache(
            batch, cfg.num_kv_heads, max_len, hd))(
                jnp.arange(cfg.num_layers))
        cross = {
            "k": jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads,
                            cfg.encoder_seq, hd), jnp.bfloat16),
            "v": jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads,
                            cfg.encoder_seq, hd), jnp.bfloat16),
        }
        return {"self": self_c, "cross": cross}

    def prefill(params, batch, cache) -> PyTree:
        """Run the encoder once and stash cross K/V in the cache."""
        enc = encode(params, batch["frames"])

        def per_layer(p):
            k, v = _encoder_kv(p["cross_attn"], enc, cfg)
            return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

        ks, vs = xmap_seq(per_layer, params["layers"], name="xkv_layers")
        return {"self": cache["self"], "cross": {"k": ks, "v": vs}}

    def decode_step(params, tokens: Array, cache: PyTree
                    ) -> tuple[Array, PyTree]:
        B = tokens.shape[0]
        pos = cache["self"]["len"][0]
        x = embed(params["embed"], tokens)
        x = x + sinusoidal_positions(8192, cfg.d_model,
                                     x.dtype)[pos][None, None]

        def body(x, scanned):
            p, self_c, ck, cv = scanned
            h, self_c = attn.attention_decode(
                p["self_attn"], layernorm(p["ln1"], x), self_c,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_freqs=None)
            x = x + h
            x = x + _cross_attend(p["cross_attn"],
                                  layernorm(p["ln_x"], x), ck, cv, cfg)
            x = x + gelu_mlp(p["mlp"], layernorm(p["ln2"], x))
            return x, self_c

        x, new_self = xscan(
            body, x, (params["layers"], cache["self"],
                      cache["cross"]["k"], cache["cross"]["v"]),
            name="dec_layers")
        x = layernorm(params["final_norm"], x)
        logits = _mask_pad_cols(
            unembed(params["embed"], x, pad_to=_pad_vocab(cfg)),
            cfg.vocab_size)
        return logits[:, 0, :], {"self": new_self, "cross": cache["cross"]}

    return Model(cfg=cfg, init=init, forward=forward,
                 init_cache=init_cache, decode_step=decode_step,
                 prefill=prefill)


# ===========================================================================
# xLSTM (ssm family)
# ===========================================================================

def _build_xlstm(cfg: ModelConfig) -> Model:
    per_super = cfg.slstm_every                     # 8 ⇒ 7 mLSTM + 1 sLSTM
    n_super = cfg.num_layers // per_super
    n_m = per_super - 1

    def init(key) -> PyTree:
        ke, km, ks = jax.random.split(key, 3)

        def init_super(k):
            k1, k2 = jax.random.split(k)
            return {
                "mlstm": _stack_init(
                    lambda kk: {"ln": init_rmsnorm(cfg.d_model),
                                "mlstm": xlstm_mod.init_mlstm(
                                    kk, cfg.d_model, cfg.num_heads)},
                    k1, n_m),
                "slstm": {"ln": init_rmsnorm(cfg.d_model),
                          "slstm": xlstm_mod.init_slstm(
                              k2, cfg.d_model, cfg.num_heads)},
            }

        return {
            "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model),
            "superblocks": _stack_init(init_super, km, n_super),
            "final_norm": init_rmsnorm(cfg.d_model),
        }

    def forward(params, batch):
        x = embed(params["embed"], batch["tokens"])
        x = shard(x, ("pod", "data"), "model", None)

        def m_block(x, p):
            return x + xlstm_mod.mlstm_train(
                p["mlstm"], rmsnorm(p["ln"], x, cfg.norm_eps),
                num_heads=cfg.num_heads, impl=cfg.mixer_impl), None

        def super_body(x, p):
            x, _ = xscan(m_block, x, p["mlstm"], name="mlstm_blocks")
            x = x + xlstm_mod.slstm_train(
                p["slstm"]["slstm"],
                rmsnorm(p["slstm"]["ln"], x, cfg.norm_eps),
                num_heads=cfg.num_heads)
            return shard(x, ("pod", "data"), "model", None), None

        # remat at the SUPERBLOCK level: only superblock-boundary
        # activations persist; the mLSTM chunk states (1024x1024 matrix
        # memories, the dominant stash) are recomputed in the bwd pass
        x, _ = xscan(super_body, x, params["superblocks"],
                     name="superblocks", remat=cfg.remat)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, pad_to=_pad_vocab(cfg))
        logits = shard(logits, ("pod", "data"), None, "model")
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(batch: int, max_len: int) -> PyTree:
        del max_len   # recurrent state is O(1) in sequence length
        m = jax.vmap(lambda _: jax.vmap(lambda __: xlstm_mod.init_mlstm_cache(
            batch, cfg.d_model, cfg.num_heads))(jnp.arange(n_m)))(
                jnp.arange(n_super))
        s = jax.vmap(lambda _: xlstm_mod.init_slstm_state(
            batch, cfg.d_model, cfg.num_heads))(jnp.arange(n_super))
        return {"mlstm": m, "slstm": s, "len": jnp.zeros((), jnp.int32)}

    def decode_step(params, tokens, cache):
        x = embed(params["embed"], tokens)

        def super_body(x, scanned):
            p, mc, sc = scanned

            def m_block(x, inner):
                bp, bc = inner
                h, bc = xlstm_mod.mlstm_decode(
                    bp["mlstm"], rmsnorm(bp["ln"], x, cfg.norm_eps),
                    bc, num_heads=cfg.num_heads)
                return x + h, bc

            x, mc = xscan(m_block, x, (p["mlstm"], mc),
                          name="mlstm_blocks")
            h, sc = xlstm_mod.slstm_decode(
                p["slstm"]["slstm"],
                rmsnorm(p["slstm"]["ln"], x, cfg.norm_eps), sc,
                num_heads=cfg.num_heads)
            return x + h, (mc, sc)

        x, (mc, sc) = xscan(
            super_body, x, (params["superblocks"], cache["mlstm"],
                            cache["slstm"]), name="superblocks")
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_pad_cols(
            unembed(params["embed"], x, pad_to=_pad_vocab(cfg)),
            cfg.vocab_size)
        return logits[:, 0, :], {"mlstm": mc, "slstm": sc,
                                 "len": cache["len"] + 1}

    return Model(cfg=cfg, init=init, forward=forward,
                 init_cache=init_cache, decode_step=decode_step)


# ===========================================================================
# zamba2 (hybrid: mamba2 + shared attention)
# ===========================================================================

def _build_zamba(cfg: ModelConfig) -> Model:
    per = cfg.attn_every                              # 6 mamba per attn
    n_super = cfg.num_layers // per                   # 13 for 81 layers
    n_tail = cfg.num_layers - n_super * per           # 3

    def init_mamba_block(k):
        return {"ln": init_rmsnorm(cfg.d_model),
                "mamba": ssm_mod.init_mamba2(k, cfg.d_model, cfg.ssm_state,
                                             cfg.ssm_head_dim)}

    def init(key) -> PyTree:
        ke, km, kt, ka, kf = jax.random.split(key, 5)
        k1, k2 = jax.random.split(ka)
        shared = {
            "ln1": init_rmsnorm(cfg.d_model),
            "shared_attn": attn.init_attention(
                k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.resolved_head_dim),
            "ln2": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
        }
        return {
            "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model),
            "superblocks": _stack_init(
                lambda k: _stack_init(init_mamba_block, k, per), km,
                n_super),
            "tail_blocks": _stack_init(init_mamba_block, kt, n_tail),
            "shared": shared,
            "final_norm": init_rmsnorm(cfg.d_model),
        }

    def _mamba_scan(x, blocks):
        def body(x, p):
            h = ssm_mod.mamba2_train(
                p["mamba"], rmsnorm(p["ln"], x, cfg.norm_eps),
                d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                impl=cfg.mixer_impl)
            return x + h, None

        x, _ = xscan(body, x, blocks, name="mamba_blocks",
                     remat=cfg.remat)
        return x

    def _shared_attn_apply(shared, x):
        h = attn.attention_train(
            shared["shared_attn"], rmsnorm(shared["ln1"], x, cfg.norm_eps),
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_freqs=None,
            window=cfg.window, impl=cfg.attn_impl)
        x = x + h
        x = x + mlp(shared["mlp"], rmsnorm(shared["ln2"], x, cfg.norm_eps))
        return shard(x, ("pod", "data"), "model", None)

    def forward(params, batch):
        x = embed(params["embed"], batch["tokens"])
        x = shard(x, ("pod", "data"), "model", None)

        def super_body(x, blocks):
            x = _mamba_scan(x, blocks)
            x = _shared_attn_apply(params["shared"], x)
            return x, None

        x, _ = xscan(super_body, x, params["superblocks"],
                     name="superblocks")
        x = _mamba_scan(x, params["tail_blocks"])
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, pad_to=_pad_vocab(cfg))
        logits = shard(logits, ("pod", "data"), None, "model")
        return logits, jnp.zeros((), jnp.float32)

    def init_cache(batch: int, max_len: int) -> PyTree:
        eff = min(max_len, cfg.window) if cfg.window else max_len
        mamba_c = lambda n: jax.vmap(lambda _: ssm_mod.init_mamba2_cache(
            batch, cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim))(
                jnp.arange(n))
        attn_c = jax.vmap(lambda _: attn.init_kv_cache(
            batch, cfg.num_kv_heads, eff, cfg.resolved_head_dim))(
                jnp.arange(n_super))
        return {
            "super": jax.vmap(lambda _: mamba_c(per))(jnp.arange(n_super)),
            "tail": mamba_c(n_tail),
            "attn": attn_c,
        }

    def decode_step(params, tokens, cache):
        x = embed(params["embed"], tokens)

        def mamba_step(x, inner):
            p, c = inner
            h, c = ssm_mod.mamba2_decode(
                p["mamba"], rmsnorm(p["ln"], x, cfg.norm_eps), c,
                d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
            return x + h, c

        def super_body(x, scanned):
            blocks, mc, ac = scanned
            x, mc = xscan(mamba_step, x, (blocks, mc),
                          name="mamba_blocks")
            h, ac = attn.attention_decode(
                params["shared"]["shared_attn"],
                rmsnorm(params["shared"]["ln1"], x, cfg.norm_eps), ac,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_freqs=None,
                window=cfg.window)
            x = x + h
            x = x + mlp(params["shared"]["mlp"],
                        rmsnorm(params["shared"]["ln2"], x, cfg.norm_eps))
            return x, (mc, ac)

        x, (mc, ac) = xscan(
            super_body, x,
            (params["superblocks"], cache["super"], cache["attn"]),
            name="superblocks")
        x, tc = xscan(mamba_step, x,
                      (params["tail_blocks"], cache["tail"]),
                      name="tail_blocks")
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _mask_pad_cols(
            unembed(params["embed"], x, pad_to=_pad_vocab(cfg)),
            cfg.vocab_size)
        return logits[:, 0, :], {"super": mc, "tail": tc, "attn": ac}

    return Model(cfg=cfg, init=init, forward=forward,
                 init_cache=init_cache, decode_step=decode_step)


# ===========================================================================
# factory
# ===========================================================================

def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_decoder_lm(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    if cfg.family == "ssm":
        return _build_xlstm(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
