"""Base layers: norms, MLPs, embeddings, RoPE, losses.

Pure-JAX (no flax): params are pytrees of jnp arrays created by `init_*`
functions; `apply`-style functions are pure. Sharding is annotated at the
model level via logical PartitionSpecs (see models/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def _normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype=dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def init_layernorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: Optional[float] = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"kernel": _normal(key, (d_in, d_out), scale)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: dict, x: Array) -> Array:
    y = jnp.einsum("...d,df->...f", x, p["kernel"].astype(x.dtype))
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def init_mlp(key, d: int, d_ff: int) -> dict:
    """Gated SiLU MLP (llama-style)."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi_gate": init_dense(k1, d, d_ff),
            "wi_up": init_dense(k2, d, d_ff),
            "wo": init_dense(k3, d_ff, d, scale=d_ff ** -0.5)}


def mlp(p: dict, x: Array) -> Array:
    from .sharding import shard
    h = jax.nn.silu(dense(p["wi_gate"], x)) * dense(p["wi_up"], x)
    # pin the TP sharding of the hidden activation: GSPMD propagation can
    # lose it across remat/while boundaries, which materializes replicated
    # (B,T,ff) tensors and all-reduces them in the backward pass
    h = shard(h, ("pod", "data"), None, "model")
    return dense(p["wo"], h)


def init_gelu_mlp(key, d: int, d_ff: int) -> dict:
    """Plain GELU MLP (whisper/ViT-style)."""
    k1, k2 = jax.random.split(key)
    return {"wi": init_dense(k1, d, d_ff, bias=True),
            "wo": init_dense(k2, d_ff, d, bias=True, scale=d_ff ** -0.5)}


def gelu_mlp(p: dict, x: Array) -> Array:
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x)))


# ---------------------------------------------------------------------------
# Embeddings & positions
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int) -> dict:
    # d^-0.5 keeps tied-unembedding logits O(1) at init
    return {"table": _normal(key, (vocab, d), d ** -0.5)}


def embed(p: dict, tokens: Array, dtype=jnp.bfloat16) -> Array:
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed(p: dict, x: Array, pad_to: Optional[int] = None) -> Array:
    """Logits from the (possibly tied) embedding table. f32 output.

    `pad_to` zero-pads the vocab dim so it divides the "model" mesh axis —
    unshardable vocabs (minicpm's 122753) would otherwise force replicated
    (B,T,V) f32 logits (~32 GB/device at train_4k). cross_entropy masks
    the padding columns to -inf.
    """
    table = p["table"].astype(jnp.float32)
    if pad_to is not None and pad_to > table.shape[0]:
        table = jnp.pad(table, ((0, pad_to - table.shape[0]), (0, 0)))
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table)


def sinusoidal_positions(seq: int, d: int, dtype=jnp.float32) -> Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)],
                           axis=-1).astype(dtype)


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                      # (head_dim/2,)


def apply_rope(x: Array, positions: Array, freqs: Array) -> Array:
    """x: (..., T, D); positions: broadcastable to (..., T)."""
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: Array, labels: Array,
                  mask: Optional[Array] = None,
                  valid_vocab: Optional[int] = None) -> Array:
    """Mean token NLL in f32. logits: (..., Vp) f32; labels int32.

    Shard-friendly: the gold logit is extracted with a select+reduce over
    the (possibly "model"-sharded, possibly padded) vocab dim instead of
    take_along_axis, so GSPMD lowers it to a local reduce + psum rather
    than a cross-shard gather. Columns ≥ valid_vocab (padding) are -inf'd.
    """
    Vp = logits.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                   logits.ndim - 1)
    if valid_vocab is not None and valid_vocab < Vp:
        logits = jnp.where(col < valid_vocab, logits, -jnp.inf)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.where(col == labels[..., None], logits, 0.0).sum(axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
