"""Ray-sphere tracer Pallas kernel (paper benchmark: Ray).

Per-lane nearest-hit Lambert shading against a small sphere list. The
sphere table rides along as a whole-array block (constant index map) — the
TPU analogue of OpenCL constant memory — and the hit loop is unrolled at
trace time (S is static). Scene-dependent shading cost is the irregularity
source: rays that miss everything do no shading work in the paper's GPU;
on TPU the masked lanes are wasted VPU slots, which is precisely the
divergence penalty modeled as ``alpha`` in the DES calibration.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ray_kernel(dx_ref, dy_ref, dz_ref, sph_ref, o_ref, *, num_spheres: int):
    dx, dy, dz = dx_ref[...], dy_ref[...], dz_ref[...]
    light = (0.577, 0.577, 0.577)
    best_t = jnp.full(dx.shape, jnp.inf, dtype=dx.dtype)
    shade = jnp.zeros_like(dx)
    for s in range(num_spheres):  # static unroll: constant-memory loop
        cx = sph_ref[s, 0]
        cy = sph_ref[s, 1]
        cz = sph_ref[s, 2]
        r = sph_ref[s, 3]
        alb = sph_ref[s, 4]
        b = dx * cx + dy * cy + dz * cz
        c = cx * cx + cy * cy + cz * cz - r * r
        disc = b * b - c
        hit = disc > 0.0
        t = b - jnp.sqrt(jnp.maximum(disc, 0.0))
        hit = hit & (t > 1e-3) & (t < best_t)
        nx, ny, nz = dx * t - cx, dy * t - cy, dz * t - cz
        inv = 1.0 / jnp.maximum(r, 1e-6)
        lam = jnp.maximum(0.0, (nx * light[0] + ny * light[1] +
                                nz * light[2]) * inv)
        best_t = jnp.where(hit, t, best_t)
        shade = jnp.where(hit, alb * lam, shade)
    o_ref[...] = shade


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def raytrace(dirx: jax.Array, diry: jax.Array, dirz: jax.Array,
             spheres: jax.Array, *, bm: int = 128,
             interpret: bool = True) -> jax.Array:
    """Shade unit rays from the origin. dir*: equal shapes; spheres (S, 5)."""
    shape = dirx.shape
    n = dirx.size
    lanes = 128
    rows = -(-n // lanes)
    bm = min(bm, rows)
    pr = (-rows) % bm
    grid_rows = rows + pr

    def prep(x):
        flat = jnp.pad(x.reshape(-1), (0, rows * lanes - n))
        return jnp.pad(flat.reshape(rows, lanes), ((0, pr), (0, 0)))

    S = spheres.shape[0]
    spec = pl.BlockSpec((bm, lanes), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_ray_kernel, num_spheres=S),
        out_shape=jax.ShapeDtypeStruct((grid_rows, lanes), dirx.dtype),
        grid=(grid_rows // bm,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec((S, 5), lambda i: (0, 0))],
        out_specs=spec,
        interpret=interpret,
    )(prep(dirx), prep(diry), prep(dirz), spheres)
    return out.reshape(-1)[:n].reshape(shape)


def demo_spheres(num: int = 8, seed: int = 3) -> jax.Array:
    """A reproducible little scene: `num` spheres in front of the camera."""
    import numpy as np
    rng = np.random.default_rng(seed)
    c = rng.uniform(-2.0, 2.0, size=(num, 3)) + np.array([0.0, 0.0, 5.0])
    r = rng.uniform(0.3, 1.0, size=(num, 1))
    alb = rng.uniform(0.4, 1.0, size=(num, 1))
    return jnp.asarray(np.concatenate([c, r, alb], axis=1),
                       dtype=jnp.float32)
