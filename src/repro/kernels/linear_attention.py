"""Chunked gated linear attention / SSD Pallas kernel.

One kernel serves two sequence mixers of the model zoo:
  * Mamba-2 SSD (zamba2-7b): scalar per-step decay a_t = exp(-softplus(dt)·A)
  * mLSTM (xlstm-1.3b): forget-gate decay (the exp-input-gate stabilizer is
    applied by the model layer on top of the kernel's linear recurrence)

Recurrence: S_t = d_t · S_{t-1} + k_tᵀ v_t ;  o_t = q_t · S_t, with
d_t = exp(log_decay_t). The chunked form processes C timesteps per grid
step: an intra-chunk causal part (masked (C×C) matmul on the MXU) plus an
inter-chunk part through the carried state S — which lives in VMEM scratch
and persists across the sequential chunk axis of the TPU grid. This is the
textbook TPU adaptation of GPU chunked-scan kernels: the sequential-grid
guarantee replaces the inter-block atomics/barriers a CUDA implementation
needs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gla_kernel(q_ref, k_ref, v_ref, ld_ref, o_ref, state_ref, *,
                chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, Dk)
    k = k_ref[0].astype(jnp.float32)          # (C, Dk)
    v = v_ref[0].astype(jnp.float32)          # (C, Dv)
    ld = ld_ref[0].astype(jnp.float32)        # (1, C) log decays

    cum = jnp.cumsum(ld, axis=1)              # inclusive cumsum (1, C)
    total = cum[0, chunk - 1]                 # log decay over whole chunk

    # intra-chunk: A_ij = q_i·k_j · exp(cum_i - cum_j) for i >= j
    # (each key k_j is decayed by every step after j up to i, inclusive of
    #  step i's decay because S is updated before the readout)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, C)
    ci = jnp.transpose(cum)                   # (C, 1)
    gamma = jnp.exp(ci - cum)                 # (C, C) = exp(cum_i - cum_j)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(row >= col, s * gamma, 0.0)
    intra = jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # inter-chunk: queries read the carried state decayed to their step
    q_dec = q * jnp.exp(ci)                   # (C, Dk) · exp(cum_i)
    inter = jax.lax.dot_general(q_dec, state_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    o_ref[0] = (intra + inter).astype(o_ref.dtype)

    # state update: S ← exp(total)·S + Σ_j exp(total - cum_j) k_jᵀ v_j
    k_dec = k * jnp.exp(total - cum).reshape(chunk, 1)
    state_ref[...] = jnp.exp(total) * state_ref[...] + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     log_decay: jax.Array, *, chunk: int = 128,
                     interpret: bool = True) -> jax.Array:
    """q, k: (BH, T, Dk); v: (BH, T, Dv); log_decay: (BH, T) (entries ≤ 0).

    Returns (BH, T, Dv). T is padded to a chunk multiple (padded steps use
    decay 1 and zero k/v, which leaves the recurrence untouched).
    """
    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    chunk = min(chunk, T)
    pt = (-T) % chunk
    if pt:
        q = jnp.pad(q, ((0, 0), (0, pt), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pt), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pt), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pt)))
    Tp = T + pt
    ld = log_decay.reshape(BH, Tp // chunk, chunk)

    out = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=chunk),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, Dv), q.dtype),
        grid=(BH, Tp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, Dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, Dk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, Dv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Dv), lambda h, c: (h, c, 0)),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, ld)
    return out[:, :T]
