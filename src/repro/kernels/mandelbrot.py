"""Mandelbrot escape-iteration Pallas kernel (paper benchmark: Mandelbrot).

The data-dependent `while_loop` terminates a block as soon as *all* of its
lanes have escaped — this is the TPU rendering of the benchmark's
irregularity: blocks over the fractal interior run the full iteration
budget, background blocks exit after a handful of steps. Package runtimes
therefore vary with data content exactly as the paper's Fig. 1 requires,
which is what the dynamic schedulers exploit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mandel_kernel(cre_ref, cim_ref, o_ref, *, max_iter: int):
    cre = cre_ref[...]
    cim = cim_ref[...]

    def cond(st):
        i, _, _, _, alive = st
        return (i < max_iter) & jnp.any(alive)

    def body(st):
        i, zr, zi, it, alive = st
        zr2, zi2 = zr * zr, zi * zi
        alive = alive & (zr2 + zi2 <= 4.0)
        zr_n = zr2 - zi2 + cre
        zi_n = 2.0 * zr * zi + cim
        zr = jnp.where(alive, zr_n, zr)
        zi = jnp.where(alive, zi_n, zi)
        it = it + alive.astype(jnp.float32)
        return i + 1, zr, zi, it, alive

    st = (jnp.int32(0), jnp.zeros_like(cre), jnp.zeros_like(cim),
          jnp.zeros_like(cre), jnp.ones(cre.shape, dtype=bool))
    _, _, _, it, _ = jax.lax.while_loop(cond, body, st)
    o_ref[...] = it


@functools.partial(jax.jit, static_argnames=("max_iter", "bm", "interpret"))
def mandelbrot(cre: jax.Array, cim: jax.Array, *, max_iter: int = 64,
               bm: int = 128, interpret: bool = True) -> jax.Array:
    """Escape iterations (f32) for points cre + i*cim; any equal shapes."""
    shape = cre.shape
    n = cre.size
    lanes = 128
    rows = -(-n // lanes)
    bm = min(bm, rows)
    pr = (-rows) % bm

    def prep(x):
        flat = jnp.pad(x.reshape(-1), (0, rows * lanes - n),
                       constant_values=4.0)  # pad escapes immediately
        return jnp.pad(flat.reshape(rows, lanes), ((0, pr), (0, 0)),
                       constant_values=4.0)

    grid_rows = rows + pr
    out = pl.pallas_call(
        functools.partial(_mandel_kernel, max_iter=max_iter),
        out_shape=jax.ShapeDtypeStruct((grid_rows, lanes), jnp.float32),
        grid=(grid_rows // bm,),
        in_specs=[pl.BlockSpec((bm, lanes), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
        interpret=interpret,
    )(prep(cre), prep(cim))
    return out.reshape(-1)[:n].reshape(shape)
