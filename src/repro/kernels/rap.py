"""Resource Allocation Problem Pallas kernel (paper benchmark: Rap).

Row-parallel diminishing-returns utility over variable-length candidate
lists. Row lengths differ wildly (the benchmark's irregularity); the kernel
masks with a broadcasted iota against the per-row length column. On TPU the
sublane reduction lands in a (bm, 1) output block — the wrapper squeezes it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rap_kernel(val_ref, len_ref, o_ref):
    vals = val_ref[...]                      # (bm, L)
    lens = len_ref[...]                      # (bm, 1) int32
    L = vals.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
    mask = col < lens                        # broadcast (bm, 1) -> (bm, L)
    util = jnp.log1p(jnp.maximum(vals, 0.0))
    o_ref[...] = jnp.where(mask, util, 0.0).sum(axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def rap(values: jax.Array, lengths: jax.Array, *, bm: int = 256,
        interpret: bool = True) -> jax.Array:
    """values: (N, L) f32, lengths: (N,) int32 -> (N,) f32 utilities."""
    N, L = values.shape
    bm = min(bm, N)
    pn = (-N) % bm
    vals = jnp.pad(values, ((0, pn), (0, 0)))
    lens = jnp.pad(lengths, (0, pn)).reshape(-1, 1)
    Np = N + pn
    out = pl.pallas_call(
        _rap_kernel,
        out_shape=jax.ShapeDtypeStruct((Np, 1), values.dtype),
        grid=(Np // bm,),
        in_specs=[pl.BlockSpec((bm, L), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(vals, lens)
    return out[:N, 0]
