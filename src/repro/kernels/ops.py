"""Jit'd public wrappers + implementation dispatch for all kernels.

``impl="pallas"`` runs the Pallas kernel (interpret mode off-TPU),
``impl="ref"`` the pure-jnp oracle. ``package_kernel(name)`` adapts a
benchmark to the Coexecutor Runtime's package signature
``fn(offset, *chunks) -> chunk_out`` so the paper's six benchmarks can be
co-executed exactly like Listing 1.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .gaussian import gaussian_blur
from .linear_attention import linear_attention
from .mandelbrot import mandelbrot
from .matmul import matmul
from .rap import rap
from .raytrace import demo_spheres, raytrace
from .taylor import taylor_sin


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(pallas_fn: Callable, ref_fn: Callable, impl: str, *a, **kw):
    if impl == "ref":
        return ref_fn(*a, **kw)
    if impl == "pallas":
        return pallas_fn(*a, interpret=not _on_tpu(), **kw)
    raise ValueError(f"impl must be 'pallas' or 'ref', got {impl!r}")


def matmul_op(a, b, *, impl: str = "pallas", **kw):
    return _dispatch(matmul, ref.matmul, impl, a, b, **kw)


def gaussian_op(img, *, impl: str = "pallas", **kw):
    return _dispatch(gaussian_blur, ref.gaussian_blur, impl, img, **kw)


def taylor_op(x, *, impl: str = "pallas", **kw):
    return _dispatch(taylor_sin, ref.taylor_sin, impl, x, **kw)


def mandelbrot_op(cre, cim, *, impl: str = "pallas", **kw):
    return _dispatch(mandelbrot, ref.mandelbrot, impl, cre, cim, **kw)


def raytrace_op(dx, dy, dz, spheres, *, impl: str = "pallas", **kw):
    return _dispatch(raytrace, ref.raytrace, impl, dx, dy, dz, spheres, **kw)


def rap_op(values, lengths, *, impl: str = "pallas", **kw):
    return _dispatch(rap, ref.rap, impl, values, lengths, **kw)


def flash_attention_op(q, k, v, *, impl: str = "pallas", **kw):
    return _dispatch(flash_attention, ref.attention, impl, q, k, v, **kw)


def linear_attention_op(q, k, v, log_decay, *, impl: str = "pallas", **kw):
    return _dispatch(linear_attention, ref.linear_attention, impl,
                     q, k, v, log_decay, **kw)


# ---------------------------------------------------------------------------
# Coexecutor package adapters (the paper's Listing-1 shape)
# ---------------------------------------------------------------------------

def package_kernel(name: str) -> Callable:
    """Package-form kernel ``fn(offset, *chunks) -> chunk`` for `name`.

    Index spaces match the DES workload profiles: rows for gaussian/matmul/
    rap, flat elements (row-blocks of 128 lanes) for taylor/mandelbrot/ray.
    """
    if name == "taylor":
        def fn(offset, chunk):
            return ref.taylor_sin(chunk)
        return fn
    if name == "gaussian":
        def fn(offset, s0, s1, s2, s3, s4):
            t = [float(x) for x in ref.GAUSS_TAPS]
            vert = (t[0] * s0 + t[1] * s1 + t[2] * s2 + t[3] * s3 +
                    t[4] * s4)
            xp = jnp.pad(vert, ((0, 0), (2, 2)))
            W = vert.shape[1]
            return (t[0] * xp[:, 0:W] + t[1] * xp[:, 1:W + 1] +
                    t[2] * xp[:, 2:W + 2] + t[3] * xp[:, 3:W + 3] +
                    t[4] * xp[:, 4:W + 4])
        return fn
    if name == "matmul":
        def fn(offset, a_rows, b):
            return ref.matmul(a_rows, b)
        return fn
    if name == "mandelbrot":
        def fn(offset, cre, cim):
            return ref.mandelbrot(cre, cim)
        return fn
    if name == "ray":
        spheres = demo_spheres()
        def fn(offset, dx, dy, dz):
            return ref.raytrace(dx, dy, dz, spheres)
        return fn
    if name == "rap":
        def fn(offset, values, lengths):
            return ref.rap(values, lengths)
        return fn
    raise KeyError(name)
