"""Jit'd public wrappers + the typed co-executable kernels.

Two surfaces live here:

* ``<name>_op(...)`` — jit-friendly wrappers with implementation dispatch
  along the :data:`KERNEL_IMPLS` axis: ``impl="pallas"`` runs the Pallas
  kernel (interpret mode off-TPU), ``impl="xla"`` the jit-compiled jnp
  oracle (the production XLA lowering), ``impl="ref"`` the eager oracle.
  The default is backend-aware (:func:`default_impl`): Pallas on TPU,
  XLA elsewhere — so importing code never pays interpret-mode cost by
  accident.
* the paper's six benchmarks as **typed co-executable kernels**
  (:class:`~repro.core.dataplane.CoexecKernel`): each declares its
  per-argument partition semantics — SPLIT along an axis (with a halo for
  the Gaussian stencil), BROADCAST for whole-array operands (MatMul's
  ``B``, Ray's sphere scene) — and an output slot, and registers in the
  :mod:`repro.api.registry` kernel registry next to the schedulers and
  workloads. Third-party kernels register the same way, without editing
  core; resolve any of them with ``repro.api.build_kernel(name)`` and
  hand the result straight to ``CoexecutorRuntime.launch`` /
  ``CoexecEngine.submit``.

Each registration also carries a demo-input generator
(``repro.api.kernel_demo_inputs``) so the serving benchmarks and the
USM-vs-BUFFERS parity tests can drive every registered kernel without
per-kernel glue. The pre-registry ``package_kernel(name)`` if-chain (and
later its deprecation shim) is gone: the registry is the only entry
point.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dataplane import (ArgRole, ArgSpec, CoexecKernel,
                                  OutputSpec)

from . import ref
from .flash_attention import flash_attention
from .gaussian import gaussian_blur, gaussian_blur_halo
from .linear_attention import linear_attention
from .mandelbrot import mandelbrot
from .matmul import matmul
from .rap import rap
from .raytrace import demo_spheres, raytrace
from .taylor import taylor_sin

#: The implementation-variant axis every wrapper / registered kernel
#: understands. "pallas" = the hand-written Pallas body (interpret mode
#: off-TPU), "xla" = the jit-compiled jnp oracle (the production XLA
#: path), "ref" = the eager jnp oracle (bitwise ground truth).
KERNEL_IMPLS = ("pallas", "xla", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_impl() -> str:
    """The backend-aware default variant: Pallas on TPU, XLA elsewhere.

    Off-TPU the Pallas bodies only run in interpret mode — orders of
    magnitude slower than the compiled oracle — so nothing should select
    them implicitly.
    """
    return "pallas" if _on_tpu() else "xla"


def resolve_impl(impl: str | None = None) -> str:
    """Canonicalize an impl request to one of :data:`KERNEL_IMPLS`.

    Args:
        impl: ``None`` / ``""`` / ``"auto"`` resolve via
            :func:`default_impl`; otherwise must be a member of
            :data:`KERNEL_IMPLS`.

    Returns:
        The canonical implementation name.

    Raises:
        ValueError: unknown implementation name.
    """
    if impl in (None, "", "auto"):
        return default_impl()
    if impl not in KERNEL_IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; choose from "
                         f"{('auto',) + KERNEL_IMPLS}")
    return impl


@functools.lru_cache(maxsize=None)
def _jit_oracle(ref_fn: Callable, kw_items: tuple) -> Callable:
    # one compiled entry per (oracle, static-options) pair — jitting a
    # fresh partial per call would recompile every time
    return jax.jit(functools.partial(ref_fn, **dict(kw_items)))


def _dispatch(pallas_fn: Callable, ref_fn: Callable, impl: str | None,
              *a, **kw):
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref_fn(*a, **kw)
    if impl == "xla":
        return _jit_oracle(ref_fn, tuple(sorted(kw.items())))(*a)
    return pallas_fn(*a, interpret=not _on_tpu(), **kw)


def matmul_op(a, b, *, impl: str | None = None, **kw):
    return _dispatch(matmul, ref.matmul, impl, a, b, **kw)


def gaussian_op(img, *, impl: str | None = None, **kw):
    return _dispatch(gaussian_blur, ref.gaussian_blur, impl, img, **kw)


def taylor_op(x, *, impl: str | None = None, **kw):
    return _dispatch(taylor_sin, ref.taylor_sin, impl, x, **kw)


def mandelbrot_op(cre, cim, *, impl: str | None = None, **kw):
    return _dispatch(mandelbrot, ref.mandelbrot, impl, cre, cim, **kw)


def raytrace_op(dx, dy, dz, spheres, *, impl: str | None = None, **kw):
    return _dispatch(raytrace, ref.raytrace, impl, dx, dy, dz, spheres, **kw)


def rap_op(values, lengths, *, impl: str | None = None, **kw):
    return _dispatch(rap, ref.rap, impl, values, lengths, **kw)


def flash_attention_op(q, k, v, *, impl: str | None = None, **kw):
    return _dispatch(flash_attention, ref.attention, impl, q, k, v, **kw)


def linear_attention_op(q, k, v, log_decay, *, impl: str | None = None,
                        **kw):
    return _dispatch(linear_attention, ref.linear_attention, impl,
                     q, k, v, log_decay, **kw)


# ---------------------------------------------------------------------------
# Typed co-executable kernels (registered; paper Listing-1 benchmarks)
# ---------------------------------------------------------------------------
# Factories are memoized so repeated build_kernel() calls return the same
# CoexecKernel object — the engines' jit caches and fusion keys hash on it.
# Each factory takes the `impl` axis; the public entry resolves "auto"
# before hitting the cache, so build_kernel("taylor") and
# build_kernel("taylor", impl=default_impl()) share one object.

_GAUSS_DEMO_W = 96        # demo image width (rows are the index space)
_MATMUL_DEMO_K = 32       # demo inner dim; B is (K, N2)
_MATMUL_DEMO_N2 = 24
_RAP_DEMO_L = 48          # demo candidate-resource count per row


def _impl_axis(inner: Callable) -> Callable:
    """Wrap a cached factory so its ``impl`` option resolves "auto" first.

    ``inner`` is the ``lru_cache``d builder keyed on the *canonical* impl
    name; resolving before the cache keeps the memoization contract
    (same options -> same kernel object) intact across the auto default.
    """
    @functools.wraps(inner)
    def factory(*, impl: str = "auto", **options) -> CoexecKernel:
        return inner(impl=resolve_impl(impl), **options)
    return factory


@functools.lru_cache(maxsize=None)
def _taylor_kernel_impl(*, impl: str, terms: int = 12) -> CoexecKernel:
    """Taylor-series sin over a split 1-D array (regular, compute-bound)."""
    if impl == "pallas":
        def fn(offset, x, _terms=int(terms)):
            return taylor_sin(x, terms=_terms, interpret=not _on_tpu())
    else:
        def fn(offset, x, _terms=int(terms)):
            return ref.taylor_sin(x, terms=_terms)

    return CoexecKernel("taylor", fn, (ArgSpec("x"),), OutputSpec())


_taylor_kernel = _impl_axis(_taylor_kernel_impl)


def _taylor_inputs(n: int, rng) -> list:
    return [rng.uniform(-2, 2, n).astype(np.float32)]


@functools.lru_cache(maxsize=None)
def _gaussian_kernel_impl(*, impl: str) -> CoexecKernel:
    """Separable 5x5 blur; rows split with a 2-row zero-filled halo.

    The halo is what the pre-protocol closure faked with five pre-shifted
    input copies: the data plane now hands each package its row range
    plus two rows of context on either side (zeros beyond the image, as
    in the reference's zero padding), so co-executed output matches
    :func:`repro.kernels.ref.gaussian_blur` on the full image exactly.
    The Pallas variant consumes the same halo'd chunk through
    :func:`~repro.kernels.gaussian.gaussian_blur_halo` (halo-aware
    BlockSpecs over the pre-shifted views).
    """
    if impl == "pallas":
        def fn(offset, img):
            return gaussian_blur_halo(img, interpret=not _on_tpu())
    else:
        def fn(offset, img):
            taps = jnp.asarray(ref.GAUSS_TAPS, dtype=img.dtype)
            rows = img.shape[0] - 4                # drop the 2+2 halo
            vert = sum(taps[d] * img[d:d + rows, :] for d in range(5))
            padded = jnp.pad(vert, ((0, 0), (2, 2)))
            W = vert.shape[1]
            return sum(taps[d] * padded[:, d:d + W] for d in range(5))

    return CoexecKernel("gaussian", fn, (ArgSpec("img", halo=2),),
                        OutputSpec(trailing=lambda ins: (ins[0].shape[1],)))


_gaussian_kernel = _impl_axis(_gaussian_kernel_impl)


def _gaussian_inputs(n: int, rng) -> list:
    return [rng.normal(size=(n, _GAUSS_DEMO_W)).astype(np.float32)]


@functools.lru_cache(maxsize=None)
def _matmul_kernel_impl(*, impl: str) -> CoexecKernel:
    """Row-split MatMul: A splits by rows, B broadcasts whole.

    The broadcast declaration is the protocol's point: the runtime knows
    ``B`` is not indexed by the launch's index space, so the USM plane
    shares it and the BUFFERS plane stages it per package (the paper's
    accessor-per-command-group cost), instead of the old contract that
    silently sliced every input by rows. The Pallas variant runs the
    tiled MXU kernel on each package's row block against the broadcast B.
    """
    if impl == "pallas":
        def fn(offset, a_rows, b):
            return matmul(a_rows, b, interpret=not _on_tpu())
    else:
        def fn(offset, a_rows, b):
            return ref.matmul(a_rows, b)

    return CoexecKernel(
        "matmul", fn,
        (ArgSpec("a"), ArgSpec("b", role=ArgRole.BROADCAST)),
        OutputSpec(trailing=lambda ins: (ins[1].shape[1],)))


_matmul_kernel = _impl_axis(_matmul_kernel_impl)


def _matmul_inputs(n: int, rng) -> list:
    return [rng.normal(size=(n, _MATMUL_DEMO_K)).astype(np.float32),
            rng.normal(size=(_MATMUL_DEMO_K,
                             _MATMUL_DEMO_N2)).astype(np.float32)]


@functools.lru_cache(maxsize=None)
def _mandelbrot_kernel_impl(*, impl: str,
                            max_iter: int = 64) -> CoexecKernel:
    """Escape iterations over split coordinate arrays (irregular)."""
    if impl == "pallas":
        def fn(offset, cre, cim, _it=int(max_iter)):
            return mandelbrot(cre, cim, max_iter=_it,
                              interpret=not _on_tpu())
    else:
        def fn(offset, cre, cim, _it=int(max_iter)):
            return ref.mandelbrot(cre, cim, max_iter=_it)

    return CoexecKernel("mandelbrot", fn,
                        (ArgSpec("cre"), ArgSpec("cim")), OutputSpec())


_mandelbrot_kernel = _impl_axis(_mandelbrot_kernel_impl)


def _mandelbrot_inputs(n: int, rng) -> list:
    return [rng.uniform(-2.2, 0.8, n).astype(np.float32),
            rng.uniform(-1.4, 1.4, n).astype(np.float32)]


@functools.lru_cache(maxsize=None)
def _ray_kernel_impl(*, impl: str) -> CoexecKernel:
    """Ray tracing: split ray directions, broadcast sphere scene.

    The scene is a trailing BROADCAST argument with a default (the demo
    scene), so both ``launch(n, kernel, [dx, dy, dz])`` and an explicit
    ``[dx, dy, dz, spheres]`` work.
    """
    if impl == "pallas":
        def fn(offset, dx, dy, dz, spheres):
            return raytrace(dx, dy, dz, spheres,
                            interpret=not _on_tpu())
    else:
        def fn(offset, dx, dy, dz, spheres):
            return ref.raytrace(dx, dy, dz, spheres)

    return CoexecKernel(
        "ray", fn,
        (ArgSpec("dx"), ArgSpec("dy"), ArgSpec("dz"),
         ArgSpec("spheres", role=ArgRole.BROADCAST,
                 default=lambda: np.asarray(demo_spheres()))),
        OutputSpec())


_ray_kernel = _impl_axis(_ray_kernel_impl)


def _ray_inputs(n: int, rng) -> list:
    dx, dy = rng.uniform(-0.4, 0.4, (2, n)).astype(np.float32)
    dz = np.sqrt(np.maximum(1 - dx**2 - dy**2, 0.5)).astype(np.float32)
    return [dx, dy, dz]


@functools.lru_cache(maxsize=None)
def _rap_kernel_impl(*, impl: str) -> CoexecKernel:
    """Resource-allocation rows: values and lengths split together."""
    if impl == "pallas":
        def fn(offset, values, lengths):
            return rap(values, lengths, interpret=not _on_tpu())
    else:
        def fn(offset, values, lengths):
            return ref.rap(values, lengths)

    return CoexecKernel("rap", fn,
                        (ArgSpec("values"), ArgSpec("lengths")),
                        OutputSpec())


_rap_kernel = _impl_axis(_rap_kernel_impl)


def _rap_inputs(n: int, rng) -> list:
    return [rng.normal(size=(n, _RAP_DEMO_L)).astype(np.float32),
            rng.integers(0, _RAP_DEMO_L, size=n).astype(np.int32)]


def _register_builtin_kernels() -> None:
    """Idempotently register the paper's six kernels (import side)."""
    from repro.api.registry import register_kernel

    register_kernel("taylor", _taylor_kernel, fields=("terms", "impl"),
                    demo_inputs=_taylor_inputs, overwrite=True)
    register_kernel("gaussian", _gaussian_kernel, fields=("impl",),
                    demo_inputs=_gaussian_inputs, overwrite=True)
    register_kernel("matmul", _matmul_kernel, fields=("impl",),
                    demo_inputs=_matmul_inputs, overwrite=True)
    register_kernel("mandelbrot", _mandelbrot_kernel,
                    fields=("max_iter", "impl"),
                    demo_inputs=_mandelbrot_inputs, overwrite=True)
    register_kernel("ray", _ray_kernel, fields=("impl",),
                    demo_inputs=_ray_inputs, overwrite=True)
    register_kernel("rap", _rap_kernel, fields=("impl",),
                    demo_inputs=_rap_inputs, overwrite=True)


_register_builtin_kernels()
