"""Blockwise-softmax (flash) attention Pallas kernel, TPU-native.

Used by the prefill/serve paths of the LM stack (training defaults to the
differentiable XLA path; see models/attention.py). Features: causal masking,
GQA (q-head blocks index their kv head via the index map), and sliding
windows (SWA) for h2o-danube3/zamba2-style configs.

Layout: grid (B*Hq, Tq/bq, Tk/bk) with the key axis innermost — TPU executes
it sequentially, so the running max/denominator/accumulator live in VMEM
scratch across key steps (online softmax). Fully-masked key blocks are
skipped with `pl.when`, which on real silicon elides both the DMA waits and
the MXU work for ~half the blocks under causal masking (and all but w/bk
blocks under SWA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability: causal ⇒ keys cannot start after the last
    # query; SWA ⇒ keys cannot end before the window of the first query.
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window is not None:
        reachable = reachable & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(reachable)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0].astype(jnp.float32)                # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= q_idx >= k_idx
        if window is not None:
            mask &= q_idx - k_idx < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = corr * l_ref[...] + \
            jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _finalize():
        # rows with no reachable keys keep l = 0 → emit zeros, not NaNs
        l = l_ref[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, T, D). Returns (B, Hq, T, D).

    Tq == Tk (prefill). Head dim D should be lane-aligned (≥128 ideal);
    smaller D is padded. GQA handled via the kv index map.
    """
    B, Hq, T, D = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = D ** -0.5

    bq = min(bq, T)
    bk = min(bk, T)
    pt = (-T) % max(bq, bk)
    Dp = max(D, 128)
    pd = Dp - D
    if pt or pd:
        pad = ((0, 0), (0, 0), (0, pt), (0, pd))
        q = jnp.pad(q, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    Tp = T + pt

    qf = q.reshape(B * Hq, Tp, Dp)
    kf = k.reshape(B * Hkv, Tp, Dp)
    vf = v.reshape(B * Hkv, Tp, Dp)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, seq_k=Tp)

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tp, Dp), q.dtype),
        grid=(B * Hq, Tp // bq, Tp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, Dp), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dp), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, Dp), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dp), lambda h, i, j: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denominator
            pltpu.VMEM((bq, Dp), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Tp, Dp)
    return out[:, :, :T, :D]
