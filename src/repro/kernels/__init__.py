"""Pallas TPU kernels for the paper's six benchmarks + production kernels.

Layout per kernel: `<name>.py` holds the `pl.pallas_call` + BlockSpec
implementation, `ref.py` the pure-jnp oracle, `ops.py` the jit'd wrapper
with impl dispatch plus the typed co-executable kernels
(:class:`~repro.core.dataplane.CoexecKernel`) registered in the
:mod:`repro.api.registry` kernel registry. Resolve them with
``repro.api.build_kernel(name)`` (the ``package_kernel`` shim was
removed when its deprecation window closed).
"""
from . import ref
from .flash_attention import flash_attention
from .gaussian import gaussian_blur, gaussian_blur_halo
from .linear_attention import linear_attention
from .mandelbrot import mandelbrot
from .matmul import matmul
from .ops import (KERNEL_IMPLS, default_impl, flash_attention_op,
                  gaussian_op, linear_attention_op, mandelbrot_op,
                  matmul_op, rap_op, raytrace_op, resolve_impl, taylor_op)
from .rap import rap
from .raytrace import demo_spheres, raytrace
from .taylor import taylor_sin

__all__ = [
    "KERNEL_IMPLS", "default_impl", "demo_spheres", "flash_attention",
    "flash_attention_op", "gaussian_blur", "gaussian_blur_halo",
    "gaussian_op", "linear_attention", "linear_attention_op", "mandelbrot",
    "mandelbrot_op", "matmul", "matmul_op", "rap",
    "rap_op", "raytrace", "raytrace_op", "ref", "resolve_impl",
    "taylor_op", "taylor_sin",
]
