"""Separable 5x5 Gaussian blur Pallas kernel (paper benchmark: Gaussian).

TPU adaptation of the stencil: BlockSpec element offsets are multiples of
the block shape, so vertical halos cannot be expressed as overlapping
blocks. Instead the wrapper materializes the five vertically-shifted views
(zero-padded) — XLA fuses these into cheap slices — and the kernel fuses the
vertical tap combine with an in-register horizontal pass over a full-width
row block. One VMEM round trip per pixel, no halo exchange.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GAUSS_TAPS


def _blur_kernel(s0, s1, s2, s3, s4, o_ref):
    t = [float(x) for x in GAUSS_TAPS]
    vert = (t[0] * s0[...] + t[1] * s1[...] + t[2] * s2[...] +
            t[3] * s3[...] + t[4] * s4[...])
    # horizontal pass within the full-width block (zero-padded edges)
    xp = jnp.pad(vert, ((0, 0), (2, 2)))
    W = vert.shape[1]
    o_ref[...] = (t[0] * xp[:, 0:W] + t[1] * xp[:, 1:W + 1] +
                  t[2] * xp[:, 2:W + 2] + t[3] * xp[:, 3:W + 3] +
                  t[4] * xp[:, 4:W + 4])


def _blur_blocks(padded: jax.Array, H: int, W: int, bm: int,
                 interpret: bool) -> jax.Array:
    """Run the blur over `padded` (H+4 rows incl. the 2+2 vertical halo).

    Returns the (H, W) interior result; rows past H in the last block are
    computed on zero padding and sliced off.
    """
    pm = (-H) % bm
    padded = jnp.pad(padded, ((0, pm), (0, 0)))
    Hp = H + pm
    shifts = [jax.lax.dynamic_slice_in_dim(padded, d, Hp, axis=0)
              for d in range(5)]
    spec = pl.BlockSpec((bm, W), lambda i: (i, 0))
    out = pl.pallas_call(
        _blur_kernel,
        out_shape=jax.ShapeDtypeStruct((Hp, W), padded.dtype),
        grid=(Hp // bm,),
        in_specs=[spec] * 5,
        out_specs=spec,
        interpret=interpret,
    )(*shifts)
    return out[:H]


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gaussian_blur(img: jax.Array, *, bm: int = 128,
                  interpret: bool = True) -> jax.Array:
    """5x5 separable Gaussian blur, zero padding. img: (H, W) float32."""
    H, W = img.shape
    return _blur_blocks(jnp.pad(img, ((2, 2), (0, 0))), H, W,
                        min(bm, H), interpret)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gaussian_blur_halo(img: jax.Array, *, bm: int = 128,
                       interpret: bool = True) -> jax.Array:
    """Blur the interior of an already 2+2-row-halo'd image.

    The co-execution data plane hands each package its row range plus two
    rows of vertical context on either side (zero-filled beyond the full
    image), so this entry consumes the halo directly instead of re-padding:
    ``img`` is (H + 4, W) and the result is the (H, W) interior — the
    halo-aware twin of :func:`gaussian_blur` for split launches.
    """
    H = img.shape[0] - 4
    W = img.shape[1]
    return _blur_blocks(img, H, W, min(bm, H), interpret)
