"""Taylor-series sine Pallas kernel (paper benchmark: Taylor).

Pure-VPU transcendental kernel: each (bm, 128) VMEM block runs the series
in registers via fori_loop. The paper's OpenCL version keeps coefficients in
local memory; on TPU the recurrence needs no table at all (each term is
derived from the previous one), which removes the local-memory pressure and
leaves the kernel entirely compute-bound — the regular-workload extreme of
the benchmark set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _taylor_kernel(x_ref, o_ref, *, terms: int):
    x = x_ref[...]
    x2 = x * x

    def body(k, carry):
        acc, term = carry
        acc = acc + term
        n = (2.0 * k + 2.0) * (2.0 * k + 3.0)
        term = -term * x2 / n
        return acc, term

    acc, _ = jax.lax.fori_loop(
        0, terms, body, (jnp.zeros_like(x), x))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("terms", "bm", "interpret"))
def taylor_sin(x: jax.Array, *, terms: int = 12, bm: int = 256,
               interpret: bool = True) -> jax.Array:
    """Elementwise sin(x) via `terms` Taylor terms. x: any shape, f32."""
    shape = x.shape
    n = x.size
    lanes = 128
    rows = -(-n // lanes)
    bm = min(bm, rows)
    pr = (-rows) % bm
    flat = jnp.pad(x.reshape(-1), (0, rows * lanes - n))
    grid_rows = rows + pr
    flat = jnp.pad(flat.reshape(rows, lanes), ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_taylor_kernel, terms=terms),
        out_shape=jax.ShapeDtypeStruct((grid_rows, lanes), x.dtype),
        grid=(grid_rows // bm,),
        in_specs=[pl.BlockSpec((bm, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, lanes), lambda i: (i, 0)),
        interpret=interpret,
    )(flat)
    return out.reshape(-1)[:n].reshape(shape)
