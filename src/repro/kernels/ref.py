"""Pure-jnp oracles for every kernel in this package.

Each Pallas kernel in `kernels/` is validated against the function of the
same name here (shape/dtype sweeps in tests/test_kernels.py). These are also
the implementations used on backends without Pallas support and inside
differentiable training paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Paper benchmarks (Table 1)
# ---------------------------------------------------------------------------

# 5-tap binomial Gaussian filter (separable), the classic blur stencil.
GAUSS_TAPS = np.array([1.0, 4.0, 6.0, 4.0, 1.0]) / 16.0


def gaussian_blur(img: jax.Array) -> jax.Array:
    """Separable 5x5 Gaussian blur with zero padding. img: (H, W) f32."""
    taps = jnp.asarray(GAUSS_TAPS, dtype=img.dtype)
    padded = jnp.pad(img, ((2, 2), (0, 0)))
    vert = sum(taps[d] * padded[d:d + img.shape[0], :] for d in range(5))
    padded = jnp.pad(vert, ((0, 0), (2, 2)))
    return sum(taps[d] * padded[:, d:d + img.shape[1]] for d in range(5))


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32
                      ).astype(a.dtype)


def taylor_sin(x: jax.Array, terms: int = 12) -> jax.Array:
    """sin(x) via its Taylor series (the paper's transcendental kernel)."""
    acc = jnp.zeros_like(x)
    term = x
    for k in range(terms):
        acc = acc + term
        n = 2 * k + 2
        term = -term * x * x / (n * (n + 1))
    return acc


def mandelbrot(cre: jax.Array, cim: jax.Array, max_iter: int = 64
               ) -> jax.Array:
    """Escape iteration count (float32) per point; the irregular classic."""
    def body(_, st):
        zr, zi, it, alive = st
        zr2, zi2 = zr * zr, zi * zi
        new_alive = alive & (zr2 + zi2 <= 4.0)
        zr, zi = jnp.where(new_alive, zr2 - zi2 + cre, zr), \
            jnp.where(new_alive, 2.0 * zr * zi + cim, zi)
        it = it + new_alive.astype(jnp.float32)
        return zr, zi, it, new_alive

    zr = jnp.zeros_like(cre)
    zi = jnp.zeros_like(cim)
    it = jnp.zeros_like(cre)
    alive = jnp.ones(cre.shape, dtype=bool)
    zr, zi, it, alive = jax.lax.fori_loop(0, max_iter, body,
                                          (zr, zi, it, alive))
    return it


def raytrace(dirx: jax.Array, diry: jax.Array, dirz: jax.Array,
             spheres: jax.Array) -> jax.Array:
    """Nearest-hit Lambert shading of unit rays from the origin.

    spheres: (S, 5) rows [cx, cy, cz, radius, albedo]. Output: intensity.
    """
    light = jnp.asarray([0.577, 0.577, 0.577], dtype=dirx.dtype)
    best_t = jnp.full(dirx.shape, jnp.inf, dtype=dirx.dtype)
    shade = jnp.zeros(dirx.shape, dtype=dirx.dtype)
    for s in range(spheres.shape[0]):
        cx, cy, cz, r, alb = [spheres[s, j] for j in range(5)]
        # |o + t d - c|^2 = r^2 with o = 0: t^2 - 2 t (d.c) + |c|^2 - r^2
        b = dirx * cx + diry * cy + dirz * cz
        c = cx * cx + cy * cy + cz * cz - r * r
        disc = b * b - c
        hit = disc > 0.0
        t = b - jnp.sqrt(jnp.maximum(disc, 0.0))
        hit = hit & (t > 1e-3) & (t < best_t)
        # normal at hit point
        nx, ny, nz = dirx * t - cx, diry * t - cy, dirz * t - cz
        inv = 1.0 / jnp.maximum(r, 1e-6)
        lam = jnp.maximum(0.0, (nx * light[0] + ny * light[1] +
                                nz * light[2]) * inv)
        best_t = jnp.where(hit, t, best_t)
        shade = jnp.where(hit, alb * lam, shade)
    return shade


def rap(values: jax.Array, lengths: jax.Array) -> jax.Array:
    """Resource Allocation Problem row kernel (irregular).

    For each row i, accumulate a diminishing-returns utility over its first
    ``lengths[i]`` candidate resources: sum_j log1p(relu(v_ij)) — rows have
    wildly different lengths, which is the irregularity the paper's dynamic
    schedulers exploit. values: (N, L), lengths: (N,) int32. Output: (N,).
    """
    L = values.shape[1]
    mask = jnp.arange(L)[None, :] < lengths[:, None]
    util = jnp.log1p(jnp.maximum(values, 0.0))
    return jnp.where(mask, util, 0.0).sum(axis=1)


# ---------------------------------------------------------------------------
# Production kernels
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None) -> jax.Array:
    """Multi-head attention oracle with GQA + sliding window.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0.
    `window` limits attention to the last `window` keys (SWA).
    Computation in f32, output in q.dtype.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Hkv, G, Tq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    Tk = k.shape[2]
    q_idx = jnp.arange(Tq)[:, None] + (Tk - Tq)   # align ends (decode case)
    k_idx = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), dtype=bool)
    if causal:
        mask &= q_idx >= k_idx
    if window is not None:
        mask &= q_idx - k_idx < window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(B, Hq, Tq, D).astype(q.dtype)


def linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     log_decay: jax.Array) -> jax.Array:
    """Gated linear attention / SSD oracle (exact sequential recurrence).

    q, k: (BH, T, Dk); v: (BH, T, Dv); log_decay: (BH, T) with entries <= 0.
    Recurrence per head:  S_t = exp(log_decay_t) * S_{t-1} + k_t^T v_t
                          o_t = q_t S_t
    This is Mamba-2's scalar-decay SSD and the mLSTM memory update (without
    the exp-gate stabilizer, which the model layer adds on top).
    """
    def step(S, inp):
        qt, kt, vt, ld = inp
        S = jnp.exp(ld)[..., None, None] * S + \
            kt[..., :, None] * vt[..., None, :]
        ot = jnp.einsum("...k,...kv->...v", qt, S)
        return S, ot

    from ..xscan import xscan

    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    S0 = jnp.zeros((BH, Dk, Dv), dtype=jnp.float32)
    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(log_decay, 1, 0).astype(jnp.float32))
    _, out = xscan(step, S0, xs, name="linattn_steps")
    return jnp.moveaxis(out, 0, 1).astype(q.dtype)


def chunked_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             log_decay: jax.Array, *, chunk: int = 128,
                             remat_chunks: bool = True) -> jax.Array:
    """Chunk-parallel form of `linear_attention` in pure (differentiable)
    jnp — the XLA production path for training SSD/mLSTM mixers (the Pallas
    kernel serves inference; this is its grad-friendly twin, same math).

    `remat_chunks` recomputes the per-chunk work in the backward pass so
    only the carried (Dk, Dv) states are stashed — without it the mLSTM's
    1024x1024 matrix memories stash O(T/chunk · B·H · Dk·Dv) f32
    (~2.1 TB/device on xlstm-1.3b train_4k; §Perf iteration 2).
    """
    from ..xscan import xscan

    BH, T, Dk = q.shape
    Dv = v.shape[-1]
    if T % chunk:
        pt = (-T) % chunk
        q = jnp.pad(q, ((0, 0), (0, pt), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pt), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pt), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pt)))
    Tp = q.shape[1]
    nc = Tp // chunk

    def resh(a):
        return jnp.moveaxis(
            a.reshape(BH, nc, chunk, a.shape[-1]).astype(jnp.float32),
            1, 0)

    qs, ks, vs = resh(q), resh(k), resh(v)
    lds = jnp.moveaxis(log_decay.reshape(BH, nc, chunk), 1,
                       0).astype(jnp.float32)
    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]

    def step(S, inp):
        qc, kc, vc, ld = inp                       # (BH,C,D*) / (BH,C)
        cum = jnp.cumsum(ld, axis=-1)              # (BH,C)
        total = cum[:, -1]
        gamma = jnp.exp(cum[:, :, None] - cum[:, None, :])
        s = jnp.einsum("bid,bjd->bij", qc, kc)
        a = jnp.where(row >= col, s * gamma, 0.0)
        intra = jnp.einsum("bij,bjv->biv", a, vc)
        q_dec = qc * jnp.exp(cum)[..., None]
        inter = jnp.einsum("bik,bkv->biv", q_dec, S)
        k_dec = kc * jnp.exp(total[:, None] - cum)[..., None]
        S = jnp.exp(total)[:, None, None] * S + \
            jnp.einsum("bjk,bjv->bkv", k_dec, vc)
        return S, intra + inter

    S0 = jnp.zeros((BH, Dk, Dv), jnp.float32)
    # GSPMD treats an unconstrained while-carry as replicated, which
    # replicates the whole loop body (and its transpose) and all-gathers
    # the batch-sharded q/k/v EVERY chunk step (measured 1 GiB × 42 blocks
    # per gather on xlstm-1.3b — §Perf iteration 3). Pin the state to the
    # batch sharding of its heads dim.
    from ..models.sharding import shard
    S0 = shard(S0, ("pod", "data"), None, None)
    _, out = xscan(step, S0, (qs, ks, vs, lds), name="linattn_chunks",
                   remat=remat_chunks)
    out = jnp.moveaxis(out, 0, 1).reshape(BH, Tp, Dv)
    return out[:, :T].astype(q.dtype)
