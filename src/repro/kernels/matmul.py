"""Tiled MXU matmul Pallas kernel (paper benchmark: MatMul).

Grid (M/bm, N/bn, K/bk) with K innermost — TPU grids execute the last axis
sequentially, so the f32 VMEM scratch accumulator carries across K steps.
Block shapes are MXU-aligned (multiples of 128 in the contracting/lane
dims). This is the TPU-native re-think of the AMD APP SDK OpenCL kernel:
local-memory tiles become explicit VMEM BlockSpecs and the inner product is
a single 128x128 systolic pass per block pair.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(ik == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
           bk: int = 512, interpret: bool = True) -> jax.Array:
    """C = A @ B. a: (M, K), b: (K, N); M/N/K padded to block multiples."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)

    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    _, Np = b.shape

    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), a.dtype),
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:M, :N]
