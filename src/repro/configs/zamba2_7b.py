"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 + shared attn blocks.

81 Mamba-2 blocks d_model=3584, ssm_state=64, with one *shared* attention
block (32H kv=32, d_ff=14336 MLP) applied every 6 Mamba blocks (weights
reused at every application — the Zamba signature). At 500k decode the
shared attention uses a 4k sliding window; SSM state is O(1) per token ⇒
runs long_500k.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    window=4096,
))
