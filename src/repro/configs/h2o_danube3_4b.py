"""H2O-Danube3-4B [arXiv:2401.16818; unverified] — llama+mistral mix, SWA.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding-window
attention (4096) — which bounds the KV cache and qualifies the arch for
the long_500k decode shape (see DESIGN.md §5).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    window=4096,
    rope_theta=10000.0,
))
