"""Model/shape/run configuration schema + registry.

One `ModelConfig` per assigned architecture lives in configs/<id>.py with
the exact published dimensions; `reduced()` derives the CPU-smoke variant
(same family/features, tiny dims). `SHAPES` defines the four assigned
input-shape cells; `input_specs` is built in launch/dryrun.py from these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | encdec | ssm | hybrid | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention features
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None          # sliding-window attention
    rope_theta: float = 10000.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / xLSTM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    attn_every: int = 0                   # zamba2: shared attn every N
    slstm_every: int = 0                  # xlstm: sLSTM every N
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frame count
    # vlm
    vision_tokens: int = 0
    # misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    schedule: str = "cosine"              # "wsd" for minicpm
    # runtime impls
    attn_impl: str = "xla"                # xla | flash
    mixer_impl: str = "ref"               # ref | pallas (ssm/mlstm kernel)
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k decode shape?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None    # SWA bounds the KV cache

    @property
    def has_decoder(self) -> bool:
        return True   # every assigned arch decodes (whisper is enc-dec)

    def n_params(self) -> int:
        """Analytic parameter count (approximate for ssm/hybrid families;
        the model builder reports the exact tree size — see
        models.model.count_params, which roofline uses when available)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * hd * d
        if self.family == "moe":
            ffn = 3 * d * self.moe_d_ff * self.num_experts + \
                d * self.num_experts
        elif self.family == "ssm":
            attn, ffn = 8 * d * d, 0          # mLSTM up/down + qkv approx
        elif self.family == "hybrid":
            attn, ffn = 6 * d * d, 3 * d * self.d_ff / self.num_layers
        else:
            ffn = 3 * d * self.d_ff
        layers = self.num_layers * (attn + ffn) + \
            self.encoder_layers * (attn + ffn)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(layers + emb)

    def n_active_params(self) -> int:
        """Active (per-token) params — differs from n_params for MoE."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
            + self.num_heads * hd * d
        ffn = 3 * d * self.moe_d_ff * self.top_k + d * self.num_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(self.num_layers * (attn + ffn) + emb)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        scale = {
            "num_layers": min(self.num_layers, 4),
            "d_model": 64,
            "num_heads": 4,
            "num_kv_heads": min(4, max(1, self.num_kv_heads *
                                       4 // self.num_heads)),
            "d_ff": 128 if self.d_ff else 0,
            "vocab_size": 256,
            "head_dim": 16 if self.head_dim else None,
            "window": 32 if self.window else None,
            "num_experts": min(self.num_experts, 4),
            "top_k": min(self.top_k, 2),
            "moe_d_ff": 64 if self.moe_d_ff else 0,
            "ssm_state": 16 if self.ssm_state else 0,
            "ssm_head_dim": 16 if self.ssm_state else 64,
            "attn_every": min(self.attn_every, 2),
            "slstm_every": min(self.slstm_every, 2),
            "encoder_layers": min(self.encoder_layers, 2),
            "encoder_seq": 16 if self.encoder_seq else 0,
            "vision_tokens": 8 if self.vision_tokens else 0,
            "remat": False,
        }
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # late import triggers config registration
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    from . import _load_all
    _load_all()
    return dict(_REGISTRY)
