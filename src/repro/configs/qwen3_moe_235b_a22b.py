"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family; hf].

94L d_model=4096 64H (GQA kv=4) vocab=151936; MoE every layer: 128 experts,
top-8, per-expert d_ff=1536, qk_norm as in Qwen3. ~235B total / ~22B active.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    rope_theta=1000000.0,
))
