"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule.

40L d_model=2304 36H (MHA: kv=36) d_ff=5760 vocab=122753, tied embeddings,
head_dim 64. Trained with the Warmup-Stable-Decay schedule the paper
introduced (optim/schedule.py implements it; selected via schedule="wsd").
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=10000.0,
    schedule="wsd",
))
