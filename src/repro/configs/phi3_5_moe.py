"""Phi-3.5-MoE-42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) vocab=32064; MoE: 16 experts, top-2,
per-expert d_ff=6400.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=32064,
    head_dim=128,
    num_experts=16,
    top_k=2,
    moe_d_ff=6400,
    rope_theta=10000.0,
))
