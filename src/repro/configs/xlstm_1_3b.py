"""xLSTM-1.3B [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks (7:1).

48 blocks d_model=2048 4 heads, d_ff=0 (the mLSTM up/down projection plays
the FFN role), vocab=50304. Every 8th block is an sLSTM (strictly
sequential scalar memory); the rest are mLSTM (matrix memory, chunked
linear-attention form). Recurrent state is O(1) per token ⇒ runs long_500k.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    tie_embeddings=True,
))
