"""InternVL2-1B [arXiv:2404.16821; hf] — InternViT + Qwen2-0.5B backbone.

LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655,
QKV bias (Qwen2 signature). The InternViT vision frontend is a STUB:
input_specs() provides `vision_tokens`=256 precomputed patch embeddings
(B, 256, d_model) that are prepended to the token embeddings.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    qkv_bias=True,
    vision_tokens=256,
    tie_embeddings=True,
    rope_theta=1000000.0,
))
