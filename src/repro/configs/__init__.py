"""Config registry: one module per assigned architecture."""
import importlib

_MODULES = [
    "minicpm_2b", "qwen3_0_6b", "qwen1_5_110b", "h2o_danube3_4b",
    "qwen3_moe_235b_a22b", "phi3_5_moe", "whisper_medium",
    "xlstm_1_3b", "zamba2_7b", "internvl2_1b",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"{__name__}.{m}")
    _loaded = True


from .base import (ModelConfig, ShapeConfig, SHAPES, all_configs,  # noqa: E402
                   get_config, register)

ARCH_IDS = [
    "minicpm-2b", "qwen3-0.6b", "qwen1.5-110b", "h2o-danube3-4b",
    "qwen3-moe-235b-a22b", "phi3.5-moe-42b-a6.6b", "whisper-medium",
    "xlstm-1.3b", "zamba2-7b", "internvl2-1b",
]

__all__ = ["ARCH_IDS", "ModelConfig", "SHAPES", "ShapeConfig",
           "all_configs", "get_config", "register"]
