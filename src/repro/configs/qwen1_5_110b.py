"""Qwen1.5-110B [hf:Qwen/Qwen1.5 family; hf] — dense GQA with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, attention biases
on q/k/v projections (the Qwen1.5 signature), head_dim 128.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
))
