"""Qwen3-0.6B [hf:Qwen/Qwen3-8B family; hf] — dense GQA with qk_norm.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, head_dim=128
(q/k/v projections are wider than d_model, as in Qwen3), RMSNorm on q/k
heads, tied embeddings.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
))
