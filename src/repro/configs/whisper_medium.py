"""Whisper-medium [arXiv:2212.04356; unverified] — enc-dec audio backbone.

24 encoder + 24 decoder layers, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=51865. The conv mel frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, encoder_seq=1500, d_model) per the brief.
GELU MLPs + LayerNorm + sinusoidal positions (no RoPE), cross-attention in
every decoder layer.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    encoder_layers=24,
    encoder_seq=1500,
    rope_theta=0.0,      # sinusoidal absolute positions instead of RoPE
))
