"""Serving launcher: batched request loop over the cached decode path.

Requests are (prompt, max_tokens) pairs batched up to --batch; generation
is greedy. Reduced configs run on this host; full configs serve via the
dry-run path (compile-only proof).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --batch 4
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import build_model

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(model.decode_step)

    B, P, G = args.batch, args.prompt_len, args.max_tokens
    served = 0
    t0 = time.perf_counter()
    rngs = jax.random.split(jax.random.PRNGKey(1),
                            -(-args.requests // B))
    for batch_id, rk in enumerate(rngs):
        n = min(B, args.requests - served)
        prompts = jax.random.randint(rk, (B, P), 0, cfg.vocab_size)
        cache = model.init_cache(B, P + G)
        if model.prefill is not None:
            batch = {"tokens": prompts,
                     "frames": jnp.zeros((B, cfg.encoder_seq,
                                          cfg.d_model), jnp.bfloat16)}
            cache = jax.jit(model.prefill)(params, batch, cache)
        for t in range(P):
            logits, cache = step(params, prompts[:, t:t + 1], cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        for _ in range(G - 1):
            logits, cache = step(params, cur, cache)
            cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        jax.block_until_ready(cur)
        served += n
    dt = time.perf_counter() - t0
    print(f"[serve] {served} requests, {served * (P + G)} tokens in "
          f"{dt:.2f}s ({served * (P + G) / dt:.0f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
