"""Serving launcher: batched request loop over the cached decode path,
plus a co-execution request server over the persistent CoexecEngine.

Default (LM) mode: requests are (prompt, max_tokens) pairs batched up to
--batch; generation is greedy. Reduced configs run on this host; full
configs serve via the dry-run path (compile-only proof).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --batch 4

Co-execution mode: each "request" is one data-parallel kernel launch
served through `CoexecutorRuntime.launch_async` on a long-lived engine —
up to --concurrent launches interleave on the same Coexecution Units.
Every co-execution flag is *derived* from the `repro.api.CoexecSpec`
fields (see `repro.api.cli`): the parsed flags fold into one spec that
drives the real engine and the DES identically, `--spec-json` dumps the
resolved spec as a reproducible artifact, and `--list` prints every
registered scheduler/workload/kernel with its declared option fields.
The served kernel is any registered kernel (`--kernel`, defaulting to
the workload's same-named kernel), `--kernel-impl {auto,pallas,xla,ref}`
picks its implementation variant (the Pallas fast path vs the compiled
XLA oracle; auto is backend-aware), and `--memory {usm,buffers}` selects
the engine's real data plane — rows report its dispatch and
staging-copy counters. `--policy all` sweeps every registered policy;
with `--coexec sim` the same sweep runs on the DES instead of real
threads; `--admission wfq` / `--fuse` / `--preempt` / `--tenants N`
switch the sim path to the multi-tenant DES sweep with p50/p99 latency,
Jain fairness and the time-sampled fairness curve per row. Both
substrates drive the one shared control plane
(`repro.core.exec.ExecutionLoop`), so `--preempt` — WFQ reclaiming
credit mid-launch by capping per-pull package sizes — behaves
identically on `--coexec real` and `--coexec sim`.

    PYTHONPATH=src python -m repro.launch.serve --coexec real \
        --policy all --requests 16 --concurrent 8 --n 65536 \
        --kernel mandelbrot --memory buffers
    PYTHONPATH=src python -m repro.launch.serve --coexec sim \
        --policy all --workload mandelbrot
    PYTHONPATH=src python -m repro.launch.serve --coexec sim \
        --admission wfq --fuse --tenants 16
"""
from __future__ import annotations

import argparse
import time


def _percentile_ms(sorted_s: list, q: float) -> float:
    """Nearest-rank percentile of sorted seconds, in milliseconds."""
    import math

    if not sorted_s:
        return float("nan")
    idx = max(0, math.ceil(q * len(sorted_s)) - 1)
    return 1e3 * sorted_s[idx]


def default_serve_spec():
    """The serve CLI's base spec: two same-device units, dist 0.4.

    Two Coexecution Units on this host's first device are the CPU-only
    container's stand-in for the paper's CPU+GPU pair; flags the user
    passes override these fields (see `repro.api.cli.spec_from_args`).
    """
    from repro.api import CoexecSpec

    return (CoexecSpec.builder()
            .policy("all")      # sweep every registered policy by default
            .units(count=2, kinds=("cpu", "cpu"), speed_hints=(0.4, 0.6))
            .dist(0.4)
            .workload("mandelbrot")
            .build())


def _sweep_policies(spec) -> tuple[str, ...]:
    """Expand ``policy="all"`` into every registered policy name."""
    from repro.api import scheduler_names

    if spec.scheduler.policy == "all":
        return scheduler_names()
    return (spec.scheduler.policy,)


def coexec_real_rows(spec=None, *, policies=None, units=None) -> list[dict]:
    """Serve ``spec.workload.requests`` kernel launches per policy through
    the persistent engine (at most ``spec.workload.concurrent`` in
    flight); one measurement dict each. Shared by ``serve --coexec real``
    and ``benchmarks.run coexec``. The spec's admission section selects
    the engine's cross-launch queueing policy; its workload section picks
    the served kernel (any registered kernel, via ``--kernel`` or the
    workload's name) and its memory section the data plane, whose
    dispatch/copy counters are aggregated into each row.
    """
    from repro.api import kernel_demo_inputs
    from repro.kernels import resolve_impl
    from ..core import CoexecutorRuntime, service_fairness_curve

    if spec is None:
        spec = default_serve_spec()
    if units is None:
        units = spec.build_units()
    n = spec.workload.items
    requests = spec.workload.requests
    concurrent = spec.workload.concurrent
    kname = spec.workload.resolve_kernel()
    impl = resolve_impl(spec.workload.kernel_impl)
    kernel = spec.workload.build_kernel()
    datas = [kernel_demo_inputs(kname, n, seed=i) for i in range(requests)]
    rows = []
    for policy in (policies or _sweep_policies(spec)):
        pspec = spec.replace(
            scheduler=spec.scheduler.replace(policy=policy))
        with CoexecutorRuntime.from_spec(pspec, units=units) as rt:
            rt.launch(n, kernel, datas[0])          # warm the jit cache
            busy0 = sum(u.busy_s for u in units)
            t0 = time.perf_counter()
            served, pkgs, lats, inflight = 0, 0, [], []
            h2d, d2h, dispatches = 0, 0, 0
            host_s = 0.0        # staging + collection (non-compute) time
            service = []        # (t_complete, tenant, items) per package

            def _reap(h, t_sub, tenant):
                nonlocal served, pkgs, h2d, d2h, dispatches, host_s
                h.result()
                served, pkgs = served + 1, pkgs + h.stats.num_packages
                h2d += h.stats.data.h2d_copies
                d2h += h.stats.data.d2h_copies
                dispatches += h.stats.data.dispatches
                host_s += sum((p.t_launch - p.t_issue)
                              + (p.t_collected - p.t_complete)
                              for p in h.stats.packages)
                service.extend((p.t_complete, tenant, p.size)
                               for p in h.stats.packages)
                lats.append(time.perf_counter() - t_sub)

            for i, d in enumerate(datas):
                inflight.append((rt.launch_async(n, kernel, d,
                                                 tenant=f"t{i}"),
                                 time.perf_counter(), f"t{i}"))
                if len(inflight) >= concurrent:
                    _reap(*inflight.pop(0))
            for h, t_sub, tenant in inflight:
                _reap(h, t_sub, tenant)
            dt = time.perf_counter() - t0
            busy = sum(u.busy_s for u in units) - busy0
        lats.sort()
        # fairness of throughput across requests + the time-sampled
        # service fairness curve (the measure --preempt tightens), on a
        # duration-weighted deterministic clock (items computed)
        from ..core import jain_index

        thru = [n / max(lat, 1e-9) for lat in lats]
        clock, ticked = 0, []
        for _, tenant, items in sorted(service):
            clock += items
            ticked.append((clock, tenant, items))
        curve = service_fairness_curve(
            ticked, [f"t{i}" for i in range(requests)])
        rows.append(dict(kernel=kname, impl=impl,
                         memory=spec.memory.model,
                         policy=policy, requests=served, n=n,
                         concurrent=concurrent, seconds=dt, packages=pkgs,
                         req_per_s=served / dt,
                         items_per_s=served * n / dt,
                         dispatches=dispatches,
                         h2d_copies=h2d, d2h_copies=d2h,
                         device_idle_frac=max(
                             0.0, 1.0 - busy / (len(units) * dt)),
                         host_overhead_frac=host_s / dt,
                         fairness=jain_index(thru),
                         fairness_curve_mean=float(sum(curve) / len(curve)),
                         fairness_curve_min=float(min(curve)),
                         p50_ms=_percentile_ms(lats, 0.5),
                         p99_ms=_percentile_ms(lats, 0.99)))
    return rows


def coexec_sim_rows(spec=None, *, policies=None) -> list[dict]:
    """The same policy sweep on the DES (virtual time, deterministic).

    The spec's scheduler section (options, granularity) drives the DES
    split exactly as it drives the real engine; the speed hint is the
    DES units' calibrated speeds (the profile's ground truth), not the
    spec's ``dist`` — `dist` describes real devices the DES replaces.
    """
    from ..core import paper_workload, simulate

    if spec is None:
        spec = default_serve_spec()
    workload = spec.workload.name
    wl, cpu, gpu = paper_workload(workload,
                                  size_scale=spec.workload.size_scale)
    rows = []
    for policy in (policies or _sweep_policies(spec)):
        sched = spec.scheduler.replace(policy=policy).build(
            wl.total, 2, speeds=[cpu.speed, gpu.speed])
        r = simulate(sched, [cpu, gpu], wl, spec=spec)
        busy = sum(r.unit_busy_s.values())
        span = max(r.total_s, 1e-12)
        rows.append(dict(workload=workload, policy=policy,
                         memory=r.memory,
                         seconds=r.total_s, packages=r.num_packages,
                         balance=r.balance(),
                         steals=getattr(sched, "steals", 0),
                         dispatches=r.data.dispatches,
                         h2d_copies=r.data.h2d_copies,
                         d2h_copies=r.data.d2h_copies,
                         device_idle_frac=max(
                             0.0, 1.0 - busy / (len(r.unit_busy_s) * span)),
                         host_overhead_frac=r.host_busy_s / span))
    return rows


def coexec_multi_rows(spec=None, *, tenants=None, policies=None,
                      per_tenant_items: int = 2048,
                      num_packages: int = 16,
                      admissions=None,
                      fuse_modes=None,
                      preempt_modes=None) -> list[dict]:
    """Multi-tenant admission sweep on the DES: one row per (tenant count,
    policy, admission policy, fusion mode, preemption mode) with p50/p99
    latency, Jain fairness over per-tenant throughput, the time-sampled
    service fairness curve (the measure ``--preempt`` tightens), and
    total dispatched packages. Sweep axes default to the single point the
    spec describes (its admission policy/fuse/preempt flags and
    ``workload.tenants``); pass tuples to sweep. Shared by
    ``serve --coexec sim --admission/--fuse/--preempt/--tenants`` and
    ``benchmarks.run coexec-multi``.
    """
    import numpy as np

    from ..core import (LaunchSpec, Workload, jain_index, paper_workload,
                        simulate_multi)

    if spec is None:
        spec = default_serve_spec()
    workload = spec.workload.name
    if tenants is None:
        tenants = (spec.workload.tenants or 8,)
    if admissions is None:
        admissions = (spec.admission.policy,)
    if fuse_modes is None:
        fuse_modes = (spec.admission.fuse,)
    if preempt_modes is None:
        preempt_modes = (spec.admission.preempt,)
    base, cpu, gpu = paper_workload(workload)
    per_item_in = base.bytes_in_per_item
    per_item_out = base.bytes_out_per_item
    # keep the profile's irregularity: resample its per-item weights to
    # the per-tenant problem size (as paper_workload does for size sweeps)
    weights = None
    if base.weights is not None:
        idx = np.linspace(0, len(base.weights) - 1,
                          per_tenant_items).astype(int)
        weights = base.weights[idx]

    def sched_for(policy):
        # the spec's scheduler options/granularity apply; dynamic gets a
        # per-tenant-sized package count unless the spec pins one
        sched_spec = spec.scheduler.replace(policy=policy)
        if policy == "dynamic" and \
                "num_packages" not in sched_spec.options_dict():
            sched_spec = sched_spec.with_options(num_packages=num_packages)
        return sched_spec.build(per_tenant_items, 2,
                                speeds=[cpu.speed, gpu.speed])

    def specs(nt, policy):
        out = []
        for t in range(nt):
            wl = Workload(name=base.name, total=per_tenant_items,
                          bytes_in_per_item=per_item_in,
                          bytes_out_per_item=per_item_out,
                          working_set_bytes=base.working_set_bytes
                          * per_tenant_items / base.total,
                          weights=weights,
                          contention_scale=base.contention_scale)
            out.append(LaunchSpec(wl, sched_for(policy), tenant=f"t{t}"))
        return out

    rows = []
    for policy in (policies or ("dynamic",)):
        for nt in tenants:
            for adm in admissions:
                for fuse in fuse_modes:
                    for preempt in preempt_modes:
                        if preempt and adm != "wfq" \
                                and False in preempt_modes:
                            # sweeping both modes: fifo+preempt would
                            # duplicate the fifo row (preemption only
                            # reclaims WFQ credit). A single-point
                            # request still produces its row, with the
                            # flag inert.
                            continue
                        cfg = spec.admission.replace(
                            policy=adm, fuse=fuse, preempt=preempt,
                            fuse_threshold=per_tenant_items,
                            fuse_wait_s=0.0).to_config()
                        res = simulate_multi(specs(nt, policy), [cpu, gpu],
                                             admission=cfg)
                        lats = sorted(res.latencies())
                        thru = [r.items / max(r.latency_s, 1e-12)
                                for r in res.launches]
                        curve = res.fairness_curve()
                        rows.append(dict(
                            workload=workload, tenants=nt, admission=adm,
                            fuse=fuse, preempt=preempt, policy=policy,
                            p50_ms=_percentile_ms(lats, 0.5),
                            p99_ms=_percentile_ms(lats, 0.99),
                            fairness=jain_index(thru),
                            fairness_curve_mean=float(
                                sum(curve) / len(curve)),
                            fairness_curve_min=float(min(curve)),
                            packages=res.dispatched_packages,
                            fused_batches=res.fused_batches,
                            total_ms=1e3 * res.total_s))
    return rows


def trace_from_spec(spec, capacity_items_s: float):
    """Build (or load) the open-loop trace the spec's traffic section asks
    for.

    Args:
        spec: a ``CoexecSpec`` with ``traffic.arrival != "closed"``.
        capacity_items_s: modeled serving capacity in work-items/s, used
            to turn ``traffic.load`` into an arrival rate when
            ``traffic.rate`` is 0.

    Returns:
        A :class:`repro.core.Trace`.
    """
    from ..core import Trace, synthesize_trace

    tr = spec.traffic
    if tr.trace:
        return Trace.load(tr.trace)
    items = spec.workload.items
    rate = tr.rate if tr.rate > 0 else tr.load * capacity_items_s / items
    return synthesize_trace(
        tr.arrivals, rate, arrival=tr.arrival,
        tenants=spec.workload.tenants or 8, items=items,
        item_jitter=tr.item_jitter, slo_ms=spec.admission.slo_ms,
        burst=tr.burst, burst_duty=tr.burst_duty, seed=tr.seed)


def traffic_rows(spec=None, *, loads=None, admissions=None,
                 arrival_kinds=None, tenants=None) -> list[dict]:
    """Open-loop SLO sweep on the DES: one aggregate row per (arrival
    process, load multiple, admission mode) with admitted-launch
    p50/p99 latency, deadline-miss rate, shed fraction and fusion
    counters. Sweep axes default to the single point the spec describes;
    pass tuples to sweep. Shared by ``serve --coexec sim --arrival ...``
    and ``benchmarks.run traffic``.

    Each admission mode is a dict of ``AdmissionSpec.replace`` overrides
    (e.g. ``{"policy": "edf", "preempt": True, "shed": True}``); a
    string is shorthand for ``{"policy": <string>}``.
    """
    from ..core import capacity_items_per_s, paper_workload, replay_trace_sim

    if spec is None:
        spec = default_serve_spec()
    _, cpu, gpu = paper_workload(spec.workload.name)
    units = [cpu, gpu]
    cap = capacity_items_per_s(units)
    if loads is None:
        loads = (spec.traffic.load,)
    if admissions is None:
        admissions = ({},)
    if arrival_kinds is None:
        arrival_kinds = (spec.traffic.arrival
                         if spec.traffic.arrival != "closed" else "poisson",)
    if tenants is None:
        tenants = spec.workload.tenants or 8
    rows = []
    for arrival in arrival_kinds:
        for load in loads:
            tspec = spec.replace(
                traffic=spec.traffic.replace(arrival=arrival, load=load),
                workload=spec.workload.replace(tenants=tenants))
            trace = trace_from_spec(tspec, cap)
            # a file trace describes itself; the spec's synthesis knobs
            # didn't shape it
            row_arrival = arrival
            row_tenants = tenants
            if tspec.traffic.trace:
                row_arrival = str(trace.meta.get("arrival", "trace"))
                row_tenants = len(trace.tenants())
            for mode in admissions:
                if isinstance(mode, str):
                    mode = {"policy": mode}
                adm = tspec.admission.replace(**mode)
                rep = replay_trace_sim(trace, units,
                                       admission=adm.to_config())
                r = rep.result
                rows.append(dict(
                    workload=spec.workload.name, arrival=row_arrival,
                    tenants=row_tenants, load=float(load),
                    admission=adm.policy, preempt=adm.preempt,
                    shed=adm.shed, slo_ms=adm.slo_ms,
                    arrivals=len(trace),
                    admitted=len(r.launches), shed_count=len(r.shed),
                    p50_ms=rep.p50_ms(), p99_ms=rep.p99_ms(),
                    miss_rate=rep.miss_rate(),
                    shed_fraction=rep.shed_fraction(),
                    packages=r.dispatched_packages,
                    fused_batches=r.fused_batches,
                    total_ms=1e3 * r.total_s))
    return rows


def cluster_pool_units(spec, n: int) -> list:
    """Provision ``n`` simulated pool units from the workload's pair.

    The paper's calibrated CPU/GPU units are cloned round-robin across
    the pool slots, so an elastic pool keeps the heterogeneous speed mix
    the profiles were calibrated against.
    """
    from ..core import SimUnit, paper_workload

    _, cpu, gpu = paper_workload(spec.workload.name)
    pair = (cpu, gpu)
    return [SimUnit(f"{pair[i % 2].name}{i}", pair[i % 2].kind,
                    speed=pair[i % 2].speed, alpha=pair[i % 2].alpha,
                    setup_s=pair[i % 2].setup_s) for i in range(n)]


def cluster_rows(spec=None, *, plans=None) -> list[dict]:
    """Elastic-cluster serve on the DES: one audit row per failure plan.

    Replays the spec's open-loop trace through
    :func:`repro.core.replay_trace_cluster` — the runtime-resizable pool
    with exact package re-issue — and reports the exact-once audit
    (``lost``/``duplicated`` must be 0) next to the latency percentiles.
    ``plans`` maps row names to :class:`repro.core.FailurePlan` objects
    (``None`` plans run undisturbed); it defaults to the single plan the
    spec's ``cluster.failure_plan`` names, or an undisturbed run. Shared
    by ``serve --coexec sim --cluster`` and ``benchmarks.run cluster``.
    """
    import dataclasses

    from ..core import capacity_items_per_s, replay_trace_cluster

    if spec is None:
        spec = default_serve_spec()
    if spec.traffic.arrival == "closed" and not spec.traffic.trace:
        # The cluster tier replays an open-loop trace; a closed-loop
        # spec (the CLI default) has none, so fall back to poisson
        # arrivals instead of rejecting the run.
        spec = dataclasses.replace(
            spec, traffic=dataclasses.replace(spec.traffic,
                                              arrival="poisson"))
    cl = spec.cluster
    n = cl.max_units if cl.max_units is not None else max(cl.min_units, 4)
    units = cluster_pool_units(spec, n)
    active = units[:cl.min_units]
    trace = trace_from_spec(spec, capacity_items_per_s(active))
    if plans is None:
        plans = {"plan" if cl.failure_plan else "undisturbed":
                 cl.load_plan()}
    rows = []
    for name, plan in plans.items():
        rep = replay_trace_cluster(
            trace, units, spec=spec, plan=plan,
            min_units=cl.min_units, autoscale=cl.autoscale,
            autoscale_opts=cl.autoscaler_opts(),
            granularity=spec.scheduler.granularity)
        rows.append(dict(
            name=name, workload=spec.workload.name,
            arrival=spec.traffic.arrival, admission=spec.admission.policy,
            min_units=rep.min_units, max_units=rep.max_units,
            autoscale=cl.autoscale, arrivals=rep.arrivals,
            admitted=rep.admitted, shed_count=rep.shed_count,
            completed=rep.completed, lost=rep.lost,
            duplicated=rep.duplicated, reissued=rep.reissued,
            kills=len(rep.kills), joins=len(rep.joins),
            resizes=len(rep.scale_events),
            p50_ms=rep.p50_ms(), p99_ms=rep.p99_ms()))
    return rows


def serve_coexec_cluster(spec) -> None:
    """Elastic-cluster serve: audit + latency row per failure plan."""
    for row in cluster_rows(spec):
        print(f"[serve/cluster] {row['workload']}/{row['arrival']}"
              f"/{row['admission']} pool={row['min_units']}.."
              f"{row['max_units']}"
              f"{'+autoscale' if row['autoscale'] else ''} "
              f"({row['name']}): {row['admitted']}/{row['arrivals']} "
              f"admitted, {row['completed']} completed, "
              f"lost={row['lost']} dup={row['duplicated']} "
              f"reissued={row['reissued']} kills={row['kills']} "
              f"joins={row['joins']} resizes={row['resizes']}, "
              f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms")


def traffic_tenant_rows(spec=None) -> list[dict]:
    """Per-tenant serving outcome of the spec's open-loop replay: one row
    per tenant with arrivals/admitted/shed counts, p50/p99 admitted
    latency and deadline-miss rate — the serve columns the SLO work
    surfaces.
    """
    from ..core import capacity_items_per_s, paper_workload, replay_trace_sim

    if spec is None:
        spec = default_serve_spec()
    _, cpu, gpu = paper_workload(spec.workload.name)
    units = [cpu, gpu]
    trace = trace_from_spec(spec, capacity_items_per_s(units))
    rep = replay_trace_sim(trace, units, spec=spec)
    return [dict(tenant=t.tenant, arrivals=t.arrivals, admitted=t.admitted,
                 shed=t.shed, p50_ms=t.p50_ms, p99_ms=t.p99_ms,
                 miss_rate=t.miss_rate) for t in rep.rows]


def serve_coexec_traffic(spec) -> None:
    """Open-loop serve: aggregate row plus per-tenant p50/p99/miss/shed."""
    for row in traffic_rows(spec):
        print(f"[serve/traffic] {row['workload']}/{row['arrival']}"
              f"/{row['tenants']}t load={row['load']:.2f} "
              f"{row['admission']}"
              f"{'+preempt' if row['preempt'] else ''}"
              f"{'+shed' if row['shed'] else ''}: "
              f"{row['admitted']}/{row['arrivals']} admitted "
              f"(shed {row['shed_count']}), "
              f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms "
              f"miss={row['miss_rate']:.3f}")
    for row in traffic_tenant_rows(spec):
        print(f"[serve/traffic]   {row['tenant']:>8s}: "
              f"arrivals={row['arrivals']:4d} admitted={row['admitted']:4d} "
              f"shed={row['shed']:3d} p50={row['p50_ms']:8.2f}ms "
              f"p99={row['p99_ms']:8.2f}ms miss={row['miss_rate']:.3f}")


def serve_coexec_real(spec) -> None:
    for row in coexec_real_rows(spec):
        print(f"[serve/coexec] {row['kernel']}[{row['impl']}]"
              f"/{row['policy']:13s} "
              f"({spec.admission.policy}"
              f"{'+fuse' if spec.admission.fuse else ''}"
              f"{'+preempt' if spec.admission.preempt else ''}"
              f"/{row['memory']}): {row['requests']} "
              f"requests ({row['concurrent']} in flight) in "
              f"{row['seconds']:.3f}s = {row['req_per_s']:6.1f} req/s, "
              f"{row['items_per_s'] / 1e6:7.2f} "
              f"Mitems/s, {row['packages']} packages, "
              f"copies h2d={row['h2d_copies']} d2h={row['d2h_copies']}, "
              f"fairness={row['fairness']:.3f} "
              f"curve={row['fairness_curve_mean']:.3f}, "
              f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")


def serve_coexec_sim(spec) -> None:
    if spec.cluster.enabled:
        return serve_coexec_cluster(spec)
    if spec.traffic.arrival != "closed" or spec.traffic.trace:
        return serve_coexec_traffic(spec)
    multi = (spec.admission.policy != "fifo" or spec.admission.fuse
             or spec.workload.tenants is not None)
    if multi:
        for row in coexec_multi_rows(spec, policies=_sweep_policies(spec)):
            print(f"[serve/coexec-multi] {row['workload']}"
                  f"/{row['policy']}/{row['tenants']}t/{row['admission']}"
                  f"{'+fuse' if row['fuse'] else ''}"
                  f"{'+preempt' if row['preempt'] else ''}: "
                  f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms "
                  f"fairness={row['fairness']:.3f} "
                  f"curve={row['fairness_curve_mean']:.3f} "
                  f"packages={row['packages']} "
                  f"(fused_batches={row['fused_batches']})")
        return
    for row in coexec_sim_rows(spec):
        print(f"[serve/coexec-sim] {row['workload']}/{row['policy']:13s}: "
              f"{row['seconds']:7.3f}s, {row['packages']:4d} packages, "
              f"balance={row['balance']:.2f}, steals={row['steals']}")


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI parser: LM flags + spec-derived co-execution flags.

    Returns:
        A parser whose co-execution flags are generated from the
        ``CoexecSpec`` fields by :func:`repro.api.cli.add_spec_args` —
        adding a spec field adds a serve flag with no edit here.
    """
    from repro.api import add_spec_args

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--coexec", choices=["off", "real", "sim"],
                    default="off",
                    help="serve co-execution kernel requests instead of LM "
                         "decode: 'real' uses the persistent CoexecEngine, "
                         "'sim' the discrete-event simulator")
    ap.add_argument("--spec-json", action="store_true",
                    help="print the resolved CoexecSpec as JSON and exit")
    ap.add_argument("--list", action="store_true",
                    help="print registered schedulers, workloads and "
                         "kernels (with their option fields) and exit")
    add_spec_args(ap)
    return ap


def main() -> None:
    from repro.api import registry_listing, spec_from_args

    ap = build_parser()
    args = ap.parse_args()
    if args.list:
        print(registry_listing())
        return
    try:
        spec = spec_from_args(args, base=default_serve_spec()).validate()
    except (KeyError, ValueError) as e:
        ap.error(str(e))

    if args.spec_json:
        print(spec.to_json(indent=2))
        return
    if args.coexec == "real":
        return serve_coexec_real(spec)
    if args.coexec == "sim":
        return serve_coexec_sim(spec)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import build_model

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(model.decode_step)

    requests = spec.workload.requests
    B, P, G = args.batch, args.prompt_len, args.max_tokens
    served = 0
    t0 = time.perf_counter()
    rngs = jax.random.split(jax.random.PRNGKey(1),
                            -(-requests // B))
    for batch_id, rk in enumerate(rngs):
        n = min(B, requests - served)
        prompts = jax.random.randint(rk, (B, P), 0, cfg.vocab_size)
        cache = model.init_cache(B, P + G)
        if model.prefill is not None:
            batch = {"tokens": prompts,
                     "frames": jnp.zeros((B, cfg.encoder_seq,
                                          cfg.d_model), jnp.bfloat16)}
            cache = jax.jit(model.prefill)(params, batch, cache)
        for t in range(P):
            logits, cache = step(params, prompts[:, t:t + 1], cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        for _ in range(G - 1):
            logits, cache = step(params, cur, cache)
            cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        jax.block_until_ready(cur)
        served += n
    dt = time.perf_counter() - t0
    print(f"[serve] {served} requests, {served * (P + G)} tokens in "
          f"{dt:.2f}s ({served * (P + G) / dt:.0f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
