"""Serving launcher: batched request loop over the cached decode path,
plus a co-execution request server over the persistent CoexecEngine.

Default (LM) mode: requests are (prompt, max_tokens) pairs batched up to
--batch; generation is greedy. Reduced configs run on this host; full
configs serve via the dry-run path (compile-only proof).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --batch 4

Co-execution mode: each "request" is one data-parallel kernel launch
served through `CoexecutorRuntime.launch_async` on a long-lived engine —
up to --concurrent launches interleave on the same Coexecution Units.
`--policy all` sweeps work_stealing against static/dynamic/hguided; with
`--coexec sim` the same sweep runs on the DES instead of real threads.

    PYTHONPATH=src python -m repro.launch.serve --coexec real \
        --policy all --requests 16 --concurrent 8 --n 65536
    PYTHONPATH=src python -m repro.launch.serve --coexec sim \
        --policy all --workload mandelbrot
"""
from __future__ import annotations

import argparse
import time

COEXEC_POLICIES = ("static", "dynamic", "hguided", "work_stealing")


def default_two_units():
    """Two Coexecution Units on this host's first device (the CPU-only
    container's stand-in for the paper's CPU+GPU pair)."""
    import jax

    from ..core import counits_from_devices

    return counits_from_devices(jax.local_devices()[:1] * 2,
                                kinds=["cpu", "cpu"],
                                speed_hints=[0.4, 0.6])


def coexec_real_rows(policies=COEXEC_POLICIES, *, n: int = 1 << 16,
                     requests: int = 16, concurrent: int = 8,
                     units=None) -> list[dict]:
    """Serve `requests` kernel launches per policy through the persistent
    engine (at most `concurrent` in flight); one measurement dict each.
    Shared by `serve --coexec real` and `benchmarks.run coexec`.
    """
    import numpy as np

    from ..core import CoexecutorRuntime
    from ..kernels import package_kernel

    if units is None:
        units = default_two_units()
    rng = np.random.default_rng(0)
    datas = [rng.uniform(-2, 2, n).astype(np.float32)
             for _ in range(requests)]
    kernel = package_kernel("taylor")
    rows = []
    for policy in policies:
        with CoexecutorRuntime(policy) as rt:
            rt.config(units=units, dist=0.4)
            rt.launch(n, kernel, [datas[0]])        # warm the jit cache
            t0 = time.perf_counter()
            served, pkgs, inflight = 0, 0, []
            for d in datas:
                inflight.append(rt.launch_async(n, kernel, [d]))
                if len(inflight) >= concurrent:
                    h = inflight.pop(0)
                    h.result()
                    served, pkgs = served + 1, pkgs + h.stats.num_packages
            for h in inflight:
                h.result()
                served, pkgs = served + 1, pkgs + h.stats.num_packages
            dt = time.perf_counter() - t0
        rows.append(dict(policy=policy, requests=served, n=n,
                         concurrent=concurrent, seconds=dt, packages=pkgs,
                         req_per_s=served / dt))
    return rows


def coexec_sim_rows(workload: str,
                    policies=COEXEC_POLICIES) -> list[dict]:
    """The same policy sweep on the DES (virtual time, deterministic)."""
    from ..core import SPEED_HINT_POLICIES, make_scheduler, paper_workload, \
        simulate

    wl, cpu, gpu = paper_workload(workload)
    rows = []
    for policy in policies:
        kw = {}
        if policy in SPEED_HINT_POLICIES:
            kw["speeds"] = [cpu.speed, gpu.speed]
        sched = make_scheduler(policy, wl.total, 2, **kw)
        r = simulate(sched, [cpu, gpu], wl)
        rows.append(dict(workload=workload, policy=policy,
                         seconds=r.total_s, packages=r.num_packages,
                         balance=r.balance(),
                         steals=getattr(sched, "steals", 0)))
    return rows


def serve_coexec_real(args) -> None:
    policies = (COEXEC_POLICIES if args.policy == "all" else (args.policy,))
    for row in coexec_real_rows(policies, n=args.n, requests=args.requests,
                                concurrent=args.concurrent):
        print(f"[serve/coexec] {row['policy']:13s}: {row['requests']} "
              f"requests ({row['concurrent']} in flight) in "
              f"{row['seconds']:.3f}s = {row['req_per_s']:6.1f} req/s, "
              f"{row['requests'] * row['n'] / row['seconds'] / 1e6:7.2f} "
              f"Mitems/s, {row['packages']} packages")


def serve_coexec_sim(args) -> None:
    policies = (COEXEC_POLICIES if args.policy == "all" else (args.policy,))
    for row in coexec_sim_rows(args.workload, policies):
        print(f"[serve/coexec-sim] {row['workload']}/{row['policy']:13s}: "
              f"{row['seconds']:7.3f}s, {row['packages']:4d} packages, "
              f"balance={row['balance']:.2f}, steals={row['steals']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--coexec", choices=["off", "real", "sim"],
                    default="off",
                    help="serve co-execution kernel requests instead of LM "
                         "decode: 'real' uses the persistent CoexecEngine, "
                         "'sim' the discrete-event simulator")
    ap.add_argument("--policy", default="all",
                    help=f"coexec scheduling policy to serve with, or "
                         f"'all' to sweep {COEXEC_POLICIES}")
    ap.add_argument("--concurrent", type=int, default=8,
                    help="max in-flight launch_async requests (coexec real)")
    ap.add_argument("--n", type=int, default=1 << 16,
                    help="items per coexec request (coexec real)")
    ap.add_argument("--workload", default="mandelbrot",
                    help="paper workload profile (coexec sim)")
    args = ap.parse_args()

    if args.coexec == "real":
        return serve_coexec_real(args)
    if args.coexec == "sim":
        return serve_coexec_sim(args)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import build_model

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(model.decode_step)

    B, P, G = args.batch, args.prompt_len, args.max_tokens
    served = 0
    t0 = time.perf_counter()
    rngs = jax.random.split(jax.random.PRNGKey(1),
                            -(-args.requests // B))
    for batch_id, rk in enumerate(rngs):
        n = min(B, args.requests - served)
        prompts = jax.random.randint(rk, (B, P), 0, cfg.vocab_size)
        cache = model.init_cache(B, P + G)
        if model.prefill is not None:
            batch = {"tokens": prompts,
                     "frames": jnp.zeros((B, cfg.encoder_seq,
                                          cfg.d_model), jnp.bfloat16)}
            cache = jax.jit(model.prefill)(params, batch, cache)
        for t in range(P):
            logits, cache = step(params, prompts[:, t:t + 1], cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        for _ in range(G - 1):
            logits, cache = step(params, cur, cache)
            cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        jax.block_until_ready(cur)
        served += n
    dt = time.perf_counter() - t0
    print(f"[serve] {served} requests, {served * (P + G)} tokens in "
          f"{dt:.2f}s ({served * (P + G) / dt:.0f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
