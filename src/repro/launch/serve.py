"""Serving launcher: batched request loop over the cached decode path,
plus a co-execution request server over the persistent CoexecEngine.

Default (LM) mode: requests are (prompt, max_tokens) pairs batched up to
--batch; generation is greedy. Reduced configs run on this host; full
configs serve via the dry-run path (compile-only proof).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --batch 4

Co-execution mode: each "request" is one data-parallel kernel launch
served through `CoexecutorRuntime.launch_async` on a long-lived engine —
up to --concurrent launches interleave on the same Coexecution Units.
`--policy all` sweeps work_stealing against static/dynamic/hguided; with
`--coexec sim` the same sweep runs on the DES instead of real threads.
`--admission wfq` swaps the engine's FIFO drain for weighted-fair
queueing, `--fuse` coalesces small same-shaped concurrent launches; on
the sim path those flags (or --tenants > 1) switch to the multi-tenant
DES sweep with p50/p99 latency and Jain fairness per row.

    PYTHONPATH=src python -m repro.launch.serve --coexec real \
        --policy all --requests 16 --concurrent 8 --n 65536
    PYTHONPATH=src python -m repro.launch.serve --coexec sim \
        --policy all --workload mandelbrot
    PYTHONPATH=src python -m repro.launch.serve --coexec sim \
        --admission wfq --fuse --tenants 16
"""
from __future__ import annotations

import argparse
import time

COEXEC_POLICIES = ("static", "dynamic", "hguided", "work_stealing")


def _percentile_ms(sorted_s: list, q: float) -> float:
    """Nearest-rank percentile of sorted seconds, in milliseconds."""
    import math

    if not sorted_s:
        return float("nan")
    idx = max(0, math.ceil(q * len(sorted_s)) - 1)
    return 1e3 * sorted_s[idx]


def default_two_units():
    """Two Coexecution Units on this host's first device (the CPU-only
    container's stand-in for the paper's CPU+GPU pair)."""
    import jax

    from ..core import counits_from_devices

    return counits_from_devices(jax.local_devices()[:1] * 2,
                                kinds=["cpu", "cpu"],
                                speed_hints=[0.4, 0.6])


def coexec_real_rows(policies=COEXEC_POLICIES, *, n: int = 1 << 16,
                     requests: int = 16, concurrent: int = 8,
                     units=None, admission: str = "fifo",
                     fuse: bool = False) -> list[dict]:
    """Serve `requests` kernel launches per policy through the persistent
    engine (at most `concurrent` in flight); one measurement dict each.
    Shared by `serve --coexec real` and `benchmarks.run coexec`.
    `admission`/`fuse` select the engine's cross-launch queueing policy.
    """
    import numpy as np

    from ..core import CoexecutorRuntime
    from ..kernels import package_kernel

    if units is None:
        units = default_two_units()
    rng = np.random.default_rng(0)
    datas = [rng.uniform(-2, 2, n).astype(np.float32)
             for _ in range(requests)]
    kernel = package_kernel("taylor")
    rows = []
    for policy in policies:
        with CoexecutorRuntime(policy) as rt:
            rt.config(units=units, dist=0.4, admission=admission, fuse=fuse)
            rt.launch(n, kernel, [datas[0]])        # warm the jit cache
            t0 = time.perf_counter()
            served, pkgs, lats, inflight = 0, 0, [], []

            def _reap(h, t_sub):
                nonlocal served, pkgs
                h.result()
                served, pkgs = served + 1, pkgs + h.stats.num_packages
                lats.append(time.perf_counter() - t_sub)

            for i, d in enumerate(datas):
                inflight.append((rt.launch_async(n, kernel, [d],
                                                 tenant=f"t{i}"),
                                 time.perf_counter()))
                if len(inflight) >= concurrent:
                    _reap(*inflight.pop(0))
            for h, t_sub in inflight:
                _reap(h, t_sub)
            dt = time.perf_counter() - t0
        lats.sort()
        rows.append(dict(policy=policy, requests=served, n=n,
                         concurrent=concurrent, seconds=dt, packages=pkgs,
                         req_per_s=served / dt,
                         p50_ms=_percentile_ms(lats, 0.5),
                         p99_ms=_percentile_ms(lats, 0.99)))
    return rows


def coexec_sim_rows(workload: str,
                    policies=COEXEC_POLICIES) -> list[dict]:
    """The same policy sweep on the DES (virtual time, deterministic)."""
    from ..core import SPEED_HINT_POLICIES, make_scheduler, paper_workload, \
        simulate

    wl, cpu, gpu = paper_workload(workload)
    rows = []
    for policy in policies:
        kw = {}
        if policy in SPEED_HINT_POLICIES:
            kw["speeds"] = [cpu.speed, gpu.speed]
        sched = make_scheduler(policy, wl.total, 2, **kw)
        r = simulate(sched, [cpu, gpu], wl)
        rows.append(dict(workload=workload, policy=policy,
                         seconds=r.total_s, packages=r.num_packages,
                         balance=r.balance(),
                         steals=getattr(sched, "steals", 0)))
    return rows


def coexec_multi_rows(workload: str = "taylor",
                      tenants=(1, 2, 4, 8, 16, 32), *,
                      per_tenant_items: int = 2048,
                      num_packages: int = 16,
                      policy: str = "dynamic",
                      admissions=("fifo", "wfq"),
                      fuse_modes=(False, True)) -> list[dict]:
    """Multi-tenant admission sweep on the DES: one row per (tenant count,
    admission policy, fusion mode) with p50/p99 latency, Jain fairness
    over per-tenant throughput, and total dispatched packages. `policy`
    picks each tenant's intra-launch scheduler. Shared by
    `serve --coexec sim --admission/--fuse/--tenants` and
    `benchmarks.run coexec-multi`.
    """
    from ..core import (SPEED_HINT_POLICIES, AdmissionConfig, LaunchSpec,
                        Workload, jain_index, make_scheduler, paper_workload,
                        simulate_multi)

    import numpy as np

    base, cpu, gpu = paper_workload(workload)
    per_item_in = base.bytes_in_per_item
    per_item_out = base.bytes_out_per_item
    # keep the profile's irregularity: resample its per-item weights to
    # the per-tenant problem size (as paper_workload does for size sweeps)
    weights = None
    if base.weights is not None:
        idx = np.linspace(0, len(base.weights) - 1,
                          per_tenant_items).astype(int)
        weights = base.weights[idx]
    sched_kw = {}
    if policy in SPEED_HINT_POLICIES:
        sched_kw["speeds"] = [cpu.speed, gpu.speed]
    elif policy == "dynamic":
        sched_kw["num_packages"] = num_packages

    def specs(nt):
        out = []
        for t in range(nt):
            wl = Workload(name=base.name, total=per_tenant_items,
                          bytes_in_per_item=per_item_in,
                          bytes_out_per_item=per_item_out,
                          working_set_bytes=base.working_set_bytes
                          * per_tenant_items / base.total,
                          weights=weights,
                          contention_scale=base.contention_scale)
            sched = make_scheduler(policy, per_tenant_items, 2, **sched_kw)
            out.append(LaunchSpec(wl, sched, tenant=f"t{t}"))
        return out

    rows = []
    for nt in tenants:
        for adm in admissions:
            for fuse in fuse_modes:
                cfg = AdmissionConfig(policy=adm, fuse=fuse,
                                      fuse_threshold=per_tenant_items,
                                      fuse_wait_s=0.0)
                res = simulate_multi(specs(nt), [cpu, gpu], admission=cfg)
                lats = sorted(res.latencies())
                thru = [r.items / max(r.latency_s, 1e-12)
                        for r in res.launches]
                rows.append(dict(
                    workload=workload, tenants=nt, admission=adm, fuse=fuse,
                    policy=policy,
                    p50_ms=_percentile_ms(lats, 0.5),
                    p99_ms=_percentile_ms(lats, 0.99),
                    fairness=jain_index(thru),
                    packages=res.dispatched_packages,
                    fused_batches=res.fused_batches,
                    total_ms=1e3 * res.total_s))
    return rows


def serve_coexec_real(args) -> None:
    policies = (COEXEC_POLICIES if args.policy == "all" else (args.policy,))
    for row in coexec_real_rows(policies, n=args.n, requests=args.requests,
                                concurrent=args.concurrent,
                                admission=args.admission, fuse=args.fuse):
        print(f"[serve/coexec] {row['policy']:13s} ({args.admission}"
              f"{'+fuse' if args.fuse else ''}): {row['requests']} "
              f"requests ({row['concurrent']} in flight) in "
              f"{row['seconds']:.3f}s = {row['req_per_s']:6.1f} req/s, "
              f"{row['requests'] * row['n'] / row['seconds'] / 1e6:7.2f} "
              f"Mitems/s, {row['packages']} packages, "
              f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")


def serve_coexec_sim(args) -> None:
    if args.admission != "fifo" or args.fuse or args.tenants is not None:
        policies = (COEXEC_POLICIES if args.policy == "all"
                    else (args.policy,))
        for policy in policies:
            for row in coexec_multi_rows(args.workload,
                                         tenants=(args.tenants or 8,),
                                         policy=policy,
                                         admissions=(args.admission,),
                                         fuse_modes=(args.fuse,)):
                print(f"[serve/coexec-multi] {row['workload']}"
                      f"/{row['policy']}/{row['tenants']}t/{row['admission']}"
                      f"{'+fuse' if row['fuse'] else ''}: "
                      f"p50={row['p50_ms']:.2f}ms p99={row['p99_ms']:.2f}ms "
                      f"fairness={row['fairness']:.3f} "
                      f"packages={row['packages']} "
                      f"(fused_batches={row['fused_batches']})")
        return
    policies = (COEXEC_POLICIES if args.policy == "all" else (args.policy,))
    for row in coexec_sim_rows(args.workload, policies):
        print(f"[serve/coexec-sim] {row['workload']}/{row['policy']:13s}: "
              f"{row['seconds']:7.3f}s, {row['packages']:4d} packages, "
              f"balance={row['balance']:.2f}, steals={row['steals']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--coexec", choices=["off", "real", "sim"],
                    default="off",
                    help="serve co-execution kernel requests instead of LM "
                         "decode: 'real' uses the persistent CoexecEngine, "
                         "'sim' the discrete-event simulator")
    ap.add_argument("--policy", default="all",
                    help=f"coexec scheduling policy to serve with, or "
                         f"'all' to sweep {COEXEC_POLICIES}")
    ap.add_argument("--concurrent", type=int, default=8,
                    help="max in-flight launch_async requests (coexec real)")
    ap.add_argument("--n", type=int, default=1 << 16,
                    help="items per coexec request (coexec real)")
    ap.add_argument("--workload", default="mandelbrot",
                    help="paper workload profile (coexec sim)")
    ap.add_argument("--admission", choices=["fifo", "wfq"], default="fifo",
                    help="cross-launch queueing: FIFO drain or "
                         "weighted-fair (deficit round robin per tenant)")
    ap.add_argument("--fuse", action="store_true",
                    help="coalesce small same-shaped concurrent launches "
                         "into shared dispatches")
    ap.add_argument("--tenants", type=int, default=None,
                    help="concurrent tenants for the multi-tenant sim "
                         "sweep (coexec sim; implied 8 when --admission "
                         "wfq or --fuse is given)")
    args = ap.parse_args()

    if args.tenants is not None and args.tenants < 1:
        ap.error("--tenants must be a positive integer")

    if args.coexec == "real":
        return serve_coexec_real(args)
    if args.coexec == "sim":
        return serve_coexec_sim(args)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import build_model

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(model.decode_step)

    B, P, G = args.batch, args.prompt_len, args.max_tokens
    served = 0
    t0 = time.perf_counter()
    rngs = jax.random.split(jax.random.PRNGKey(1),
                            -(-args.requests // B))
    for batch_id, rk in enumerate(rngs):
        n = min(B, args.requests - served)
        prompts = jax.random.randint(rk, (B, P), 0, cfg.vocab_size)
        cache = model.init_cache(B, P + G)
        if model.prefill is not None:
            batch = {"tokens": prompts,
                     "frames": jnp.zeros((B, cfg.encoder_seq,
                                          cfg.d_model), jnp.bfloat16)}
            cache = jax.jit(model.prefill)(params, batch, cache)
        for t in range(P):
            logits, cache = step(params, prompts[:, t:t + 1], cache)
        cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        for _ in range(G - 1):
            logits, cache = step(params, cur, cache)
            cur = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        jax.block_until_ready(cur)
        served += n
    dt = time.perf_counter() - t0
    print(f"[serve] {served} requests, {served * (P + G)} tokens in "
          f"{dt:.2f}s ({served * (P + G) / dt:.0f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
