"""Production training launcher.

Two modes:
  * default      — run the hetero-DP training loop on this host (reduced
                   configs; groups simulated). This is the runnable path.
  * --dry-run    — delegate to dryrun.py semantics for the full config on
                   the production mesh (lower+compile only).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 100 --policy hguided --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--policy", default="hguided",
                    choices=["static", "dynamic", "hguided"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--groups", default="podA:1.0,podB:0.6,podC:0.3",
                    help="name:speed pairs for the device groups")
    ap.add_argument("--dry-run", action="store_true",
                    help="full config on the production mesh, "
                         "lower+compile only")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        from .dryrun import run_cell
        run_cell(args.arch, args.shape, args.multi_pod)
        return

    import tempfile

    import jax

    from ..checkpoint import Checkpointer
    from ..configs import get_config
    from ..data import DataPipeline
    from ..ft import Supervisor
    from ..hetero import HeteroTrainer, make_policy
    from ..models import build_model, count_params
    from ..optim import AdamW, make_schedule

    groups = {}
    for part in args.groups.split(","):
        name, speed = part.split(":")
        groups[name] = float(speed)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[train] {args.arch} ({count_params(params):,} params, "
          f"reduced) × {len(groups)} groups, policy={args.policy}")

    pipe = DataPipeline(seed=1, global_batch=args.microbatches,
                        seq_len=args.seq_len, vocab=cfg.vocab_size,
                        num_shards=args.microbatches)
    trainer = HeteroTrainer(
        model, params,
        optimizer=AdamW(lr=make_schedule(cfg.schedule, 3e-3, 10,
                                         args.steps)),
        policy=make_policy(args.policy, {g: 1.0 for g in groups},
                           total_steps=args.steps),
        pipeline=pipe, group_speeds=groups,
        total_microbatches=args.microbatches)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train_ckpt_")
    ck = Checkpointer(ckpt_dir)
    if args.resume and ck.latest_step() is not None:
        step, tree = ck.restore(trainer.state_tree())
        trainer.load_state_tree(tree)
        print(f"[train] resumed from step {step}")
    sup = Supervisor(trainer, ck, ckpt_every=args.ckpt_every)
    report = sup.run(args.steps)
    print(f"[train] done: {report.steps_run} steps, "
          f"loss {report.losses[0]:.4f} → {report.losses[-1]:.4f}, "
          f"ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
