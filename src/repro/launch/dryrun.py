import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Everything below is ordinary.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import warnings          # noqa: E402

warnings.filterwarnings("ignore")

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, get_config           # noqa: E402
from ..models import (build_model, cache_specs, count_params,  # noqa: E402
                      param_specs)
from ..models.sharding import batch_spec                      # noqa: E402
from ..optim import AdamW, clip_by_global_norm                # noqa: E402
from ..roofline import (Roofline, cell_bytes, cell_flops,     # noqa: E402
                        collective_bytes)
from .mesh import make_production_mesh                        # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions every op),
  * the per-device footprint fits (memory_analysis),
  * and it yields the §Roofline terms (cost_analysis + HLO collectives).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun
"""


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sharded_bytes(structs, specs, mesh) -> float:
    """Per-device bytes of a ShapeDtypeStruct tree under `specs`."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0.0
    for leaf, spec in zip(jax.tree_util.tree_leaves(structs),
                          jax.tree_util.tree_leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= sizes.get(ax, 1)
        total += leaf.size * leaf.dtype.itemsize / shards
    return total


def make_batch_specs(cfg, shape, mesh):
    """ShapeDtypeStructs + shardings for one input shape."""
    B, S = shape.global_batch, shape.seq_len
    sh = lambda arr_shape, dtype: jax.ShapeDtypeStruct(arr_shape, dtype)
    structs: dict = {}
    if shape.kind in ("train", "prefill"):
        structs["tokens"] = sh((B, S), jnp.int32)
        if shape.kind == "train":
            structs["labels"] = sh((B, S), jnp.int32)
        if cfg.family == "encdec":
            structs["frames"] = sh((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
        if cfg.family == "vlm":
            structs["vision_embeds"] = sh((B, cfg.vision_tokens,
                                           cfg.d_model), jnp.float32)
    else:  # decode: one new token against a seq_len-deep cache
        structs["tokens"] = sh((B, 1), jnp.int32)
    shards = {k: NamedSharding(mesh, batch_spec(v.shape))
              for k, v in structs.items()}
    return structs, shards


# microbatch count per heavy train cell (activation stash / accum)
GRAD_ACCUM: dict[tuple[str, str], int] = {
    ("qwen1.5-110b", "train_4k"): 2,
    ("qwen3-moe-235b-a22b", "train_4k"): 2,
    ("phi3.5-moe-42b-a6.6b", "train_4k"): 2,
    ("minicpm-2b", "train_4k"): 2,
}


def model_flops_for(cfg, shape, n_params: int) -> float:
    n_active = cfg.n_active_params() if cfg.family == "moe" else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/row


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full quadratic attention; see DESIGN.md §5"}

    # full configs lower with chunked attention (O(T·c) memory), the
    # chunked SSD/mLSTM mixer (the per-timestep oracle would scan T steps
    # and stash the matrix memory at every one), and remat
    cfg = dataclasses.replace(cfg, attn_impl="chunked",
                              mixer_impl="chunked", remat=True)
    # FSDP (ZeRO-3) for configs whose f32 params+Adam state exceed a
    # v5e's HBM under TP-16-only sharding (>8 GB/device replicated)
    from ..models.sharding import set_fsdp
    set_fsdp(cfg.n_params() * 12 / 16 > 8e9)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    chips = mesh.devices.size
    # perf_counter: compile timing must be monotonic (wall clock jumps
    # under NTP adjustment)
    t0 = time.perf_counter()

    with jax.sharding.set_mesh(mesh):
        params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n_params = count_params(params_struct)
        p_specs = param_specs(params_struct)
        p_shard = _named(mesh, p_specs)
        batch_structs, batch_shards = make_batch_specs(cfg, shape, mesh)

        if shape.kind == "train":
            optimizer = AdamW(lr=1e-4)
            opt_struct = jax.eval_shape(optimizer.init, params_struct)
            opt_shard = type(opt_struct)(
                step=NamedSharding(mesh, P()),
                m=p_shard, v=p_shard)
            accum = GRAD_ACCUM.get((arch, shape_name), 1)

            def train_step(params, opt_state, batch):
                if accum > 1:
                    # microbatched gradient accumulation: divides the
                    # remat activation stash by `accum` so the monster
                    # configs fit a 16 GB v5e
                    from ..xscan import xscan

                    def micro(carry, mb):
                        g_acc, l_acc = carry
                        (l, _), g = jax.value_and_grad(
                            model.loss, has_aux=True)(params, mb)
                        g_acc = jax.tree.map(jnp.add, g_acc, g)
                        return (g_acc, l_acc + l), None

                    mbs = jax.tree.map(
                        lambda x: x.reshape(accum, x.shape[0] // accum,
                                            *x.shape[1:]), batch)
                    zero = jax.tree.map(jnp.zeros_like, params)
                    (grads, loss), _ = xscan(
                        micro, (zero, jnp.zeros((), jnp.float32)), mbs,
                        name="grad_accum")
                    grads = jax.tree.map(lambda g: g / accum, grads)
                    loss = loss / accum
                else:
                    (loss, metrics), grads = jax.value_and_grad(
                        model.loss, has_aux=True)(params, batch)
                grads, gnorm = clip_by_global_norm(grads, 1.0)
                params, opt_state = optimizer.update(grads, opt_state,
                                                     params)
                return params, opt_state, loss

            fn = jax.jit(
                train_step,
                in_shardings=(p_shard, opt_shard, batch_shards),
                out_shardings=(p_shard, opt_shard,
                               NamedSharding(mesh, P())))
            args = (params_struct, opt_struct, batch_structs)

        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill_logits(params, batch)

            fn = jax.jit(prefill_step,
                         in_shardings=(p_shard, batch_shards),
                         out_shardings=NamedSharding(mesh, P(
                             ("pod", "data") if multi_pod else ("data",))))
            args = (params_struct, batch_structs)

        else:  # decode
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            c_specs = cache_specs(cache_struct)
            c_shard = _named(mesh, c_specs)

            def serve_step(params, cache, tokens):
                return model.decode_step(params, tokens, cache)

            fn = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard,
                              batch_shards["tokens"]),
                out_shardings=(NamedSharding(mesh, P()), c_shard))
            args = (params_struct, cache_struct, batch_structs["tokens"])

        lowered = fn.lower(*args)
        compiled = lowered.compile()

        # per-device footprint of the sharded state
        param_bytes_dev = sharded_bytes(params_struct, p_specs, mesh)
        if shape.kind == "train":
            state_bytes_dev = 3 * param_bytes_dev      # + m + v
            cache_bytes_dev = 0.0
        elif shape.kind == "decode":
            cache_bytes_dev = sharded_bytes(cache_struct, c_specs, mesh)
            state_bytes_dev = param_bytes_dev + cache_bytes_dev
        else:
            cache_bytes_dev = 0.0
            state_bytes_dev = param_bytes_dev

    # ---- artifacts -----------------------------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:   # CPU backend may not implement it
        mem["error"] = str(e)
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) \
        else (cost_list or {})
    hlo = compiled.as_text()

    dp_shards = chips // 16                    # pod×data axes (model = 16)
    flops_global = cell_flops(cfg, shape)["total_flops"]
    bytes_dev = cell_bytes(cfg, shape,
                           param_bytes_per_dev=param_bytes_dev,
                           cache_bytes_per_dev=cache_bytes_dev,
                           chips=chips, dp_shards=dp_shards)
    coll = collective_bytes(hlo)
    hbm_footprint = None
    if "argument_size_in_bytes" in mem:
        hbm_footprint = (mem["argument_size_in_bytes"] +
                         mem.get("temp_size_in_bytes", 0) +
                         mem.get("output_size_in_bytes", 0))
    roof = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_dev=flops_global / chips,
        bytes_per_dev=bytes_dev,
        coll_bytes_per_dev=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, shape, n_params),
        xla_raw_flops=float(cost.get("flops", 0.0)),
        xla_raw_bytes=float(cost.get("bytes accessed", 0.0)),
        hbm_per_dev=hbm_footprint,
    )
    out = {"status": "ok", "n_params": n_params,
           "compile_seconds": round(time.perf_counter() - t0, 1),
           "state_bytes_per_dev": state_bytes_dev,
           "memory_analysis": mem, **roof.to_dict()}
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] "
              f"compile={out['compile_seconds']}s "
              f"t_comp={roof.t_compute*1e3:.1f}ms "
              f"t_mem={roof.t_memory*1e3:.1f}ms "
              f"t_coll={roof.t_collective*1e3:.1f}ms "
              f"bound={roof.bottleneck} "
              f"frac={roof.roofline_frac:.3f} "
              f"hbm/dev={(hbm_footprint or 0)/2**30:.2f}GiB")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mesh in ("single", "multi"):
                    cells.append((arch, shape, mesh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        cells = [(args.arch, args.shape, args.mesh)]

    for arch, shape, mesh in cells:
        key = f"{arch}__{shape}__{mesh}".replace("/", "_")
        path = os.path.join(args.out, key + ".json")
        if os.path.exists(path):
            print(f"[skip existing] {key}")
            continue
        result = run_cell(arch, shape, mesh == "multi")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
