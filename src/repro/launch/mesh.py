"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:          # older jax: meshes have no axis types
    AxisType = None


def _mk(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh with Auto axis types (tests use small fakes)."""
    return _mk(shape, axes)
