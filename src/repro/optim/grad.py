"""Gradient utilities: clipping, accumulation, cross-group compression.

`compress_bf16` + `ErrorFeedback` implement 2x gradient-traffic compression
for the cross-pod all-reduce (the "pod" axis rides DCN, the slowest link in
the §Roofline collective term): gradients are cast to bf16 before the
cross-pod reduction and the quantization residual is fed back into the next
step's gradient (error feedback keeps convergence unbiased in expectation).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


class ErrorFeedback(NamedTuple):
    residual: PyTree

    @classmethod
    def init(cls, params: PyTree) -> "ErrorFeedback":
        return cls(residual=jax.tree.map(jnp.zeros_like, params))


def compress_bf16(grads: PyTree, ef: Optional[ErrorFeedback] = None
                  ) -> tuple[PyTree, Optional[ErrorFeedback]]:
    """Cast grads to bf16 for the wire; error-feedback the residual."""
    if ef is not None:
        grads = jax.tree.map(lambda g, r: g + r, grads, ef.residual)
    wire = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if ef is not None:
        new_res = jax.tree.map(
            lambda g, w: g - w.astype(g.dtype), grads, wire)
        return wire, ErrorFeedback(residual=new_res)
    return wire, None


def accumulate_grads(loss_fn, params: PyTree, microbatches: list[dict]
                     ) -> tuple[jax.Array, PyTree]:
    """Sequential gradient accumulation over microbatches (jit-unrolled)."""
    total_loss = 0.0
    acc = None
    for mb in microbatches:
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        total_loss = total_loss + loss
        acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
    n = len(microbatches)
    return total_loss / n, jax.tree.map(lambda x: x / n, acc)
