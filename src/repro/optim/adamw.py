"""AdamW optimizer (pure JAX, pytree-native).

State layout mirrors the param tree (m, v per leaf + scalar step), so the
parameter sharding specs apply verbatim to the optimizer state — this is
what lets dryrun shard (params, opt_state) with one spec tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            return p - lr * (mh / (jnp.sqrt(vh) + self.eps)
                             + self.weight_decay * p)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v)
