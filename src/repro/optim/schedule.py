"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM).

WSD is the schedule minicpm-2b trains with: linear warmup → long stable
plateau → short (10 %) exponential-ish decay. Exposed as callables
step → lr for AdamW.
"""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(peak_lr: float, warmup: int, total: int,
                         floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def wsd(peak_lr: float, warmup: int, total: int, decay_frac: float = 0.1,
        floor: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay."""
    decay_start = int(total * (1.0 - decay_frac))

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - decay_start) / max(total - decay_start, 1),
                        0.0, 1.0)
        dec = peak_lr * (floor ** frac)     # exponential decay to floor·peak
        stable = jnp.full_like(step, peak_lr)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, stable, dec))
        return out
    return lr


def make_schedule(name: str, peak_lr: float, warmup: int, total: int):
    if name == "wsd":
        return wsd(peak_lr, warmup, total)
    return linear_warmup_cosine(peak_lr, warmup, total)
