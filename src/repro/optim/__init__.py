from .adamw import AdamW, AdamWState
from .grad import (ErrorFeedback, accumulate_grads, clip_by_global_norm,
                   compress_bf16, global_norm)
from .schedule import linear_warmup_cosine, make_schedule, wsd

__all__ = ["AdamW", "AdamWState", "ErrorFeedback", "accumulate_grads",
           "clip_by_global_norm", "compress_bf16", "global_norm",
           "linear_warmup_cosine", "make_schedule", "wsd"]
