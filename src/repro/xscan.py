"""Scan wrapper that embeds loop trip counts into HLO op metadata.

XLA's cost_analysis counts while-loop bodies exactly once, so any
scan-based model under-reports FLOPs/bytes/collective traffic by its trip
count. `xscan` tags every op inside the loop (forward *and* the transposed
backward loop — named scopes survive jvp/transpose) with ``xscan[N]`` in
`op_name`; roofline/analysis.py multiplies in-loop collective payloads by
the product of enclosing scan counts. Nested scans compose naturally:
"…xscan[13]/…/xscan[6]/…" ⇒ ×78.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

Carry = Any


def xscan(body: Callable, carry: Carry, xs: Any, *,
          name: str = "layers", length: Optional[int] = None,
          remat: bool = False) -> tuple[Carry, Any]:
    if length is None:
        length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    fn = jax.checkpoint(body) if remat else body
    with jax.named_scope(f"{name}.xscan[{length}]"):
        return jax.lax.scan(fn, carry, xs)


def xmap_seq(fn: Callable, xs: Any, *, name: str = "map",
             length: Optional[int] = None) -> Any:
    """lax.map with the same trip-count tagging."""
    if length is None:
        length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    with jax.named_scope(f"{name}.xscan[{length}]"):
        return jax.lax.map(fn, xs)
