"""Checkpointing: atomic, async, reshard-on-restore.

Trees are flattened to path-keyed arrays in a single .npz per checkpoint
(one per step, `ckpt_<step>.npz` + `latest` pointer written atomically via
rename). `save_async` hands the host copy to a writer thread so the train
loop never blocks on disk — the checkpoint analogue of the Commander
loop's compute/communication overlap. On restore, arrays are placed with
whatever sharding the *current* mesh prescribes (elastic restart: a 4-group
checkpoint restores onto 2 groups transparently, because the on-disk format
is sharding-free).
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "##"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: PyTree, flat: dict[str, np.ndarray],
                    place: Optional[Callable] = None) -> PyTree:
    def fill(path, leaf):
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        return place(arr, leaf) if place else arr
    return jax.tree_util.tree_map_with_path(fill, template)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------
    def _write(self, step: int, flat: dict[str, np.ndarray]) -> None:
        try:
            path = os.path.join(self.dir, f"ckpt_{step:010d}.npz")
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, path)            # atomic publish
            ptr = os.path.join(self.dir, "latest")
            with open(ptr + ".tmp", "w") as f:
                f.write(str(step))
            os.replace(ptr + ".tmp", ptr)
            self._gc()
        except BaseException as e:           # surfaced on next wait()
            self._error = e

    def _gc(self) -> None:
        ckpts = sorted(p for p in os.listdir(self.dir)
                       if p.startswith("ckpt_") and p.endswith(".npz"))
        for old in ckpts[:-self.keep]:
            os.remove(os.path.join(self.dir, old))

    def save(self, step: int, tree: PyTree) -> None:
        self.wait()
        self._write(step, _flatten(tree))

    def save_async(self, step: int, tree: PyTree) -> None:
        self.wait()                           # one outstanding save max
        flat = _flatten(tree)                 # host copy happens here
        self._thread = threading.Thread(
            target=self._write, args=(step, flat), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "latest")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip())

    def restore(self, template: PyTree, *, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> tuple[int, PyTree]:
        """Restore into the template's structure; reshard if specs given."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"ckpt_{step:010d}.npz")
        with np.load(path) as data:
            flat = {k: data[k] for k in data.files}
        if shardings is not None:
            spec_flat = _flatten_specs(shardings)

            def place(arr, leaf_path_key=None):
                return arr
            def fill(path, leaf):
                key = _SEP.join(
                    str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)
                return jax.device_put(flat[key], spec_flat[key])
            tree = jax.tree_util.tree_map_with_path(fill, template)
        else:
            tree = _unflatten_into(template, flat)
        return step, tree


def _flatten_specs(tree: PyTree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: hasattr(x, "spec") or x is None)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat
