from .monitor import GroupMonitor
from .rebalance import (DynamicPolicy, HGuidedPolicy, RebalancePolicy,
                        StaticPolicy, make_policy)
from .sharder import ExecutableCache, quantize_shares
from .trainer import HeteroTrainer, StepReport

__all__ = ["DynamicPolicy", "ExecutableCache", "GroupMonitor",
           "HGuidedPolicy", "HeteroTrainer", "RebalancePolicy",
           "StaticPolicy", "StepReport", "make_policy", "quantize_shares"]
