"""Per-group throughput monitoring + straggler detection.

A *group* is a co-execution unit at fleet scale: a pod slice, a host, or a
simulated device group. The monitor keeps an EWMA of tokens/second per
group; stragglers are groups whose throughput falls below
`straggler_factor ×` the median. The rebalance policies (rebalance.py)
consume `shares()` and the supervisor (ft/) consumes `stragglers()`.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Optional

from ..core.profiler import EwmaThroughput


@dataclasses.dataclass
class GroupStats:
    name: str
    ewma: EwmaThroughput
    steps: int = 0
    alive: bool = True

    @property
    def throughput(self) -> float:
        return self.ewma.value


class GroupMonitor:
    def __init__(self, names: list[str], *, halflife: float = 4.0,
                 straggler_factor: float = 0.6):
        self.groups = {n: GroupStats(n, EwmaThroughput(halflife=halflife))
                       for n in names}
        self.straggler_factor = straggler_factor

    def record(self, name: str, tokens: float, seconds: float) -> None:
        g = self.groups[name]
        g.ewma.update(tokens, seconds)
        g.steps += 1

    def mark_dead(self, name: str) -> None:
        self.groups[name].alive = False

    def revive(self, name: str) -> None:
        self.groups[name].alive = True

    def alive(self) -> list[str]:
        return [n for n, g in self.groups.items() if g.alive]

    def throughputs(self) -> dict[str, float]:
        return {n: g.throughput for n, g in self.groups.items() if g.alive}

    def shares(self, fallback: Optional[dict[str, float]] = None
               ) -> dict[str, float]:
        """Measured relative speeds (normalized), hints before warm-up."""
        tps = self.throughputs()
        if not tps:
            return {}
        if any(v <= 0 for v in tps.values()):
            if fallback:
                alive = {n: fallback.get(n, 1.0) for n in tps}
            else:
                alive = {n: 1.0 for n in tps}
            tot = sum(alive.values())
            return {n: v / tot for n, v in alive.items()}
        tot = sum(tps.values())
        return {n: v / tot for n, v in tps.items()}

    def stragglers(self, warmup: int = 3) -> list[str]:
        """Groups below straggler_factor x median throughput.

        Groups with fewer than `warmup` observations are excluded: the
        first step folds compilation into the measurement, which would
        otherwise flag whichever group compiled first.
        """
        tps = {n: v for n, v in self.throughputs().items()
               if v > 0 and self.groups[n].steps >= warmup}
        if len(tps) < 2:
            return []
        med = statistics.median(tps.values())
        return [n for n, v in tps.items()
                if v < self.straggler_factor * med]
