"""The paper's load balancers lifted to step-level SPMD rebalancing.

oneAPI's limitation — a kernel's device split is fixed at compile time — is
exactly SPMD pjit's: one compiled step bakes in one batch sharding. The
Coexecutor answer maps onto training as *ratio scheduling*: each device
group's share of the global batch is re-decided between steps.

* ``StaticPolicy``   — shares fixed from hints forever (the paper's Static:
                       one decision, no adaptation).
* ``DynamicPolicy``  — every `period` steps, jump straight to the measured
                       throughput shares (the paper's Dynamic(N): the
                       training run is N = total/period packages re-split
                       on demand; small period = Dyn200, large = Dyn5).
* ``HGuidedPolicy``  — shares move toward measured throughput by a step
                       size that *shrinks* as training progresses, with a
                       minimum-share floor — the HGuided package-size law
                       ``max(min_pkg, rem·speed/(K·Σspeed))`` expressed in
                       ratio space: aggressive big corrections early, fine
                       trim later, never starving a live group.

Every policy emits shares quantized later by sharder.py; a changed
assignment costs one executable-cache entry (compile) — the analogue of the
package-launch overhead the paper charges per package.
"""
from __future__ import annotations

import abc
from typing import Optional

# the scale-down/scale-up ratio moves are shared with the serving
# cluster tier's Supervisor (they were absorbed into repro.core.cluster
# as pure functions when the elastic pool landed)
from ..core.cluster import absorb_share, grant_share


class RebalancePolicy(abc.ABC):
    name = "base"

    def __init__(self, hints: dict[str, float]):
        tot = sum(hints.values())
        self.shares_: dict[str, float] = {k: v / tot for k, v in
                                          hints.items()}

    @property
    def shares(self) -> dict[str, float]:
        return dict(self.shares_)

    def drop_group(self, name: str) -> None:
        """Elastic scale-down: dead group's share redistributes ∝ rest."""
        self.shares_ = absorb_share(self.shares_, name)

    def add_group(self, name: str, hint_share: float) -> None:
        """Elastic scale-up: newcomer enters at its hint share."""
        self.shares_ = grant_share(self.shares_, name, hint_share)

    @abc.abstractmethod
    def update(self, step: int, measured: dict[str, float]) -> bool:
        """Ingest measured shares; return True if shares changed."""


class StaticPolicy(RebalancePolicy):
    name = "static"

    def update(self, step: int, measured: dict[str, float]) -> bool:
        return False


class DynamicPolicy(RebalancePolicy):
    name = "dynamic"

    def __init__(self, hints: dict[str, float], *, period: int = 10):
        super().__init__(hints)
        self.period = max(1, period)

    def update(self, step: int, measured: dict[str, float]) -> bool:
        if step % self.period or not measured:
            return False
        keep = {k: v for k, v in measured.items() if k in self.shares_}
        tot = sum(keep.values())
        if tot <= 0:
            return False
        new = {k: v / tot for k, v in keep.items()}
        changed = any(abs(new[k] - self.shares_[k]) > 1e-3 for k in new)
        self.shares_ = new
        return changed


class HGuidedPolicy(RebalancePolicy):
    name = "hguided"

    def __init__(self, hints: dict[str, float], *, total_steps: int,
                 divisor: float = 2.0, min_share: float = 0.02):
        super().__init__(hints)
        self.total_steps = max(1, total_steps)
        self.divisor = divisor
        self.min_share = min_share

    def update(self, step: int, measured: dict[str, float]) -> bool:
        keep = {k: v for k, v in measured.items() if k in self.shares_}
        tot = sum(keep.values())
        if tot <= 0:
            return False
        target = {k: v / tot for k, v in keep.items()}
        # HGuided step size: remaining/(K·total) of the gap, floored — big
        # corrections while most of the run remains, trim near the end.
        remaining = max(0.0, 1.0 - step / self.total_steps)
        eta = max(0.1, remaining / self.divisor)
        changed = False
        new = {}
        for k, s in self.shares_.items():
            n = s + eta * (target.get(k, s) - s)
            new[k] = n
            changed |= abs(n - s) > 1e-3
        tot = sum(new.values())
        new = {k: v / tot for k, v in new.items()}
        # enforce the floor *after* normalization: lift floored groups and
        # take the excess proportionally from the rest (one pass suffices
        # for min_share « 1/num_groups)
        deficit = sum(max(0.0, self.min_share - v) for v in new.values())
        if deficit > 0:
            above = sum(v for v in new.values() if v > self.min_share)
            new = {k: (self.min_share if v <= self.min_share else
                       v - deficit * (v / above))
                   for k, v in new.items()}
        self.shares_ = new
        return changed


def make_policy(name: str, hints: dict[str, float], *,
                total_steps: int = 1000, period: int = 10,
                min_share: float = 0.02) -> RebalancePolicy:
    name = name.lower()
    if name == "static":
        return StaticPolicy(hints)
    if name.startswith("dyn"):
        if name not in ("dyn", "dynamic"):
            period = max(1, total_steps // int(name[3:]))
        return DynamicPolicy(hints, period=period)
    if name == "hguided":
        return HGuidedPolicy(hints, total_steps=total_steps,
                             min_share=min_share)
    raise KeyError(name)
