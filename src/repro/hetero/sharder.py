"""Quantize continuous group shares into microbatch assignments + cache
compiled executables per assignment.

Shares → integer microbatch counts via the largest-remainder method (sum
preserved exactly; alive groups with nonzero share get ≥1 microbatch).
Each distinct assignment keys a compiled-executable cache entry — the
recompile cost is the step-level analogue of the paper's package-launch
overhead, so policies are designed to change assignments rarely
(HGuided's damped corrections) while staying balanced.
"""
from __future__ import annotations

from typing import Any, Callable, Hashable


def quantize_shares(shares: dict[str, float], total_microbatches: int
                    ) -> dict[str, int]:
    """Largest-remainder quantization; every live group gets ≥ 1."""
    if not shares:
        return {}
    if total_microbatches < len(shares):
        raise ValueError(
            f"{total_microbatches} microbatches cannot feed "
            f"{len(shares)} groups")
    raw = {k: v * total_microbatches for k, v in shares.items()}
    floored = {k: max(1, int(v)) for k, v in raw.items()}
    drift = total_microbatches - sum(floored.values())
    # distribute the drift by largest remainder (or take from smallest)
    rema = sorted(shares, key=lambda k: raw[k] - int(raw[k]), reverse=True)
    i = 0
    while drift != 0:
        k = rema[i % len(rema)]
        if drift > 0:
            floored[k] += 1
            drift -= 1
        elif floored[k] > 1:
            floored[k] -= 1
            drift += 1
        i += 1
    return floored


class ExecutableCache:
    """Compiled-step cache keyed by the microbatch assignment."""

    def __init__(self, compile_fn: Callable[[Hashable], Any]):
        self._compile = compile_fn
        self._cache: dict[Hashable, Any] = {}
        self.compilations = 0

    def get(self, assignment: dict[str, int]) -> Any:
        key = tuple(sorted(assignment.items()))
        if key not in self._cache:
            self._cache[key] = self._compile(key)
            self.compilations += 1
        return self._cache[key]

    def __len__(self) -> int:
        return len(self._cache)
