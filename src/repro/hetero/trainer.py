"""HeteroTrainer: co-executed data-parallel training across unequal groups.

The training-step analogue of the Coexecutor Runtime: the global batch is a
queue of microbatch *packages*; each device group receives a quantized
share (policy-driven: static / dynamic / hguided), computes its partial
gradient, and the step closes with a weighted gradient combine — the
collect/merge phase of the Commander loop.

On this CPU-only container the groups are *simulated*: every group runs on
the local device but reports a virtual wall time scaled by its
heterogeneity factor (e.g. a 0.5x group is a half-speed pod slice or a
straggling, thermally-throttled slice). The gradient math is identical to
homogeneous data-parallel training — assignments change *where* microbatches
run, never their content — so loss trajectories are bit-comparable across
policies, which tests/test_hetero.py asserts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..data import DataPipeline
from ..optim import AdamW, clip_by_global_norm
from .monitor import GroupMonitor
from .rebalance import RebalancePolicy
from .sharder import ExecutableCache, quantize_shares

PyTree = Any


@dataclasses.dataclass
class StepReport:
    step: int
    loss: float
    assignment: dict[str, int]
    group_seconds: dict[str, float]   # virtual per-group wall time
    step_seconds: float               # max over groups (barrier)
    rebalanced: bool


class HeteroTrainer:
    def __init__(self, model, params: PyTree, *, optimizer: AdamW,
                 policy: RebalancePolicy, pipeline: DataPipeline,
                 group_speeds: dict[str, float],
                 total_microbatches: int,
                 grad_clip: float = 1.0,
                 monitor: Optional[GroupMonitor] = None):
        self.model = model
        self.params = params
        self.optimizer = optimizer
        self.opt_state = optimizer.init(params)
        self.policy = policy
        self.pipeline = pipeline
        self.group_speeds = dict(group_speeds)
        self.total_microbatches = total_microbatches
        self.grad_clip = grad_clip
        self.monitor = monitor or GroupMonitor(list(group_speeds))
        self.step = 0
        self.exec_cache = ExecutableCache(lambda key: self._compiled_fns)
        self.history: list[StepReport] = []

        def loss_fn(params, batch):
            return self.model.loss(params, batch)

        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        def apply_updates(params, opt_state, grads):
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
            params, opt_state = self.optimizer.update(grads, opt_state,
                                                      params)
            return params, opt_state, gnorm

        self._apply = jax.jit(apply_updates)
        self._compiled_fns = (self._grad_fn, self._apply)

    # ------------------------------------------------------------------
    def _assignment(self) -> dict[str, int]:
        alive = self.monitor.alive()
        shares = {k: v for k, v in self.policy.shares.items() if k in alive}
        tot = sum(shares.values())
        shares = {k: v / tot for k, v in shares.items()}
        return quantize_shares(shares, self.total_microbatches)

    def kill_group(self, name: str) -> None:
        """Elastic scale-down (node failure / preemption)."""
        self.monitor.mark_dead(name)
        self.policy.drop_group(name)

    def train_step(self) -> StepReport:
        assignment = self._assignment()
        self.exec_cache.get(assignment)      # compile-count accounting

        # deterministic global partition: microbatch i of this step is
        # identical no matter which group runs it
        mb_ids = list(range(self.total_microbatches))
        cursor = 0
        total_loss = 0.0
        grads_sum = None
        group_seconds: dict[str, float] = {}

        for name, count in assignment.items():
            ids = mb_ids[cursor:cursor + count]
            cursor += count
            t0 = time.perf_counter()
            for i in ids:
                batch = self.pipeline.batch_at(self.step, shard=i)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                (loss, _), grads = self._grad_fn(self.params, batch)
                total_loss += float(loss)
                grads_sum = grads if grads_sum is None else jax.tree.map(
                    jnp.add, grads_sum, grads)
            real = time.perf_counter() - t0
            virtual = real / self.group_speeds[name]
            group_seconds[name] = virtual
            tokens = count * self.pipeline.seq_len * (
                self.pipeline.global_batch // self.pipeline.num_shards)
            self.monitor.record(name, tokens, virtual)

        scale = 1.0 / self.total_microbatches
        grads = jax.tree.map(lambda g: g * scale, grads_sum)
        self.params, self.opt_state, _ = self._apply(
            self.params, self.opt_state, grads)

        measured = self.monitor.shares()
        rebalanced = self.policy.update(self.step, measured)
        report = StepReport(
            step=self.step,
            loss=total_loss / self.total_microbatches,
            assignment=assignment,
            group_seconds=group_seconds,
            step_seconds=max(group_seconds.values()),
            rebalanced=rebalanced,
        )
        self.history.append(report)
        self.step += 1
        return report

    def run(self, steps: int) -> list[StepReport]:
        return [self.train_step() for _ in range(steps)]

    # -- checkpoint integration ----------------------------------------
    def state_tree(self) -> PyTree:
        return {"params": self.params,
                "m": self.opt_state.m, "v": self.opt_state.v,
                "opt_step": self.opt_state.step,
                "step": jnp.asarray(self.step)}

    def load_state_tree(self, tree: PyTree) -> None:
        from ..optim.adamw import AdamWState
        self.params = tree["params"]
        self.opt_state = AdamWState(step=jnp.asarray(tree["opt_step"]),
                                    m=tree["m"], v=tree["v"])
        self.step = int(tree["step"])
