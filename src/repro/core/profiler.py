"""Online throughput profiling for Coexecution Units.

The HGuided scheduler needs relative computing speeds. The paper takes a
programmer hint (``dist(0.35)``) but the runtime also refines speeds online;
we implement that refinement as an exponentially-weighted moving average of
measured package throughput (items/second), which also powers the hetero/
step-level monitor and straggler detection.
"""
from __future__ import annotations

import dataclasses
import math
import threading


@dataclasses.dataclass
class EwmaThroughput:
    """EWMA of items/second with debiased warm-up."""

    halflife: float = 4.0      # in number of observations
    _value: float = 0.0
    _weight: float = 0.0

    def update(self, items: float, seconds: float) -> float:
        if seconds <= 0:
            return self.value
        rate = items / seconds
        decay = math.exp(-math.log(2.0) / self.halflife)
        self._value = decay * self._value + (1 - decay) * rate
        self._weight = decay * self._weight + (1 - decay)
        return self.value

    @property
    def value(self) -> float:
        return self._value / self._weight if self._weight > 0 else 0.0


class SpeedBoard:
    """Thread-safe per-unit throughput board shared with the Scheduler.

    On the persistent engine one board outlives every launch: speeds
    learned from earlier launches' packages seed the adaptive (HGuided)
    refinement of later ones. Cumulative busy/items counters let callers
    compute utilization over the engine's lifetime; per-launch stats are
    kept separately (from each launch's own packages) so concurrent
    launches stay isolated.
    """

    def __init__(self, num_units: int, hints: list[float] | None = None):
        self._ewma = [EwmaThroughput() for _ in range(num_units)]
        self._hints = list(hints) if hints else [1.0] * num_units
        self._busy_s = [0.0] * num_units
        self._items = [0.0] * num_units
        self._lock = threading.Lock()

    def record(self, unit: int, items: float, seconds: float) -> None:
        with self._lock:
            self._ewma[unit].update(items, seconds)
            self._busy_s[unit] += max(seconds, 0.0)
            self._items[unit] += items

    def snapshot(self) -> dict[int, dict[str, float]]:
        """Point-in-time view: {unit: {speed, busy_s, items}} (lifetime)."""
        with self._lock:
            return {i: {"speed": (e.value if e.value > 0 else hint),
                        "busy_s": b, "items": n}
                    for i, (e, hint, b, n)
                    in enumerate(zip(self._ewma, self._hints,
                                     self._busy_s, self._items))}

    def speeds(self) -> list[float]:
        """Measured speeds, falling back to hints before observations."""
        with self._lock:
            out = []
            for hint, e in zip(self._hints, self._ewma):
                v = e.value
                out.append(v if v > 0 else hint)
            return out

    def relative(self) -> list[float]:
        s = self.speeds()
        tot = sum(s)
        return [x / tot for x in s] if tot > 0 else s
