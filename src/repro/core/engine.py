"""Persistent co-execution engine (EngineCL-style, arXiv:1805.02755).

The paper's antecedent EngineCL shows that co-execution management overhead
stays under 1% only when the runtime is a *persistent engine*: worker threads
are created once and fed work, instead of being spawned and joined per
launch. This module provides that engine for the Coexecutor Runtime:

* one long-lived management thread per Coexecution Unit, started by
  :meth:`CoexecEngine.start` and parked on a condition variable when idle;
* a multi-tenant launch queue — any number of callers may
  :meth:`CoexecEngine.submit` co-executions concurrently; packages from all
  in-flight launches interleave on the same units under the engine's
  admission policy (FIFO by default — the Commander protocol of Fig. 2a —
  or weighted-fair queueing across tenants);
* a cross-launch :class:`~.admission.AdmissionController` between ``submit``
  and the workers: deficit-round-robin fairness (``admission="wfq"``),
  coalescing of small same-shaped concurrent launches into shared vmapped
  dispatches (``fuse=True``), and backpressure (``max_inflight`` with a
  blocking or :class:`~.admission.AdmissionFull`-raising submit path);
* per-launch isolation — each launch owns its scheduler, output container,
  package log and :class:`LaunchStats`; completion is surfaced through a
  :class:`LaunchHandle` future, so independent callers never observe each
  other's state;
* a persistent :class:`~.profiler.SpeedBoard` — throughput measured on
  earlier launches seeds the adaptive (HGuided) speed refinement of later
  ones, which a per-launch thread pool could never do;
* a per-memory-model data plane (:mod:`~repro.core.dataplane`) between
  the workers and the units: the spec's ``MemorySpec`` selects zero-copy
  unified-shared-memory movement or per-package staged buffers, with
  copy/dispatch counters surfaced in each launch's :class:`LaunchStats`.

Lifecycle::

    engine = CoexecEngine(units, admission="wfq", fuse=True)
    engine.start()
    h1 = engine.submit(sched1, kernel_a, inputs_a, out_a, tenant="u1")
    h2 = engine.submit(sched2, kernel_b, inputs_b, out_b, tenant="u2")
    out_a = h1.result(); out_b = h2.result()
    engine.shutdown()            # drains in-flight launches, joins threads

or, scoped::

    with CoexecEngine(units) as engine:
        out = engine.submit(sched, kernel, inputs, out).result()
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .admission import (AdmissionConfig, AdmissionController, AdmissionFull,
                        coerce_admission)
from .dataplane import (CoexecKernel, DataPlaneCounters, as_coexec_kernel,
                        make_plane)
from .memory import MemoryModel
from .package import Package, Range, validate_cover
from .profiler import SpeedBoard
from .scheduler import DynamicScheduler, HGuidedScheduler, Scheduler
from .units import JaxUnit

# Pre-3.11 `concurrent.futures.TimeoutError` is not the builtin; subclass
# whichever classes exist so `except TimeoutError` catches both flavors.
_TIMEOUT_BASES = ((TimeoutError,)
                  if concurrent.futures.TimeoutError is TimeoutError
                  else (concurrent.futures.TimeoutError, TimeoutError))


class LaunchWaitTimeout(*_TIMEOUT_BASES):
    """The *wait* on a LaunchHandle timed out; the launch itself is fine.

    Distinguishes "I gave up waiting" from "the launch failed": a launch
    whose kernel raised ``TimeoutError`` surfaces that original exception
    from :meth:`LaunchHandle.result` / returns it from
    :meth:`LaunchHandle.exception`, never this class. Subclasses
    ``TimeoutError`` (both flavors), so broad handlers keep working.
    """


@dataclasses.dataclass
class LaunchStats:
    """Per-launch metrics mirroring the paper's measurements.

    Isolated per submit: concurrent launches on the same engine each get
    their own instance (busy seconds are derived from this launch's
    packages only, never from cumulative unit counters). For a launch that
    was served through a fused batch, ``packages`` holds one synthesized
    package covering the launch's whole index space, timed by the shared
    dispatch that computed it (and ``data`` is the member's even integer
    share of the batch's counters, so summing member stats recovers the
    batch's real copy/dispatch totals).

    ``data`` carries the launch's data-plane accounting — dispatches and
    explicit H2D/D2H staging copies/bytes — so the USM-vs-BUFFERS
    distinction of the configured :class:`~.memory.MemoryModel` is
    observable per launch (USM performs zero staging copies).
    """

    total_s: float
    packages: list[Package]
    unit_busy_s: dict[str, float]
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    @property
    def num_packages(self) -> int:
        """Number of packages this launch was served as."""
        return len(self.packages)


class LaunchHandle:
    """Future for one submitted co-execution.

    ``result()`` blocks until the launch's whole index space has been
    computed and collected, then returns the output container. ``stats``
    is populated before the future resolves.
    """

    def __init__(self, launch_id: int):
        self.launch_id = launch_id
        self.stats: Optional[LaunchStats] = None
        self._future: concurrent.futures.Future = concurrent.futures.Future()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until the launch completes and return its output.

        Args:
            timeout: max seconds to wait; ``None`` waits forever.

        Returns:
            The launch's output container (the ``out`` array passed to
            ``submit``, now fully written).

        Raises:
            LaunchWaitTimeout: the wait timed out while the launch is
                still in flight (never raised for a finished launch).
            BaseException: whatever the launch itself failed with — a
                kernel's own ``TimeoutError`` surfaces as-is and is
                therefore distinguishable from a wait timeout.
        """
        try:
            return self._future.result(timeout)
        except _TIMEOUT_BASES as e:
            if not self._future.done():
                raise LaunchWaitTimeout(
                    f"launch {self.launch_id} still in flight after "
                    f"{timeout}s") from None
            if self._future.exception() is e:
                raise    # the launch *failed* with a TimeoutError: keep it
            # the launch settled in the instant after the wait expired:
            # surface its real outcome, not the raced wait timeout
            return self._future.result()

    def exception(self, timeout: Optional[float] = None):
        """Block until the launch settles and return its exception.

        Args:
            timeout: max seconds to wait; ``None`` waits forever.

        Returns:
            The exception the launch failed with (``TimeoutError``
            included — returned, not raised), or ``None`` on success.

        Raises:
            LaunchWaitTimeout: the wait timed out while the launch is
                still in flight. This is the only exception this method
                raises, so raise-vs-return cleanly separates "gave up
                waiting" from "launch failed".
        """
        try:
            return self._future.exception(timeout)
        except _TIMEOUT_BASES:
            if not self._future.done():
                raise LaunchWaitTimeout(
                    f"launch {self.launch_id} still in flight after "
                    f"{timeout}s") from None
            # settled in the instant after the wait expired (a stored
            # TimeoutError is *returned* above, never raised, so the only
            # raise path here is the raced wait timeout)
            return self._future.exception()

    def done(self) -> bool:
        """Whether the launch has completed (successfully or not)."""
        return self._future.done()

    @property
    def packages(self) -> list[Package]:
        """Packages served for this launch (empty until completion)."""
        return self.stats.packages if self.stats is not None else []


class _Launch:
    """Engine-internal state of one in-flight co-execution."""

    __slots__ = ("id", "scheduler", "kernel", "inputs", "out", "adaptive",
                 "handle", "outstanding", "done_pkgs", "failed", "finalized",
                 "t_submit", "tenant", "weight", "fuse_key", "slots",
                 "members", "wfq_cost_scale", "plan")

    def __init__(self, launch_id: int, scheduler: Scheduler, kernel: Callable,
                 inputs: Sequence[np.ndarray], out: np.ndarray,
                 adaptive: bool):
        self.id = launch_id
        self.scheduler = scheduler
        self.kernel = kernel
        self.inputs = inputs
        self.out = out
        self.adaptive = adaptive
        self.handle = LaunchHandle(launch_id)
        self.outstanding = 0          # issued but not yet collected
        self.done_pkgs: list[Package] = []
        self.failed = False
        self.finalized = False
        self.t_submit = time.perf_counter()
        self.tenant = f"launch-{launch_id}"
        self.weight = 1.0
        self.fuse_key = None
        self.slots = 1
        self.members: Optional[list["_Launch"]] = None   # fused batches only
        self.wfq_cost_scale = 1      # work-items each package unit is worth
        self.plan = None             # LaunchPlan, set by the engine


class CoexecEngine:
    """Long-lived per-unit worker threads fed from a multi-tenant queue.

    The queueing discipline between ``submit`` and the workers is the
    :class:`~.admission.AdmissionController` (``engine.admission``): FIFO
    or weighted-fair, optional launch fusion, optional backpressure.
    """

    _UNSET = object()

    def __init__(self, units: Sequence[JaxUnit], *, spec=None,
                 memory: "MemoryModel" = _UNSET,
                 admission: "str | AdmissionConfig" = _UNSET,
                 fuse: Optional[bool] = None,
                 max_inflight: Optional[int] = None):
        """Build an engine over a fixed set of Coexecution Units.

        The canonical configuration is a declarative
        :class:`~repro.api.spec.CoexecSpec` (``spec=`` here, or
        :meth:`from_spec` to also build the units). The per-knob kwargs
        are the pre-spec surface: they still work but emit a
        :class:`DeprecationWarning`, and cannot be combined with ``spec``.

        Args:
            units: the Coexecution Units; one worker thread each.
            spec: a ``CoexecSpec`` supplying memory + admission config.
            memory: (deprecated) USM or BUFFERS collection semantics.
            admission: (deprecated) policy name (``"fifo"`` / ``"wfq"``)
                or a full :class:`~.admission.AdmissionConfig`.
            fuse: (deprecated) overrides the config's ``fuse`` flag.
            max_inflight: (deprecated) overrides the config's launch cap.

        Raises:
            ValueError: empty unit list, bad admission options, or
                ``spec`` combined with legacy kwargs.
        """
        if not units:
            raise ValueError("need at least one Coexecution Unit")
        self.units = list(units)
        legacy = {k: v for k, v in
                  (("memory", memory), ("admission", admission))
                  if v is not self._UNSET}
        if fuse is not None:
            legacy["fuse"] = fuse
        if max_inflight is not None:
            legacy["max_inflight"] = max_inflight
        if spec is not None and legacy:
            raise ValueError(
                f"pass either spec= or the legacy kwargs "
                f"{sorted(legacy)}, not both")
        if legacy:
            import warnings

            warnings.warn(
                f"CoexecEngine({', '.join(sorted(legacy))}=...) kwargs are "
                f"deprecated; build from a repro.api.CoexecSpec "
                f"(CoexecEngine.from_spec or spec=)",
                DeprecationWarning, stacklevel=2)
        if spec is not None:
            self.spec = spec
            self.memory = spec.memory_model()
            cfg = spec.admission_config()
        else:
            self.spec = None
            self.memory = memory if memory is not self._UNSET \
                else MemoryModel.USM
            cfg = coerce_admission(
                admission if admission is not self._UNSET else None)
            if fuse is not None:
                cfg = dataclasses.replace(cfg, fuse=bool(fuse))
            if max_inflight is not None:
                cfg = dataclasses.replace(
                    cfg, max_inflight=int(max_inflight))
        # the data plane implementing self.memory: USM = zero-copy shared
        # views + in-place collection, BUFFERS = per-package staging copies
        self.plane = make_plane(self.memory)
        self.admission = AdmissionController(
            len(self.units), cfg,
            fuse_materialize=self._materialize_fused,
            speed_refresh=self._refresh_speeds)
        self.board = SpeedBoard(len(self.units),
                                hints=[u.speed_hint for u in self.units])
        self._cv = threading.Condition()
        self._ids = itertools.count()
        self._threads: list[threading.Thread] = []
        self._fused_kernels: dict = {}
        self._stop = False
        self._started = False

    @classmethod
    def from_spec(cls, spec, *, units: Optional[Sequence[JaxUnit]] = None
                  ) -> "CoexecEngine":
        """Build an engine entirely from a :class:`CoexecSpec`.

        Args:
            spec: the declarative configuration; its ``units`` section is
                materialized unless ``units`` is supplied.
            units: pre-built Coexecution Units overriding the spec's
                ``units`` section.

        Returns:
            A constructed (not yet started) engine.
        """
        units = list(units) if units is not None else spec.build_units()
        return cls(units, spec=spec)

    # -- lifecycle ---------------------------------------------------------
    @property
    def running(self) -> bool:
        """Whether the engine has started and not yet shut down."""
        return self._started and not self._stop

    def start(self) -> "CoexecEngine":
        """Spawn the per-unit management threads (idempotent).

        Returns:
            The engine itself, for chaining.

        Raises:
            RuntimeError: if the engine was already shut down.
        """
        with self._cv:
            if self._started:
                if self._stop:
                    raise RuntimeError("engine was shut down; build a new one")
                return self
            self._started = True
            self._threads = [
                threading.Thread(target=self._worker, args=(i,),
                                 name=f"counit-{u.name}-{i}", daemon=True)
                for i, u in enumerate(self.units)]
        for t in self._threads:
            t.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting launches; drain in-flight ones, join workers.

        Args:
            wait: block until every worker thread has exited.
        """
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if wait:
            for t in self._threads:
                t.join()

    def __enter__(self) -> "CoexecEngine":
        """Start the engine on context entry."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Drain and shut the engine down on context exit."""
        self.shutdown()

    # -- submission --------------------------------------------------------
    def submit(self, scheduler: Scheduler, kernel: Callable,
               inputs: Sequence[np.ndarray], out: np.ndarray,
               *, adaptive: bool = True, tenant: Optional[str] = None,
               weight: float = 1.0, block: bool = True) -> LaunchHandle:
        """Enqueue one co-execution; returns immediately with its handle.

        The scheduler must be built for this engine's unit count. Packages
        are pulled on demand by whichever units go idle, interleaved with
        every other in-flight launch under the admission policy.

        Args:
            scheduler: fresh one-shot load balancer for this launch.
            kernel: a typed :class:`~.dataplane.CoexecKernel`, or a legacy
                positional closure ``fn(offset, *chunks) -> chunk_out``
                (treated as all-``SPLIT`` axis-0 arguments).
            inputs: full host input arrays (moved per the kernel's
                declared per-argument semantics and the engine's memory
                model; a typed kernel's trailing ``BROADCAST`` defaults
                may be omitted).
            out: preallocated output container the results land in.
            adaptive: refresh HGuided speeds from the engine's SpeedBoard.
            tenant: fairness flow this launch belongs to; defaults to a
                per-launch tenant (WFQ then means fair across launches).
            weight: relative WFQ share of the tenant (latest submit wins).
            block: when the engine is at ``max_inflight`` capacity, wait
                for a slot (True) or raise immediately (False).

        Returns:
            The launch's :class:`LaunchHandle`.

        Raises:
            ValueError: mismatched unit count, reused scheduler,
                non-positive weight, or inputs that do not satisfy the
                kernel's declared argument semantics.
            RuntimeError: engine not started, or shut down.
            AdmissionFull: at capacity and ``block=False``.
        """
        kernel = as_coexec_kernel(kernel, len(inputs))
        plan = self.plane.plan(kernel, inputs, out, scheduler.total)
        if scheduler.num_units != len(self.units):
            raise ValueError(
                f"scheduler built for {scheduler.num_units} units, engine "
                f"has {len(self.units)}")
        if scheduler.issued or scheduler.done():
            # A drained scheduler would hand out no packages, so the launch
            # could never reach its completion path (and would wedge
            # shutdown's drain). Schedulers are one-shot by design.
            raise ValueError("scheduler has already issued work; build a "
                             "fresh scheduler per launch")
        if weight <= 0:
            raise ValueError("weight must be positive")
        with self._cv:
            if self._stop:
                raise RuntimeError("engine is shut down")
            if not self._started:
                raise RuntimeError("engine not started; call start() first "
                                   "(or use it as a context manager)")
            while not self.admission.has_capacity():
                if not block:
                    raise AdmissionFull(
                        f"{self.admission.in_flight} launches in flight "
                        f"(max_inflight="
                        f"{self.admission.config.max_inflight})")
                self._cv.wait(timeout=0.05)
                if self._stop:
                    raise RuntimeError("engine is shut down")
            launch = _Launch(next(self._ids), scheduler, kernel, inputs, out,
                             adaptive)
            launch.plan = plan
            if tenant is not None:
                launch.tenant = str(tenant)
            launch.weight = float(weight)
            launch.fuse_key = self._fuse_key(scheduler, kernel, inputs, out)
            self.admission.admit(launch, time.perf_counter())
            self._cv.notify_all()
        return launch.handle

    # -- fusion ------------------------------------------------------------
    def _fuse_key(self, scheduler: Scheduler, kernel: Callable,
                  inputs: Sequence[np.ndarray], out: np.ndarray):
        """Coalescing key, or None when this launch is not fusion-eligible.

        Eligible launches are small (≤ ``fuse_threshold`` items) with every
        input and the output indexed by the full index space on axis 0 —
        the shape contract that makes member stacking a pure reshape.
        Typed kernels with broadcast args, halos or non-zero split axes
        are ineligible (their operands do not stack along the member axis).
        """
        cfg = self.admission.config
        if not cfg.fuse:
            return None
        if isinstance(kernel, CoexecKernel) and not kernel.all_split:
            return None
        total = scheduler.total
        if total > cfg.fuse_threshold:
            return None
        arrs = [np.asarray(a) for a in inputs]
        if any(a.ndim < 1 or a.shape[0] != total for a in arrs):
            return None
        if out.shape[0] != total:
            return None
        return (kernel, total,
                tuple((a.shape, str(a.dtype)) for a in arrs),
                tuple(out.shape), str(out.dtype))

    def _fused_kernel(self, fn: Callable) -> Callable:
        """Vmapped wrapper computing whole members at member-local offset 0.

        A fused package covers whole members, so each member's chunk spans
        its entire index space and the correct kernel offset is 0 — the
        wrapper maps the original kernel over the member axis, which keeps
        index-dependent kernels (Mandelbrot coordinates etc.) bitwise
        faithful to their unfused execution. Cached per kernel so repeated
        fusion reuses one jit entry per batch shape.
        """
        got = self._fused_kernels.get(fn)
        if got is None:
            import jax
            import jax.numpy as jnp

            def fused(offset, *chunks, _fn=fn):
                member = lambda *cs: _fn(jnp.int32(0), *cs)   # noqa: E731
                return jax.vmap(member)(*chunks)

            self._fused_kernels[fn] = got = fused
        return got

    def _materialize_fused(self, members: list[_Launch]) -> _Launch:
        """Coalesce staged member launches into one fused launch.

        Member inputs are stacked along a new leading *member* axis; the
        fused index space is the member count, split across units by a
        Dynamic scheduler with one package per unit, so N small requests
        cost ~one dispatch per unit.
        """
        first = members[0]
        n_inputs = len(first.inputs)
        inputs = [np.stack([np.asarray(m.inputs[j]) for m in members])
                  for j in range(n_inputs)]
        out = np.zeros((len(members), *first.out.shape), first.out.dtype)
        sched = DynamicScheduler(len(members), len(self.units),
                                 num_packages=min(len(members),
                                                  len(self.units)))
        fused = _Launch(next(self._ids), sched,
                        self._fused_kernel(first.kernel), inputs, out,
                        adaptive=False)
        fused.plan = self.plane.plan(
            as_coexec_kernel(fused.kernel, len(inputs)), inputs, out,
            sched.total)
        fused.tenant = f"fused-{fused.id}"
        fused.weight = sum(m.weight for m in members)
        fused.members = list(members)
        # the fused scheduler's index space is *members*; WFQ credit is
        # accounted in work-items, so each member unit costs its whole
        # index space (keeps engine fairness on the sim's scale)
        fused.wfq_cost_scale = first.scheduler.total
        return fused

    # -- worker loop -------------------------------------------------------
    def _refresh_speeds(self, launch: _Launch) -> None:
        """Feed SpeedBoard throughput into an adaptive launch's scheduler."""
        if launch.adaptive and isinstance(launch.scheduler, HGuidedScheduler):
            for i, s in enumerate(self.board.speeds()):
                launch.scheduler.update_speed(i, s)

    def _next_work(self, unit_idx: int) -> Optional[tuple[_Launch, Package]]:
        """Pull the next package for `unit_idx` (caller holds the cv)."""
        self.admission.flush(time.perf_counter(), force=self._stop)
        got = self.admission.next_work(unit_idx)
        if got is not None:
            got[0].outstanding += 1
        return got

    def _finalize_locked(self, launch: _Launch) -> None:
        """Resolve a launch whose last package was collected (cv held)."""
        if launch.finalized:
            return
        launch.finalized = True
        self.admission.discard(launch)
        try:
            validate_cover(launch.done_pkgs, launch.scheduler.total)
        except BaseException as e:
            for h in self._handles_of(launch):
                h._future.set_exception(e)
            return
        if launch.members is not None:
            self._demux_fused_locked(launch)
            return
        busy: dict[str, float] = {u.name: 0.0 for u in self.units}
        for p in launch.done_pkgs:
            busy[self.units[p.unit].name] += max(p.t_complete - p.t_issue, 0.0)
        launch.handle.stats = LaunchStats(
            total_s=time.perf_counter() - launch.t_submit,
            packages=list(launch.done_pkgs),
            unit_busy_s=busy,
            data=launch.plan.counters.snapshot())
        launch.handle._future.set_result(launch.out)

    def _demux_fused_locked(self, fused: _Launch) -> None:
        """Scatter a completed fused batch back to its member launches.

        Each member gets its output row copied into its own container and
        a synthesized single-package stats record timed by the shared
        dispatch that computed it.
        """
        now = time.perf_counter()
        pkgs = sorted(fused.done_pkgs, key=lambda p: p.offset)
        # the batch's data-plane accounting, attributed in even integer
        # shares so per-member stats still *sum* to the real copy counts
        data_shares = fused.plan.counters.snapshot().split(len(fused.members))
        for i, m in enumerate(fused.members):
            cover = next(p for p in pkgs
                         if p.offset <= i < p.offset + p.size)
            mp = Package(rng=Range(0, m.scheduler.total), seq=0,
                         unit=cover.unit)
            mp.t_issue, mp.t_launch = cover.t_issue, cover.t_launch
            mp.t_complete, mp.t_collected = cover.t_complete, cover.t_collected
            busy = {u.name: 0.0 for u in self.units}
            busy[self.units[cover.unit].name] = max(
                cover.t_complete - cover.t_issue, 0.0) / cover.size
            np.copyto(m.out, fused.out[i])
            m.handle.stats = LaunchStats(total_s=now - m.t_submit,
                                         packages=[mp], unit_busy_s=busy,
                                         data=data_shares[i])
            m.handle._future.set_result(m.out)

    def _handles_of(self, launch: _Launch) -> list[LaunchHandle]:
        """Handles resolved by this entry (members for a fused batch)."""
        if launch.members is not None:
            return [m.handle for m in launch.members]
        return [launch.handle]

    def _fail_locked(self, launch: _Launch, err: BaseException) -> None:
        """Abort a launch on its first package error (cv held)."""
        if launch.failed or launch.finalized:
            return
        launch.failed = True
        launch.finalized = True
        self.admission.discard(launch)
        for h in self._handles_of(launch):
            h._future.set_exception(err)

    def _worker(self, unit_idx: int) -> None:
        """One Coexecution Unit's management loop (runs on its own thread)."""
        unit = self.units[unit_idx]
        while True:
            with self._cv:
                work = self._next_work(unit_idx)
                while work is None:
                    if self._stop and self.admission.drained():
                        return
                    # Park until a submit / completion / shutdown wakes us
                    # (or a staged fusion group ripens). The timeout is
                    # also a safety net against lost wakeups.
                    ripen = self.admission.next_ripen_in(time.perf_counter())
                    wait = 0.1 if ripen is None else min(0.1,
                                                         max(ripen, 1e-4))
                    self._cv.wait(timeout=wait)
                    work = self._next_work(unit_idx)
            launch, pkg = work
            pkg.t_issue = time.perf_counter()
            try:
                # the engine's data plane stages inputs per the memory
                # model (USM: zero-copy shared views; BUFFERS: per-package
                # device_put + copy-back), dispatches on the unit, and
                # lands the chunk in the launch's output container.
                self.plane.execute(unit, launch.plan, pkg)
            except BaseException as e:
                with self._cv:
                    launch.outstanding -= 1
                    self._fail_locked(launch, e)
                    self._cv.notify_all()
                continue
            self.board.record(unit_idx, pkg.size,
                              max(pkg.t_complete - pkg.t_issue, 1e-9))
            with self._cv:
                launch.outstanding -= 1
                launch.done_pkgs.append(pkg)
                if (not launch.failed and launch.scheduler.done()
                        and launch.outstanding == 0):
                    self._finalize_locked(launch)
                self._cv.notify_all()
