"""Persistent co-execution engine (EngineCL-style, arXiv:1805.02755).

The paper's antecedent EngineCL shows that co-execution management overhead
stays under 1% only when the runtime is a *persistent engine*: worker threads
are created once and fed work, instead of being spawned and joined per
launch. This module provides that engine for the Coexecutor Runtime:

* one long-lived management thread per Coexecution Unit, started by
  :meth:`CoexecEngine.start` and parked on a condition variable when idle;
* a multi-tenant launch queue — any number of callers may
  :meth:`CoexecEngine.submit` co-executions concurrently; packages from all
  in-flight launches interleave on the same units under the engine's
  admission policy (FIFO by default — the Commander protocol of Fig. 2a —
  or weighted-fair queueing across tenants, optionally with preemptive
  pull-capping);
* the shared control plane of :class:`~repro.core.exec.ExecutionLoop`
  between ``submit`` and the workers: the exact same loop object that
  drives the discrete-event simulator decides admission pulls, launch
  fusion + bitwise de-mux, finalization and counter attribution here —
  this module contributes only the :class:`RealBackend` execution
  substrate (threads, wall clock, data-plane dispatch on
  :class:`~repro.core.units.JaxUnit`\\ s);
* per-launch isolation — each launch owns its scheduler, output container,
  package log and :class:`LaunchStats`; completion is surfaced through a
  :class:`LaunchHandle` future, so independent callers never observe each
  other's state;
* a persistent :class:`~.profiler.SpeedBoard` — throughput measured on
  earlier launches seeds the adaptive (HGuided) speed refinement of later
  ones, which a per-launch thread pool could never do;
* a per-memory-model data plane (:mod:`~repro.core.dataplane`) between
  the workers and the units: the spec's ``MemorySpec`` selects zero-copy
  unified-shared-memory movement or per-package staged buffers, with
  copy/dispatch counters surfaced in each launch's :class:`LaunchStats`.

Configuration is declarative only: build a
:class:`~repro.api.spec.CoexecSpec` (the kwarg-era ``memory=`` /
``admission=`` / ``fuse=`` / ``max_inflight=`` constructor surface was
removed when its deprecation window closed — see docs/api.md).

Lifecycle::

    engine = CoexecEngine.from_spec(spec)       # or CoexecEngine(units,
    engine.start()                              #        spec=spec)
    h1 = engine.submit(sched1, kernel_a, inputs_a, out_a, tenant="u1")
    h2 = engine.submit(sched2, kernel_b, inputs_b, out_b, tenant="u2")
    out_a = h1.result(); out_b = h2.result()
    engine.shutdown()            # drains in-flight launches, joins threads

or, scoped::

    with CoexecEngine(units) as engine:
        out = engine.submit(sched, kernel, inputs, out).result()
"""
from __future__ import annotations

import collections
import concurrent.futures
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .admission import (AdmissionConfig, AdmissionController, AdmissionFull,
                        LaunchShed, fusion_bucket)
from .dataplane import (CoexecKernel, DataPlaneCounters, as_coexec_kernel,
                        make_plane)
from .exec import Backend, ExecutionLoop, LaunchState, LaunchStats
from .memory import MemoryModel
from .package import Package
from .profiler import SpeedBoard
from .scheduler import DynamicScheduler, HGuidedScheduler, Scheduler
from .units import JaxUnit

# Pre-3.11 `concurrent.futures.TimeoutError` is not the builtin; subclass
# whichever classes exist so `except TimeoutError` catches both flavors.
_TIMEOUT_BASES = ((TimeoutError,)
                  if concurrent.futures.TimeoutError is TimeoutError
                  else (concurrent.futures.TimeoutError, TimeoutError))


class LaunchWaitTimeout(*_TIMEOUT_BASES):
    """The *wait* on a LaunchHandle timed out; the launch itself is fine.

    Distinguishes "I gave up waiting" from "the launch failed": a launch
    whose kernel raised ``TimeoutError`` surfaces that original exception
    from :meth:`LaunchHandle.result` / returns it from
    :meth:`LaunchHandle.exception`, never this class. Subclasses
    ``TimeoutError`` (both flavors), so broad handlers keep working.
    """


class LaunchHandle:
    """Future for one submitted co-execution.

    ``result()`` blocks until the launch's whole index space has been
    computed and collected, then returns the output container. ``stats``
    is populated before the future resolves.
    """

    def __init__(self, launch_id: int):
        self.launch_id = launch_id
        self.stats: Optional[LaunchStats] = None
        self._future: concurrent.futures.Future = concurrent.futures.Future()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until the launch completes and return its output.

        Args:
            timeout: max seconds to wait; ``None`` waits forever.

        Returns:
            The launch's output container (the ``out`` array passed to
            ``submit``, now fully written).

        Raises:
            LaunchWaitTimeout: the wait timed out while the launch is
                still in flight (never raised for a finished launch).
            BaseException: whatever the launch itself failed with — a
                kernel's own ``TimeoutError`` surfaces as-is and is
                therefore distinguishable from a wait timeout.
        """
        try:
            return self._future.result(timeout)
        except _TIMEOUT_BASES as e:
            if not self._future.done():
                raise LaunchWaitTimeout(
                    f"launch {self.launch_id} still in flight after "
                    f"{timeout}s") from None
            if self._future.exception() is e:
                raise    # the launch *failed* with a TimeoutError: keep it
            # the launch settled in the instant after the wait expired:
            # surface its real outcome, not the raced wait timeout
            return self._future.result()

    def exception(self, timeout: Optional[float] = None):
        """Block until the launch settles and return its exception.

        Args:
            timeout: max seconds to wait; ``None`` waits forever.

        Returns:
            The exception the launch failed with (``TimeoutError``
            included — returned, not raised), or ``None`` on success.

        Raises:
            LaunchWaitTimeout: the wait timed out while the launch is
                still in flight. This is the only exception this method
                raises, so raise-vs-return cleanly separates "gave up
                waiting" from "launch failed".
        """
        try:
            return self._future.exception(timeout)
        except _TIMEOUT_BASES:
            if not self._future.done():
                raise LaunchWaitTimeout(
                    f"launch {self.launch_id} still in flight after "
                    f"{timeout}s") from None
            # settled in the instant after the wait expired (a stored
            # TimeoutError is *returned* above, never raised, so the only
            # raise path here is the raced wait timeout)
            return self._future.exception()

    def done(self) -> bool:
        """Whether the launch has completed (successfully or not)."""
        return self._future.done()

    @property
    def packages(self) -> list[Package]:
        """Packages served for this launch (empty until completion)."""
        return self.stats.packages if self.stats is not None else []


class _Launch(LaunchState):
    """Engine payload of one in-flight co-execution (real arrays, future).

    The control-plane fields live on :class:`~repro.core.exec.LaunchState`
    (the shared loop reads/writes only those); this subclass adds what
    the :class:`RealBackend` needs to actually run packages.
    """

    __slots__ = ("kernel", "inputs", "out", "adaptive", "handle", "plan")

    def __init__(self, launch_id: int, scheduler: Scheduler, kernel: Callable,
                 inputs: Sequence[np.ndarray], out: np.ndarray,
                 adaptive: bool):
        super().__init__(launch_id, scheduler,
                         t_submit=time.perf_counter())
        self.kernel = kernel
        self.inputs = inputs
        self.out = out
        self.adaptive = adaptive
        self.handle = LaunchHandle(launch_id)
        self.plan = None             # LaunchPlan, set by the engine


def _fuse_key(config: AdmissionConfig, scheduler: Scheduler,
              kernel: Callable, inputs: Sequence[np.ndarray],
              out: np.ndarray):
    """Coalescing key, or None when this launch is not fusion-eligible.

    Eligible launches are small (≤ ``fuse_threshold`` items) with every
    input and the output indexed by the full index space on axis 0 —
    the shape contract that makes member stacking a pure reshape.
    Typed kernels with broadcast args, halos or non-zero split axes
    are ineligible (their operands do not stack along the member axis).

    With ``config.fuse_buckets`` the key holds the power-of-2 size
    bucket plus the per-array *trailing* shapes instead of the exact
    shapes, so near-identical launches coalesce: members pad up to the
    bucket along axis 0 in :meth:`RealBackend.fuse_payload` and de-mux
    back to their exact extents in :meth:`RealBackend.commit_member`.
    """
    if not config.fuse:
        return None
    if isinstance(kernel, CoexecKernel) and not kernel.all_split:
        return None
    total = scheduler.total
    if total > config.fuse_threshold:
        return None
    arrs = [np.asarray(a) for a in inputs]
    if any(a.ndim < 1 or a.shape[0] != total for a in arrs):
        return None
    if out.shape[0] != total:
        return None
    if config.fuse_buckets:
        return (kernel, "bucket", fusion_bucket(total),
                tuple((a.shape[1:], str(a.dtype)) for a in arrs),
                tuple(out.shape[1:]), str(out.dtype))
    return (kernel, total,
            tuple((a.shape, str(a.dtype)) for a in arrs),
            tuple(out.shape), str(out.dtype))


class RealBackend(Backend):
    """Wall-clock JAX execution substrate for the shared control plane.

    Supplies what :class:`~repro.core.exec.ExecutionLoop` cannot decide —
    real time, real dispatch through the configured data plane, member
    stacking / vmapping for fused batches, and future resolution — while
    every scheduling decision stays in the loop. The engine's worker
    threads call :meth:`dispatch` outside the engine lock; everything
    else runs caller-serialized like the loop itself.
    """

    def __init__(self, units: Sequence[JaxUnit], plane, *,
                 board: Optional[SpeedBoard] = None,
                 condition: Optional[threading.Condition] = None):
        self.units = list(units)
        self.plane = plane
        self.board = board
        self.condition = condition
        self.loop = None        # set by the engine for elastic membership
        self._fused_kernels: dict = {}  # guarded-by: condition

    # -- substrate contract -------------------------------------------------
    def now(self) -> float:
        """Wall-clock seconds (``time.perf_counter``)."""
        return time.perf_counter()

    def dispatch(self, unit: int, launch: _Launch, pkg: Package) -> None:
        """Run one package through the data plane on a real unit.

        Args:
            unit: index of the serving Coexecution Unit.
            launch: the owning launch (its ``plan`` carries the bound
                arrays and counters).
            pkg: the package to execute; the plane stamps
                ``t_complete``/``t_collected``.
        """
        if self.loop is not None and unit in self.loop.dead_units:
            # the unit was declared dead after this worker pulled: the
            # package is already disowned and its range re-issued, so
            # executing it would double-compute (and double-count) —
            # drop it; the loop's ledger drops the zombie completion too
            return
        self.plane.execute(self.units[unit], launch.plan, pkg)
        if self.board is not None:
            self.board.record(unit, pkg.size,
                              max(pkg.t_complete - pkg.t_issue, 1e-9))

    # -- pipelined dispatch (phases of `dispatch`, overlappable) ------------
    def begin(self, unit: int, launch: _Launch, pkg: Package):
        """Stage + issue one package without waiting for the device.

        The first two data-plane phases of :meth:`dispatch`: materialize
        the package's inputs and launch the kernel asynchronously. The
        worker may then pull and stage further packages while this one
        computes, up to its pipeline depth.

        Args:
            unit: index of the serving Coexecution Unit.
            launch: the owning launch.
            pkg: the package to put in flight.

        Returns:
            The in-flight device output handle for :meth:`finish`, or
            ``None`` when the unit is already dead (the package was
            disowned and re-issued; its completion is a zombie).
        """
        if self.loop is not None and unit in self.loop.dead_units:
            return None
        u = self.units[unit]
        args = self.plane.stage(u, launch.plan, pkg)
        return self.plane.issue(u, launch.plan, pkg, args)

    def finish(self, unit: int, launch: _Launch, pkg: Package, out_dev,
               *, busy_floor: float = 0.0) -> None:
        """Await and collect one in-flight package (phase 3).

        Blocks on the device result, lands it in the launch's output
        container and feeds the SpeedBoard. ``busy_floor`` is the
        previous package's completion time on this unit: with several
        packages in flight their issue→complete spans overlap, so busy
        time and throughput are measured from whichever is later —
        issue or the moment the device actually became free.

        Args:
            unit: index of the serving Coexecution Unit.
            launch: the owning launch.
            pkg: the package to complete (in issue order per unit).
            out_dev: handle from :meth:`begin` (``None`` = dropped).
            busy_floor: completion time of the unit's previous package.
        """
        if out_dev is None:
            return
        self.plane.complete(self.units[unit], launch.plan, pkg, out_dev,
                            busy_floor=busy_floor)
        if self.board is not None:
            self.board.record(
                unit, pkg.size,
                max(pkg.t_complete - max(pkg.t_issue, busy_floor), 1e-9))

    def wait_next_event(self, timeout: Optional[float] = None) -> None:
        """Park the calling worker on the engine's condition variable.

        Args:
            timeout: max seconds to sleep, or ``None`` to wait for the
                next notify (every state change — submit, completion,
                kill/join, shutdown — notifies, so no poll is needed).
                The caller must hold the condition.
        """
        if self.condition is not None:
            self.condition.wait(timeout=timeout)

    # -- payload hooks ------------------------------------------------------
    def refresh_speeds(self, launch: _Launch) -> None:
        """Feed SpeedBoard throughput into an adaptive launch's scheduler."""
        if (self.board is not None and getattr(launch, "adaptive", False)
                and isinstance(launch.scheduler, HGuidedScheduler)):
            for i, s in enumerate(self.board.speeds()):
                launch.scheduler.update_speed(i, s)

    def _fused_kernel(self, fn: Callable) -> Callable:  # guarded-by: condition
        """Vmapped wrapper computing whole members at member-local offset 0.

        A fused package covers whole members, so each member's chunk spans
        its entire index space and the correct kernel offset is 0 — the
        wrapper maps the original kernel over the member axis, which keeps
        index-dependent kernels (Mandelbrot coordinates etc.) bitwise
        faithful to their unfused execution. Cached per kernel so repeated
        fusion reuses one jit entry per batch shape.
        """
        got = self._fused_kernels.get(fn)
        if got is None:
            import jax
            import jax.numpy as jnp

            def fused(offset, *chunks, _fn=fn):
                member = lambda *cs: _fn(jnp.int32(0), *cs)   # noqa: E731
                return jax.vmap(member)(*chunks)

            self._fused_kernels[fn] = got = fused
        return got

    def fuse_payload(self, members: list[_Launch],
                     launch_id: int) -> _Launch:
        """Stack member inputs along a new leading *member* axis.

        The fused index space is the member count, split across units by
        a Dynamic scheduler with one package per unit, so N small
        requests cost ~one dispatch per unit. One scheduler unit is one
        member, so ``wfq_cost_scale`` converts credit back to work-items.

        Args:
            members: the staged same-shaped launches to coalesce.
            launch_id: id assigned by the loop.

        Returns:
            The fused engine launch (tenant/weight set by the loop).
        """
        first = members[0]
        n_inputs = len(first.inputs)
        # bucketed members pad along axis 0 up to the shared power-of-2
        # bucket; exact-shape fusion has bucket == total (no padding)
        bucket = first.fuse_bucket or max(m.scheduler.total for m in members)

        def padded(a: np.ndarray) -> np.ndarray:
            a = np.asarray(a)
            if a.shape[0] == bucket:
                return a
            pad = [(0, bucket - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
            return np.pad(a, pad)

        inputs = [np.stack([padded(m.inputs[j]) for m in members])
                  for j in range(n_inputs)]
        out = np.zeros((len(members), bucket, *first.out.shape[1:]),
                       first.out.dtype)
        n_units = len(self.units)
        sched = DynamicScheduler(len(members), n_units,
                                 num_packages=min(len(members), n_units))
        fused = _Launch(launch_id, sched, self._fused_kernel(first.kernel),
                        inputs, out, adaptive=False)
        fused.plan = self.plane.plan(
            as_coexec_kernel(fused.kernel, len(inputs)), inputs, out,
            sched.total)
        # the fused scheduler's index space is *members*; WFQ credit is
        # accounted in work-items, so each member unit costs its whole
        # (bucket-padded) index space — keeps engine fairness on the
        # sim's scale, identically for exact and bucketed fusion
        fused.wfq_cost_scale = bucket
        fused.fuse_bucket = bucket
        fused.member_span = 1
        return fused

    def launch_counters(self, launch: _Launch) -> DataPlaneCounters:
        """The launch's data-plane accounting (from its plan)."""
        return launch.plan.counters.snapshot()

    def commit_member(self, fused: _Launch, member: _Launch, index: int,
                      cover: Package) -> None:
        """Copy one member's output row out of the fused batch result.

        Bucketed members copy only their own extent — the bucket's pad
        rows are computed (on padded zero inputs) but never land.
        """
        np.copyto(member.out, fused.out[index][:member.out.shape[0]])

    def deliver(self, launch: _Launch) -> None:
        """Resolve the launch's future with its (now written) output."""
        launch.handle.stats = launch.stats
        launch.handle._future.set_result(launch.out)

    def fail(self, launch: _Launch, err: BaseException) -> None:
        """Resolve the launch's future with its failure."""
        launch.handle._future.set_exception(err)


class CoexecEngine:
    """Long-lived per-unit worker threads fed from a multi-tenant queue.

    The queueing discipline between ``submit`` and the workers is the
    shared :class:`~repro.core.exec.ExecutionLoop` (``engine.loop``) and
    its :class:`~.admission.AdmissionController` (``engine.admission``):
    FIFO or weighted-fair (optionally preemptive), optional launch
    fusion, optional backpressure — the exact same control plane the
    discrete-event simulator drives.
    """

    def __init__(self, units: Sequence[JaxUnit], *, spec=None):
        """Build an engine over a fixed set of Coexecution Units.

        Configuration is a declarative
        :class:`~repro.api.spec.CoexecSpec` (``spec=`` here, or
        :meth:`from_spec` to also build the units); with no spec the
        engine runs USM memory and plain FIFO admission.

        Args:
            units: the Coexecution Units; one worker thread each.
            spec: a ``CoexecSpec`` supplying memory + admission config.

        Raises:
            ValueError: empty unit list or invalid spec sections.
        """
        if not units:
            raise ValueError("need at least one Coexecution Unit")
        self.units = list(units)
        if spec is not None:
            self.spec = spec
            self.memory = spec.memory_model()
            cfg = spec.admission_config()
        else:
            self.spec = None
            self.memory = MemoryModel.USM
            cfg = AdmissionConfig()
        # the data plane implementing self.memory: USM = zero-copy shared
        # views + in-place collection, BUFFERS = per-package staging copies
        self.plane = make_plane(self.memory)
        # packages a unit may have in flight: 1 = serial stage/compute/
        # collect; >= 2 overlaps staging/collection with device compute
        self.pipeline_depth = max(
            1, int(spec.units.pipeline_depth)) if spec is not None else 1
        self.board = SpeedBoard(len(self.units),
                                hints=[u.speed_hint for u in self.units])
        self._cv = threading.Condition()
        self.backend = RealBackend(self.units, self.plane, board=self.board,
                                   condition=self._cv)
        self.loop = ExecutionLoop(self.backend,
                                  [u.name for u in self.units], cfg)
        self.backend.loop = self.loop   # dead-unit dispatch guard
        self._threads: list[threading.Thread] = []  # guarded-by: _cv
        self._stop = False  # guarded-by: _cv
        self._started = False  # guarded-by: _cv

    @classmethod
    def from_spec(cls, spec, *, units: Optional[Sequence[JaxUnit]] = None
                  ) -> "CoexecEngine":
        """Build an engine entirely from a :class:`CoexecSpec`.

        Args:
            spec: the declarative configuration; its ``units`` section is
                materialized unless ``units`` is supplied.
            units: pre-built Coexecution Units overriding the spec's
                ``units`` section.

        Returns:
            A constructed (not yet started) engine.
        """
        units = list(units) if units is not None else spec.build_units()
        return cls(units, spec=spec)

    @property
    def admission(self) -> AdmissionController:
        """The shared loop's admission controller (policy + counters)."""
        return self.loop.admission

    # -- lifecycle ---------------------------------------------------------
    @property
    def running(self) -> bool:
        """Whether the engine has started and not yet shut down."""
        with self._cv:
            return self._started and not self._stop

    def start(self) -> "CoexecEngine":
        """Spawn the per-unit management threads (idempotent).

        Returns:
            The engine itself, for chaining.

        Raises:
            RuntimeError: if the engine was already shut down.
        """
        with self._cv:
            if self._started:
                if self._stop:
                    raise RuntimeError("engine was shut down; build a new one")
                return self
            self._started = True
            self._threads = threads = [
                threading.Thread(target=self._worker, args=(i,),
                                 name=f"counit-{u.name}-{i}", daemon=True)
                for i, u in enumerate(self.units)]
        for t in threads:
            t.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting launches; drain in-flight ones, join workers.

        Args:
            wait: block until every worker thread has exited.
        """
        with self._cv:
            self._stop = True
            self._cv.notify_all()
            threads = list(self._threads)
        if wait:
            for t in threads:
                t.join()

    def kill_unit(self, unit_idx: int) -> int:
        """Declare one Coexecution Unit dead; its work re-issues exactly.

        The unit's in-flight packages are disowned and their exact ranges
        re-emitted to the surviving units (the loop's ownership ledger
        guarantees exact-once accounting), per-unit scheduler
        reservations are harvested, and the unit's worker thread parks —
        a completion it races in is dropped as a zombie. Pending
        ``LaunchHandle`` objects resolve normally once survivors finish the
        re-issued cover; no handle ever spuriously times out or errors
        because a unit died.

        Args:
            unit_idx: index of the unit to fail.

        Returns:
            Number of in-flight/reserved ranges queued for re-issue.

        Raises:
            RuntimeError: killing the last live unit (nothing could
                serve the re-issued work).
        """
        with self._cv:
            live = len(self.units) - len(self.loop.dead_units)
            if unit_idx not in self.loop.dead_units and live <= 1:
                raise RuntimeError("cannot kill the last live unit")
            moved = self.loop.unit_lost(unit_idx)
            self._cv.notify_all()
        return moved

    def join_unit(self, unit_idx: int) -> None:
        """Bring a previously killed unit back into the pool.

        Args:
            unit_idx: index of a provisioned (possibly dead) unit.
        """
        with self._cv:
            self.loop.unit_joined(unit_idx,
                                  speed=self.units[unit_idx].speed_hint)
            self._cv.notify_all()

    def __enter__(self) -> "CoexecEngine":
        """Start the engine on context entry."""
        return self.start()

    def __exit__(self, *exc) -> None:
        """Drain and shut the engine down on context exit."""
        self.shutdown()

    # -- submission --------------------------------------------------------
    def submit(self, scheduler: Scheduler, kernel: Callable,
               inputs: Sequence[np.ndarray], out: np.ndarray,
               *, adaptive: bool = True, tenant: Optional[str] = None,
               weight: float = 1.0, block: bool = True,
               deadline_s: Optional[float] = None) -> LaunchHandle:
        """Enqueue one co-execution; returns immediately with its handle.

        The scheduler must be built for this engine's unit count. Packages
        are pulled on demand by whichever units go idle, interleaved with
        every other in-flight launch under the admission policy.

        Args:
            scheduler: fresh one-shot load balancer for this launch.
            kernel: a typed :class:`~.dataplane.CoexecKernel`, or a legacy
                positional closure ``fn(offset, *chunks) -> chunk_out``
                (treated as all-``SPLIT`` axis-0 arguments).
            inputs: full host input arrays (moved per the kernel's
                declared per-argument semantics and the engine's memory
                model; a typed kernel's trailing ``BROADCAST`` defaults
                may be omitted).
            out: preallocated output container the results land in.
            adaptive: refresh HGuided speeds from the engine's SpeedBoard.
            tenant: fairness flow this launch belongs to; defaults to a
                per-launch tenant (WFQ then means fair across launches).
            weight: relative WFQ share of the tenant (latest submit wins).
            block: when the engine is at ``max_inflight`` capacity, wait
                for a slot (True) or raise immediately (False).
            deadline_s: relative SLO deadline in seconds from submission;
                ``None`` falls back to the admission config's ``slo_ms``
                default (when set). Under ``shed=True`` a launch whose
                estimated finish misses this deadline is rejected — its
                handle resolves *immediately* with
                :class:`~repro.core.admission.LaunchShed`, on both the
                blocking and non-blocking submit paths.

        Returns:
            The launch's :class:`LaunchHandle`.

        Raises:
            ValueError: mismatched unit count, reused scheduler,
                non-positive weight, or inputs that do not satisfy the
                kernel's declared argument semantics.
            RuntimeError: engine not started, or shut down.
            AdmissionFull: at capacity and ``block=False``.
        """
        kernel = as_coexec_kernel(kernel, len(inputs))
        plan = self.plane.plan(kernel, inputs, out, scheduler.total)
        # compile every package bucket now (outside the engine lock, and
        # memoized per unit/shape) so no first dispatch charges JIT
        # compile time to a unit's busy clock — compile time would
        # otherwise poison the adaptive speed estimates
        self.plane.prewarm(self.units, plan,
                           getattr(scheduler, "granularity", 1))
        if scheduler.num_units != len(self.units):
            raise ValueError(
                f"scheduler built for {scheduler.num_units} units, engine "
                f"has {len(self.units)}")
        if scheduler.issued or scheduler.done():
            # A drained scheduler would hand out no packages, so the launch
            # could never reach its completion path (and would wedge
            # shutdown's drain). Schedulers are one-shot by design.
            raise ValueError("scheduler has already issued work; build a "
                             "fresh scheduler per launch")
        if weight <= 0:
            raise ValueError("weight must be positive")
        with self._cv:
            if self._stop:
                raise RuntimeError("engine is shut down")
            if not self._started:
                raise RuntimeError("engine not started; call start() first "
                                   "(or use it as a context manager)")
            while not self.admission.has_capacity():
                if not block:
                    raise AdmissionFull(
                        f"{self.admission.in_flight} launches in flight "
                        f"(max_inflight="
                        f"{self.admission.config.max_inflight})")
                self._cv.wait(timeout=0.05)
                if self._stop:
                    raise RuntimeError("engine is shut down")
            launch = _Launch(self.loop.next_id(), scheduler, kernel, inputs,
                             out, adaptive)
            launch.plan = plan
            if tenant is not None:
                launch.tenant = str(tenant)
            launch.weight = float(weight)
            if deadline_s is not None:
                launch.deadline = launch.t_submit + float(deadline_s)
            launch.fuse_key = _fuse_key(self.admission.config, scheduler,
                                        kernel, inputs, out)
            if launch.fuse_key is not None \
                    and self.admission.config.fuse_buckets:
                launch.fuse_bucket = fusion_bucket(scheduler.total)
            if not self.loop.offer(launch, now=launch.t_submit):
                # shed: resolve the handle before returning so result()
                # raises LaunchShed immediately instead of blocking until
                # a wait timeout (the future carries a pre-set exception)
                self.backend.fail(launch, LaunchShed(
                    f"launch {launch.id} shed: estimated finish misses its "
                    f"deadline under the offered load"))
                return launch.handle
            self._cv.notify_all()
        return launch.handle

    # -- worker loop -------------------------------------------------------
    def _retire_oldest(self, unit_idx: int, inflight: collections.deque,
                       busy_floor: float) -> float:
        """Complete the unit's oldest in-flight package, in issue order.

        Blocks on the device result outside the lock, then re-enters the
        loop under ``_cv`` to record the completion — zombies (packages
        disowned by ``unit_lost`` mid-flight) are dropped by the loop's
        ownership ledger exactly like the serial path's.

        Args:
            unit_idx: the worker's unit index.
            inflight: the worker's in-flight FIFO (oldest first).
            busy_floor: completion time of the previous package.

        Returns:
            The new busy floor (this package's completion time).
        """
        launch, pkg, out_dev = inflight.popleft()
        try:
            self.backend.finish(unit_idx, launch, pkg, out_dev,
                                busy_floor=busy_floor)
        except BaseException as e:
            with self._cv:
                self.loop.complete(launch, pkg, error=e)
                self._cv.notify_all()
            return busy_floor
        with self._cv:
            self.loop.complete(launch, pkg)
            self._cv.notify_all()
        return pkg.t_complete or busy_floor

    def _worker(self, unit_idx: int) -> None:
        """One Coexecution Unit's management thread, pipelined per unit.

        Pull → stage+issue → complete, with up to ``pipeline_depth``
        packages in flight: while package *k* computes on the device the
        worker pulls and stages *k+1* and collects *k-1*, so the device
        no longer idles during host-side pull/stage/collect (and the
        host no longer idles during compute). ``pipeline_depth=1``
        degenerates to the serial pull–dispatch–complete loop. In-flight
        packages retire strictly in issue order, so the scheduler's
        speed refresh, the ownership ledger and counter attribution see
        the same per-package event sequence as the serial path.

        All control-plane decisions happen inside the shared
        :class:`~repro.core.exec.ExecutionLoop` under the engine lock;
        only the (expensive) data-plane phases run unlocked.
        """
        depth = self.pipeline_depth
        # this worker's in-flight packages, oldest first — only this
        # thread touches it, but the *count* it bounds (how many pulled-
        # but-incomplete packages the unit owns) is mirrored in the
        # loop's ownership ledger under _cv
        inflight: collections.deque = collections.deque()
        busy_floor = 0.0
        while True:
            with self._cv:
                work = self.loop.pull(unit_idx, force_flush=self._stop)
                while work is None:
                    if inflight:
                        # nothing new to pull: drain the pipeline instead
                        # of parking on top of unfinished packages
                        break
                    if self._stop and self.loop.drained():
                        return
                    # Park until a submit / completion / shutdown wakes
                    # us, or — when a staged fusion group is ripening —
                    # exactly until its flush deadline. Every state
                    # change notifies the condition, so an untimed wait
                    # needs no poll-interval safety net.
                    ripen = self.admission.next_ripen_in(time.perf_counter())
                    self.backend.wait_next_event(
                        timeout=None if ripen is None else max(ripen, 1e-4))
                    work = self.loop.pull(unit_idx, force_flush=self._stop)
            if work is None:
                busy_floor = self._retire_oldest(unit_idx, inflight,
                                                 busy_floor)
                continue
            launch, pkg = work
            try:
                # the engine's data plane stages inputs per the memory
                # model (USM: zero-copy shared views; BUFFERS: pooled
                # per-package device_put) and issues the kernel on the
                # unit asynchronously; collection happens at retire time.
                out_dev = self.backend.begin(unit_idx, launch, pkg)
            except BaseException as e:
                with self._cv:
                    self.loop.complete(launch, pkg, error=e)
                    self._cv.notify_all()
                continue
            inflight.append((launch, pkg, out_dev))
            while len(inflight) >= depth:
                busy_floor = self._retire_oldest(unit_idx, inflight,
                                                 busy_floor)
