"""Persistent co-execution engine (EngineCL-style, arXiv:1805.02755).

The paper's antecedent EngineCL shows that co-execution management overhead
stays under 1% only when the runtime is a *persistent engine*: worker threads
are created once and fed work, instead of being spawned and joined per
launch. This module provides that engine for the Coexecutor Runtime:

* one long-lived management thread per Coexecution Unit, started by
  :meth:`CoexecEngine.start` and parked on a condition variable when idle;
* a multi-tenant launch queue — any number of callers may
  :meth:`CoexecEngine.submit` co-executions concurrently; packages from all
  in-flight launches interleave on the same units (FIFO between launches,
  on-demand within a launch, exactly the Commander protocol of Fig. 2a);
* per-launch isolation — each launch owns its scheduler, output container,
  package log and :class:`LaunchStats`; completion is surfaced through a
  :class:`LaunchHandle` future, so independent callers never observe each
  other's state;
* a persistent :class:`~.profiler.SpeedBoard` — throughput measured on
  earlier launches seeds the adaptive (HGuided) speed refinement of later
  ones, which a per-launch thread pool could never do.

Lifecycle::

    engine = CoexecEngine(units)
    engine.start()
    h1 = engine.submit(sched1, kernel_a, inputs_a, out_a)
    h2 = engine.submit(sched2, kernel_b, inputs_b, out_b)   # interleaves
    out_a = h1.result(); out_b = h2.result()
    engine.shutdown()            # drains in-flight launches, joins threads

or, scoped::

    with CoexecEngine(units) as engine:
        out = engine.submit(sched, kernel, inputs, out).result()
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .memory import MemoryModel
from .package import Package, validate_cover
from .profiler import SpeedBoard
from .scheduler import HGuidedScheduler, Scheduler
from .units import JaxUnit


@dataclasses.dataclass
class LaunchStats:
    """Per-launch metrics mirroring the paper's measurements.

    Isolated per submit: concurrent launches on the same engine each get
    their own instance (busy seconds are derived from this launch's
    packages only, never from cumulative unit counters).
    """

    total_s: float
    packages: list[Package]
    unit_busy_s: dict[str, float]

    @property
    def num_packages(self) -> int:
        return len(self.packages)


class LaunchHandle:
    """Future for one submitted co-execution.

    ``result()`` blocks until the launch's whole index space has been
    computed and collected, then returns the output container. ``stats``
    is populated before the future resolves.
    """

    def __init__(self, launch_id: int):
        self.launch_id = launch_id
        self.stats: Optional[LaunchStats] = None
        self._future: concurrent.futures.Future = concurrent.futures.Future()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        return self._future.result(timeout)

    def exception(self, timeout: Optional[float] = None):
        return self._future.exception(timeout)

    def done(self) -> bool:
        return self._future.done()

    @property
    def packages(self) -> list[Package]:
        return self.stats.packages if self.stats is not None else []


class _Launch:
    """Engine-internal state of one in-flight co-execution."""

    __slots__ = ("id", "scheduler", "kernel", "inputs", "out", "adaptive",
                 "handle", "outstanding", "done_pkgs", "failed", "finalized",
                 "t_submit")

    def __init__(self, launch_id: int, scheduler: Scheduler, kernel: Callable,
                 inputs: Sequence[np.ndarray], out: np.ndarray,
                 adaptive: bool):
        self.id = launch_id
        self.scheduler = scheduler
        self.kernel = kernel
        self.inputs = inputs
        self.out = out
        self.adaptive = adaptive
        self.handle = LaunchHandle(launch_id)
        self.outstanding = 0          # issued but not yet collected
        self.done_pkgs: list[Package] = []
        self.failed = False
        self.finalized = False
        self.t_submit = time.perf_counter()


class CoexecEngine:
    """Long-lived per-unit worker threads fed from a multi-tenant queue."""

    def __init__(self, units: Sequence[JaxUnit], *,
                 memory: MemoryModel = MemoryModel.USM):
        if not units:
            raise ValueError("need at least one Coexecution Unit")
        self.units = list(units)
        self.memory = memory
        self.board = SpeedBoard(len(self.units),
                                hints=[u.speed_hint for u in self.units])
        self._cv = threading.Condition()
        self._launches: list[_Launch] = []   # active, FIFO submit order
        self._ids = itertools.count()
        self._threads: list[threading.Thread] = []
        self._stop = False
        self._started = False

    # -- lifecycle ---------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._started and not self._stop

    def start(self) -> "CoexecEngine":
        """Spawn the per-unit management threads (idempotent)."""
        with self._cv:
            if self._started:
                if self._stop:
                    raise RuntimeError("engine was shut down; build a new one")
                return self
            self._started = True
            self._threads = [
                threading.Thread(target=self._worker, args=(i,),
                                 name=f"counit-{u.name}-{i}", daemon=True)
                for i, u in enumerate(self.units)]
        for t in self._threads:
            t.start()
        return self

    def shutdown(self, wait: bool = True) -> None:
        """Stop accepting launches; drain in-flight ones, join workers."""
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if wait:
            for t in self._threads:
                t.join()

    def __enter__(self) -> "CoexecEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- submission --------------------------------------------------------
    def submit(self, scheduler: Scheduler, kernel: Callable,
               inputs: Sequence[np.ndarray], out: np.ndarray,
               *, adaptive: bool = True) -> LaunchHandle:
        """Enqueue one co-execution; returns immediately with its handle.

        The scheduler must be built for this engine's unit count. Packages
        are pulled on demand by whichever units go idle, interleaved with
        every other in-flight launch.
        """
        if scheduler.num_units != len(self.units):
            raise ValueError(
                f"scheduler built for {scheduler.num_units} units, engine "
                f"has {len(self.units)}")
        if scheduler.issued or scheduler.done():
            # A drained scheduler would hand out no packages, so the launch
            # could never reach its completion path (and would wedge
            # shutdown's drain). Schedulers are one-shot by design.
            raise ValueError("scheduler has already issued work; build a "
                             "fresh scheduler per launch")
        with self._cv:
            if self._stop:
                raise RuntimeError("engine is shut down")
            if not self._started:
                raise RuntimeError("engine not started; call start() first "
                                   "(or use it as a context manager)")
            launch = _Launch(next(self._ids), scheduler, kernel, inputs, out,
                             adaptive)
            self._launches.append(launch)
            self._cv.notify_all()
        return launch.handle

    # -- worker loop -------------------------------------------------------
    def _next_work(self, unit_idx: int) -> Optional[tuple[_Launch, Package]]:
        """Pull the next package for `unit_idx` (caller holds the cv)."""
        for launch in self._launches:
            if launch.failed:
                continue
            sched = launch.scheduler
            if launch.adaptive and isinstance(sched, HGuidedScheduler):
                for i, s in enumerate(self.board.speeds()):
                    sched.update_speed(i, s)
            pkg = sched.next_package(unit_idx)
            if pkg is not None:
                launch.outstanding += 1
                return launch, pkg
        return None

    def _finalize_locked(self, launch: _Launch) -> None:
        """Resolve a launch whose last package was collected (cv held)."""
        if launch.finalized:
            return
        launch.finalized = True
        if launch in self._launches:
            self._launches.remove(launch)
        try:
            validate_cover(launch.done_pkgs, launch.scheduler.total)
        except BaseException as e:
            launch.handle._future.set_exception(e)
            return
        busy: dict[str, float] = {u.name: 0.0 for u in self.units}
        for p in launch.done_pkgs:
            busy[self.units[p.unit].name] += max(p.t_complete - p.t_issue, 0.0)
        launch.handle.stats = LaunchStats(
            total_s=time.perf_counter() - launch.t_submit,
            packages=list(launch.done_pkgs),
            unit_busy_s=busy)
        launch.handle._future.set_result(launch.out)

    def _fail_locked(self, launch: _Launch, err: BaseException) -> None:
        """Abort a launch on its first package error (cv held)."""
        if launch.failed or launch.finalized:
            return
        launch.failed = True
        launch.finalized = True
        if launch in self._launches:
            self._launches.remove(launch)
        launch.handle._future.set_exception(err)

    def _worker(self, unit_idx: int) -> None:
        unit = self.units[unit_idx]
        while True:
            with self._cv:
                work = self._next_work(unit_idx)
                while work is None:
                    if self._stop and not self._launches:
                        return
                    # Park until a submit / completion / shutdown wakes us.
                    # The timeout is a safety net against lost wakeups only.
                    self._cv.wait(timeout=0.1)
                    work = self._next_work(unit_idx)
            launch, pkg = work
            pkg.t_issue = time.perf_counter()
            try:
                chunk = unit.run_package(launch.kernel, pkg.offset, pkg.size,
                                         launch.inputs)
                pkg.t_complete = time.perf_counter()
                # collection: USM writes in place into the launch's shared
                # container; BUFFERS is the same destination on this
                # substrate but modeled as an explicit merge copy.
                launch.out[pkg.offset:pkg.offset + pkg.size] = chunk
                pkg.t_collected = time.perf_counter()
            except BaseException as e:
                with self._cv:
                    launch.outstanding -= 1
                    self._fail_locked(launch, e)
                    self._cv.notify_all()
                continue
            self.board.record(unit_idx, pkg.size,
                              max(pkg.t_complete - pkg.t_issue, 1e-9))
            with self._cv:
                launch.outstanding -= 1
                launch.done_pkgs.append(pkg)
                if (not launch.failed and launch.scheduler.done()
                        and launch.outstanding == 0):
                    self._finalize_locked(launch)
                self._cv.notify_all()
