"""Work packages over a 1-D data-parallel index space.

The paper's Coexecutor Runtime splits a kernel's NDRange into *packages*
(contiguous ranges of work-items) that are dispatched to Coexecution Units.
Multi-dimensional problems are flattened to rows/pixels before packaging,
exactly as the reference implementation does.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class PackageState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"


@dataclasses.dataclass(frozen=True)
class Range:
    """Half-open interval [offset, offset + size) of work-items."""

    offset: int
    size: int

    def __post_init__(self) -> None:
        if self.size < 0:
            raise ValueError(f"negative range size {self.size}")
        if self.offset < 0:
            raise ValueError(f"negative range offset {self.offset}")

    @property
    def end(self) -> int:
        return self.offset + self.size

    def overlaps(self, other: "Range") -> bool:
        return self.offset < other.end and other.offset < self.end


@dataclasses.dataclass
class Package:
    """A schedulable unit of work: a range plus bookkeeping.

    Mirrors the `package` class handed to the application lambda in the
    paper's Listing 1 (``pkg.offset`` / ``pkg.size``).
    """

    rng: Range
    seq: int                      # emission order, global
    unit: Optional[int] = None    # Coexecution Unit id it was issued to
    state: PackageState = PackageState.PENDING
    # timeline bookkeeping (filled by the Commander / simulator)
    t_issue: float = 0.0
    t_launch: float = 0.0
    t_complete: float = 0.0
    t_collected: float = 0.0

    @property
    def offset(self) -> int:
        return self.rng.offset

    @property
    def size(self) -> int:
        return self.rng.size

    @property
    def compute_time(self) -> float:
        return self.t_complete - self.t_launch

    @property
    def wall_time(self) -> float:
        return self.t_collected - self.t_issue


def validate_cover(packages: list[Package], total: int) -> None:
    """Assert that packages exactly tile [0, total) — no gaps, no overlap.

    This is the core correctness invariant of every scheduler: each
    work-item is computed exactly once regardless of policy.
    """
    got = sorted((p.rng for p in packages), key=lambda r: r.offset)
    cursor = 0
    for r in got:
        if r.offset != cursor:
            raise AssertionError(
                f"package cover broken at {cursor}: next range starts at "
                f"{r.offset} (gap or overlap)"
            )
        cursor = r.end
    if cursor != total:
        raise AssertionError(f"package cover ends at {cursor}, expected {total}")
