"""Coexecution Units (paper Fig. 2a).

A *Coexecution Unit* owns one execution resource and a management thread that
talks to the Commander loop. Two substrates implement the same interface:

* ``SimUnit`` — used by the discrete-event simulator: a calibrated relative
  speed plus an irregularity exponent (`alpha`) modeling how much the unit
  suffers on computationally heavy items (branch divergence on the paper's
  iGPU). Reproduces the paper's scheduler dynamics deterministically.
* ``JaxUnit`` — real execution: dispatches jitted package kernels onto a
  ``jax.Device`` asynchronously (JAX's async dispatch stream plays the role
  of the oneAPI DAG) and reports completion when the output buffer is ready.

Package kernels have the signature ``fn(offset, chunk_inputs...) -> chunk_out``
and are compiled per package-size bucket (dynamic package sizes would
otherwise trigger unbounded recompilation — sizes are padded up to the
bucket, then sliced).
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np

try:  # jax is always present in this repo, but keep the DES importable alone
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None


@dataclasses.dataclass
class SimUnit:
    """Discrete-event-simulated device for scheduler evaluation.

    speed   — work-items/second on unit-weight data.
    alpha   — irregularity exponent: cost(item) = weight**alpha / speed.
              alpha > 1 models divergence-sensitive devices (the paper's
              iGPU on Ray/Mandelbrot); alpha = 1 is cost ∝ weight.
    setup_s — one-time init before the unit joins the Commander loop
              (queue/context creation; the paper's initialization phase).
    kind    — energy-model class ("cpu" / "gpu" / "tpu").
    """

    name: str
    kind: str
    speed: float
    alpha: float = 1.0
    setup_s: float = 2e-3

    def package_seconds(self, weights_prefix: Optional[np.ndarray],
                        offset: int, size: int) -> float:
        """Compute time for items [offset, offset+size)."""
        if weights_prefix is None:  # regular kernel: every item costs 1
            return size / self.speed
        w = weights_prefix[offset + size] - weights_prefix[offset]
        return float(w) / self.speed


class JaxUnit:
    """A real Coexecution Unit backed by a jax.Device.

    The management thread (owned by the Director) calls :meth:`run_package`;
    dispatch is asynchronous and completion is detected by blocking on the
    output buffer, mirroring the event-driven collection of the paper.
    """

    def __init__(self, name: str, device: "jax.Device", *, kind: str = "cpu",
                 speed_hint: float = 1.0,
                 size_buckets: Sequence[int] = ()):
        self.name = name
        self.kind = kind
        self.device = device
        self.speed_hint = float(speed_hint)
        self._compiled: dict[tuple[Any, int], Any] = {}
        self._size_buckets = sorted(size_buckets)
        self.busy_s = 0.0
        self._lock = threading.Lock()

    # -- size bucketing ----------------------------------------------------
    def bucket(self, size: int) -> int:
        if self._size_buckets:
            i = bisect.bisect_left(self._size_buckets, size)
            if i < len(self._size_buckets):
                return self._size_buckets[i]
        # default: next power of two — bounds compilations to O(log total)
        b = 1
        while b < size:
            b <<= 1
        return b

    def _get_compiled(self, fn: Callable) -> Any:
        # One jit per kernel; the package-size *bucket* is implicit in the
        # padded chunk shape, so XLA caches one executable per bucket.
        # Computation placement follows the committed (device_put) inputs.
        # Locked: one unit may be shared by several engines/directors, whose
        # worker threads race on first-compile of the same kernel.
        with self._lock:
            got = self._compiled.get(fn)
            if got is None:
                got = jax.jit(fn)
                self._compiled[fn] = got
        return got

    # -- execution ---------------------------------------------------------
    def run_package(self, fn: Callable, offset: int, size: int,
                    inputs: Sequence[np.ndarray]) -> np.ndarray:
        """Execute ``fn(offset_scalar, *padded_chunks) -> chunk_out``.

        Inputs are the *full* host arrays; this unit slices its package range,
        pads to the bucket size, dispatches, and returns the unpadded result.
        The kernel sees the real offset (for index-dependent work such as
        Mandelbrot pixel coordinates) and a fixed-bucket chunk.
        """
        bucket = self.bucket(size)
        chunks = []
        for arr in inputs:
            chunk = np.asarray(arr[offset:offset + size])
            if bucket != size:
                pad = [(0, bucket - size)] + [(0, 0)] * (chunk.ndim - 1)
                chunk = np.pad(chunk, pad)
            chunks.append(jax.device_put(chunk, self.device))
        compiled = self._get_compiled(fn)
        t0 = time.perf_counter()
        out = compiled(jnp.int32(offset), *chunks)
        out = np.asarray(out)  # blocks until ready (completion event)
        with self._lock:
            self.busy_s += time.perf_counter() - t0
        return out[:size]
