"""Coexecution Units (paper Fig. 2a).

A *Coexecution Unit* owns one execution resource and a management thread that
talks to the Commander loop. Two substrates implement the same interface:

* ``SimUnit`` — used by the discrete-event simulator: a calibrated relative
  speed plus an irregularity exponent (`alpha`) modeling how much the unit
  suffers on computationally heavy items (branch divergence on the paper's
  iGPU). Reproduces the paper's scheduler dynamics deterministically.
* ``JaxUnit`` — real execution: dispatches jitted package kernels onto a
  ``jax.Device`` asynchronously (JAX's async dispatch stream plays the role
  of the oneAPI DAG) and reports completion when the output buffer is ready.

Package kernels keep the signature ``fn(offset, chunk_inputs...) ->
chunk_out``; *how* the chunks reach the unit (zero-copy USM views vs
staged per-package buffers, padding to size buckets) is decided by the
data plane (:mod:`repro.core.dataplane`), which drives :meth:`JaxUnit.
dispatch`. The unit itself only owns the device, the per-kernel jit
cache, and its busy-time accounting.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Optional, Sequence

import numpy as np

try:  # jax is always present in this repo, but keep the DES importable alone
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover
    jax = None
    jnp = None


@dataclasses.dataclass
class SimUnit:
    """Discrete-event-simulated device for scheduler evaluation.

    speed   — work-items/second on unit-weight data.
    alpha   — irregularity exponent: cost(item) = weight**alpha / speed.
              alpha > 1 models divergence-sensitive devices (the paper's
              iGPU on Ray/Mandelbrot); alpha = 1 is cost ∝ weight.
    setup_s — one-time init before the unit joins the Commander loop
              (queue/context creation; the paper's initialization phase).
    kind    — energy-model class ("cpu" / "gpu" / "tpu").
    """

    name: str
    kind: str
    speed: float
    alpha: float = 1.0
    setup_s: float = 2e-3

    def package_seconds(self, weights_prefix: Optional[np.ndarray],
                        offset: int, size: int) -> float:
        """Compute time for items [offset, offset+size)."""
        if weights_prefix is None:  # regular kernel: every item costs 1
            return size / self.speed
        w = weights_prefix[offset + size] - weights_prefix[offset]
        return float(w) / self.speed


class JaxUnit:
    """A real Coexecution Unit backed by a jax.Device.

    The engine's management thread drives the unit through the launch's
    data plane (:meth:`~repro.core.dataplane.DataPlane.execute`), which
    stages inputs per the configured memory model and calls
    :meth:`dispatch`; dispatch is asynchronous and completion is detected
    by blocking on the output buffer, mirroring the event-driven
    collection of the paper.
    """

    def __init__(self, name: str, device: "jax.Device", *, kind: str = "cpu",
                 speed_hint: float = 1.0):
        self.name = name
        self.kind = kind
        self.device = device
        self.speed_hint = float(speed_hint)
        self._compiled: dict[Any, Any] = {}
        self._aot: dict[Any, Any] = {}  # guarded-by: _lock
        self.busy_s = 0.0
        self._lock = threading.Lock()

    def compiled(self, fn: Callable) -> Any:
        """The unit's cached ``jax.jit`` entry for one kernel body.

        One jit per kernel; distinct chunk shapes cache one executable
        each inside it (the data plane pads packages to power-of-two
        size buckets, bounding compilations to O(log total)).
        Computation placement follows the committed inputs. Locked: one
        unit may be shared by several engines, whose worker threads race
        on first-compile of the same kernel.
        """
        with self._lock:
            got = self._compiled.get(fn)
            if got is None:
                got = jax.jit(fn)
                self._compiled[fn] = got
        return got

    # -- execution ---------------------------------------------------------
    def dispatch(self, fn: Callable, offset: int,
                 args: Sequence[Any]) -> Any:
        """Asynchronously launch ``fn(offset, *args)`` on this unit.

        The args are whatever the launch's data plane staged (host views
        under USM, device-put buffers under BUFFERS). Dispatch runs
        under ``jax.default_device(self.device)`` so *uncommitted* host
        arrays (the USM plane's zero-copy views) still execute on this
        unit's device — committed BUFFERS operands already carry their
        placement. The kernel sees the real offset for index-dependent
        work such as Mandelbrot pixel coordinates. Returns the (not yet
        materialized) output array; the caller blocks on it to observe
        completion.
        """
        with jax.default_device(self.device):
            exe = None
            try:
                key = (fn, tuple((tuple(a.shape), np.dtype(a.dtype).str)
                                 for a in args))
            except (AttributeError, TypeError):
                key = None
            if key is not None:
                with self._lock:
                    exe = self._aot.get(key)
            if exe is not None:
                return exe(jnp.int32(offset), *args)
            return self.compiled(fn)(jnp.int32(offset), *args)

    def prewarm(self, fn: Callable, args: Sequence[Any]) -> None:
        """Ahead-of-time compile ``fn`` for one argument-shape bucket.

        Lowers and compiles the jitted kernel against the bucket's
        shapes/dtypes *without executing it* (safe for kernels whose
        bodies do host callbacks), and parks the executable where
        :meth:`dispatch` finds it — so the first real dispatch of this
        bucket skips XLA compilation and none of it is charged to
        :attr:`busy_s`. Memoized per ``(kernel, shapes, dtypes)``: later
        launches presenting the same compile bucket skip straight
        through.

        Args:
            fn: the kernel body (same object :meth:`dispatch` receives).
            args: arguments of the bucket's shapes/dtypes; values are
                irrelevant and nothing is computed from them.
        """
        avals = [jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype)
                 for a in args]
        key = (fn, tuple((tuple(v.shape), np.dtype(v.dtype).str)
                         for v in avals))
        with self._lock:
            if key in self._aot:
                return
        with jax.default_device(self.device):
            exe = self.compiled(fn).lower(
                jax.ShapeDtypeStruct((), np.int32), *avals).compile()
        with self._lock:
            self._aot.setdefault(key, exe)

    def add_busy(self, seconds: float) -> None:
        """Account dispatch-to-completion time against this unit."""
        with self._lock:
            self.busy_s += seconds
