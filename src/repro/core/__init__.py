"""Coexecutor Runtime — the paper's contribution as a composable JAX module.

Public surface:
    CoexecutorRuntime, counits_from_devices     — real co-execution (Listing 1)
    CoexecEngine, LaunchHandle, LaunchStats     — persistent engine (start/
                                                  submit/shutdown; concurrent
                                                  launches interleave)
    make_scheduler / Static / Dynamic /
        HGuided / WorkStealing                  — load balancers (§3.2)
    simulate, solo_run, Workload, SimUnit       — DES reproduction engine
    MemoryModel, MemoryCosts                    — USM vs Buffers (§3.1)
    PowerModel, energy_report, edp_ratio        — energy/EDP model (§5.2)
    paper_workload, ALL_BENCHMARKS              — Table 1 profiles
"""
from .energy import (EnergyReport, PowerModel, PAPER_POWER, TPU_POWER,
                     edp_ratio, energy_report, geomean)
from .engine import CoexecEngine, LaunchHandle, LaunchStats
from .memory import MemoryCosts, MemoryModel, TPU_MEMORY_COSTS
from .package import Package, Range, validate_cover
from .profiler import EwmaThroughput, SpeedBoard
from .runtime import CoexecutorRuntime, counits_from_devices
from .scheduler import (SPEED_HINT_POLICIES, DynamicScheduler,
                        HGuidedScheduler, Scheduler, StaticScheduler,
                        WorkStealingScheduler, make_scheduler, static_bounds)
from .sim import SimResult, Workload, simulate, solo_run
from .units import JaxUnit, SimUnit
from .workloads import (ALL_BENCHMARKS, IRREGULAR, REGULAR, SPECS,
                        paper_workload)

__all__ = [
    "ALL_BENCHMARKS", "CoexecEngine", "CoexecutorRuntime",
    "DynamicScheduler", "EnergyReport", "EwmaThroughput", "HGuidedScheduler",
    "IRREGULAR", "JaxUnit", "LaunchHandle", "LaunchStats", "MemoryCosts",
    "MemoryModel", "PAPER_POWER", "Package", "PowerModel", "REGULAR",
    "Range", "SPECS", "SPEED_HINT_POLICIES", "Scheduler", "SimResult",
    "SimUnit", "SpeedBoard",
    "StaticScheduler", "TPU_MEMORY_COSTS", "TPU_POWER",
    "WorkStealingScheduler", "Workload", "counits_from_devices", "edp_ratio",
    "energy_report", "geomean", "make_scheduler", "paper_workload",
    "simulate", "solo_run", "static_bounds", "validate_cover",
]
