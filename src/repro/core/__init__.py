"""Coexecutor Runtime — the paper's contribution as a composable JAX module.

Configuration is declarative: build a ``repro.api.CoexecSpec`` and hand
it to ``CoexecutorRuntime.from_spec`` / ``CoexecEngine.from_spec`` /
``simulate(..., spec=...)``. The kwarg-era entry points (``rt.config``,
``make_scheduler``, engine admission kwargs, ``package_kernel``) were
removed when their deprecation window closed — see docs/api.md.

Public surface:
    CoexecutorRuntime, counits_from_devices     — real co-execution (Listing 1)
    CoexecEngine, LaunchHandle, LaunchStats     — persistent engine (start/
                                                  submit/shutdown; concurrent
                                                  launches interleave)
    ExecutionLoop, LaunchState                  — the shared control plane
                                                  both backends drive
                                                  (repro.core.exec)
    AdmissionConfig, AdmissionController,
        AdmissionFull, jain_index               — cross-launch admission:
                                                  WFQ fairness (+ preemptive
                                                  pull-capping), launch
                                                  fusion, backpressure
    LaunchWaitTimeout                           — wait-timeout vs launch-failed
    Static / Dynamic / HGuided / WorkStealing   — load balancers (§3.2),
                                                  built via the registry
                                                  (repro.api.build_scheduler)
    simulate, solo_run, Workload, SimUnit       — DES reproduction engine
    simulate_multi, LaunchSpec, MultiSimResult  — multi-tenant DES (admission
                                                  policies in virtual time)
    MemoryModel, MemoryCosts                    — USM vs Buffers (§3.1)
    CoexecKernel, ArgSpec, ArgRole, OutputSpec  — typed kernel protocol
    DataPlaneCounters, make_plane               — real USM/BUFFERS data plane
    PowerModel, energy_report, edp_ratio        — energy/EDP model (§5.2)
    paper_workload, ALL_BENCHMARKS              — Table 1 profiles
    UnitPool, Supervisor, Autoscaler,
        FailurePlan, replay_trace_cluster       — elastic cluster tier:
                                                  resizable pool, failure
                                                  detection, exact re-issue
                                                  (repro.core.cluster)
"""
from .admission import (ADMISSION_POLICIES, AdmissionConfig,
                        AdmissionController, AdmissionFull, LaunchShed,
                        fusion_bucket, jain_index, service_fairness_curve)
from .cluster import (Autoscaler, ClusterRealBackend, ClusterReplay,
                      ClusterSimBackend, FailurePlan, InjectedFailure,
                      Supervisor, UnitPool, absorb_share, grant_share,
                      replay_cluster_lockstep, replay_trace_cluster)
from .dataplane import (ArgRole, ArgSpec, CoexecKernel, DataPlaneCounters,
                        OutputSpec, as_coexec_kernel, make_plane)
from .energy import (EnergyReport, PowerModel, PAPER_POWER, TPU_POWER,
                     edp_ratio, energy_report, geomean)
from .engine import (CoexecEngine, LaunchHandle, LaunchStats,
                     LaunchWaitTimeout)
from .exec import ExecutionLoop, LaunchState
from .memory import MemoryCosts, MemoryModel, TPU_MEMORY_COSTS
from .package import Package, Range, validate_cover
from .profiler import EwmaThroughput, SpeedBoard
from .runtime import CoexecutorRuntime, counits_from_devices
from .scheduler import (SPEED_HINT_POLICIES, DynamicScheduler,
                        HGuidedScheduler, Scheduler, StaticScheduler,
                        WorkStealingScheduler, static_bounds)
from .sim import (LaunchSimResult, LaunchSpec, MultiSimResult, ShedRecord,
                  SimResult, Workload, simulate, simulate_multi, solo_run)
from .traffic import (Arrival, TenantRow, Trace, TrafficReplay,
                      capacity_items_per_s, replay_trace_lockstep,
                      replay_trace_sim, synthesize_trace, tenant_rows)
from .units import JaxUnit, SimUnit
from .workloads import (ALL_BENCHMARKS, IRREGULAR, REGULAR, SPECS,
                        paper_workload)

__all__ = [
    "ADMISSION_POLICIES", "ALL_BENCHMARKS", "AdmissionConfig",
    "AdmissionController", "AdmissionFull", "ArgRole", "ArgSpec",
    "Arrival", "Autoscaler", "ClusterRealBackend", "ClusterReplay",
    "ClusterSimBackend", "CoexecEngine", "CoexecKernel",
    "CoexecutorRuntime", "DataPlaneCounters", "DynamicScheduler",
    "EnergyReport", "EwmaThroughput", "ExecutionLoop", "FailurePlan",
    "HGuidedScheduler", "IRREGULAR", "InjectedFailure", "JaxUnit",
    "LaunchHandle", "LaunchShed", "LaunchSimResult", "LaunchSpec",
    "LaunchState", "LaunchStats", "LaunchWaitTimeout", "MemoryCosts",
    "MemoryModel", "MultiSimResult", "OutputSpec", "PAPER_POWER",
    "Package", "PowerModel", "REGULAR", "Range", "SPECS",
    "SPEED_HINT_POLICIES", "Scheduler", "ShedRecord", "SimResult",
    "SimUnit", "SpeedBoard", "StaticScheduler", "Supervisor",
    "TPU_MEMORY_COSTS", "TPU_POWER", "TenantRow", "Trace",
    "TrafficReplay", "UnitPool", "WorkStealingScheduler", "Workload",
    "absorb_share", "as_coexec_kernel", "capacity_items_per_s",
    "counits_from_devices", "edp_ratio", "energy_report", "fusion_bucket",
    "geomean", "grant_share", "jain_index", "make_plane",
    "paper_workload", "replay_cluster_lockstep", "replay_trace_cluster",
    "replay_trace_lockstep", "replay_trace_sim", "service_fairness_curve",
    "simulate", "simulate_multi", "solo_run", "static_bounds",
    "synthesize_trace", "tenant_rows", "validate_cover",
]
