"""Public Coexecutor Runtime API (paper §3.3, Listing 1).

Python rendering of the paper's C++ API, configured by a declarative
:class:`~repro.api.spec.CoexecSpec`::

    from repro.api import CoexecSpec

    spec = (CoexecSpec.builder().policy("hguided").dist(0.35)
            .memory("usm").build())
    rt = CoexecutorRuntime.from_spec(spec)
    out = rt.launch(n, kernel, inputs)           # blocking co-execution

    h1 = rt.launch_async(n, kernel_a, inputs_a)  # non-blocking: a Future
    h2 = rt.launch_async(m, kernel_b, inputs_b)  # co-executions interleave
    out_a, out_b = h1.result(), h2.result()

The pre-spec kwarg surface — ``CoexecutorRuntime("hguided").config(
units=..., dist=0.35, memory="usm")`` — was removed when its deprecation
window closed (see docs/api.md); use
:meth:`CoexecutorRuntime.configure` / :meth:`CoexecutorRuntime.from_spec`.

`kernel(offset, *chunks) -> chunk_out` is a pure JAX function over a package
slice — the analogue of the SYCL command-group lambda. The runtime splits the
index space with the configured load balancer, co-executes on all units, and
the results land in the expected host container, exactly as the paper
describes ("the data resulting from the computation will be in the expected
data structures").

Execution is backed by a persistent :class:`~.engine.CoexecEngine` (started
on first launch, reused across launches): many co-executions from
independent callers interleave safely on the same units, each with its own
scheduler and :class:`~.engine.LaunchStats`. ``shutdown()`` (or use as a
context manager) drains the engine and joins its worker threads.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

import jax

from .dataplane import CoexecKernel
from .engine import CoexecEngine, LaunchHandle, LaunchStats
from .units import JaxUnit

__all__ = ["CoexecutorRuntime", "LaunchStats", "counits_from_devices"]


def counits_from_devices(devices: Optional[Sequence["jax.Device"]] = None,
                         *, kinds: Optional[Sequence[str]] = None,
                         speed_hints: Optional[Sequence[float]] = None,
                         ) -> list[JaxUnit]:
    """One Coexecution Unit per device (default: all local jax devices).

    On the paper's platform this is [CPU, GPU]; on a TPU host it is the
    local chips; on this CPU-only container it degenerates to one unit
    (co-execution still works — one unit serves all packages).
    """
    devices = list(devices if devices is not None else jax.local_devices())
    units = []
    seen: dict[str, int] = {}
    for i, d in enumerate(devices):
        kind = (kinds[i] if kinds else
                ("tpu" if d.platform == "tpu" else d.platform))
        hint = speed_hints[i] if speed_hints else 1.0
        name = f"{d.platform}:{d.id}"
        # the same device may back several units (the CPU-only container's
        # two-unit setup); names must stay unique or per-unit stats merge
        n = seen.get(name, 0)
        seen[name] = n + 1
        if n:
            name = f"{name}#{n}"
        units.append(JaxUnit(name, d, kind=kind, speed_hint=hint))
    return units


class CoexecutorRuntime:
    """The paper's `coexecutor_runtime<policy>` object, spec-configured."""

    def __init__(self, policy: str = "hguided", *, spec=None):
        """Build a runtime for one scheduling policy (or a full spec).

        Args:
            policy: intra-launch policy name (Listing 1's ``<hg>``);
                ignored when ``spec`` is given.
            spec: full :class:`~repro.api.spec.CoexecSpec`; when omitted
                an all-default spec with ``policy`` is used.
        """
        from repro.api.spec import CoexecSpec, SchedulerSpec

        if spec is None:
            spec = CoexecSpec(scheduler=SchedulerSpec(policy=policy))
        self._spec = spec
        self._units: Optional[list[JaxUnit]] = None
        self._engine: Optional[CoexecEngine] = None
        self.last_stats: Optional[LaunchStats] = None

    # -- declarative configuration (the CoexecSpec surface) ----------------
    @classmethod
    def from_spec(cls, spec, *, units: Optional[Sequence[JaxUnit]] = None
                  ) -> "CoexecutorRuntime":
        """Build a runtime entirely from a :class:`CoexecSpec`.

        Args:
            spec: the declarative configuration (validated here).
            units: pre-built Coexecution Units overriding the spec's
                ``units`` section (units are runtime objects, so specs
                describe them rather than contain them).

        Returns:
            A configured runtime (engine starts on first launch).
        """
        rt = cls(spec=spec.validate())
        if units is not None:
            rt._units = list(units)
        return rt

    @property
    def spec(self):
        """The :class:`CoexecSpec` in force (frozen; replace to change)."""
        return self._spec

    @property
    def policy(self) -> str:
        """The configured intra-launch scheduling policy name."""
        return self._spec.scheduler.policy

    def configure(self, spec, *, units: Optional[Sequence[JaxUnit]] = None
                  ) -> "CoexecutorRuntime":
        """Swap in a new spec (the non-deprecated ``config`` successor).

        Args:
            spec: the new declarative configuration (validated here).
            units: pre-built units overriding the spec's ``units``
                section; ``None`` keeps previously supplied units.

        Returns:
            The runtime itself, for chaining. Reconfiguring shuts down
            any running engine (units/memory/admission may have changed).
        """
        self._spec = spec.validate()
        if units is not None:
            self._units = list(units)
        self.shutdown()
        return self

    # -- engine lifecycle ---------------------------------------------------
    @property
    def engine(self) -> Optional[CoexecEngine]:
        """The persistent engine, if one has been started."""
        return self._engine

    def _get_engine(self) -> CoexecEngine:
        if self._engine is None or not self._engine.running:
            if self._units is None:
                self._units = self._spec.build_units()
            self._engine = CoexecEngine.from_spec(
                self._spec, units=self._units).start()
        return self._engine

    def shutdown(self) -> None:
        """Drain in-flight launches and join the engine's workers."""
        if self._engine is not None:
            self._engine.shutdown()
            self._engine = None

    def __enter__(self) -> "CoexecutorRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- launch (paper: runtime.launch(size, lambda)) -----------------------
    def launch_async(self, total: int, kernel: Callable,
                     inputs: Sequence[np.ndarray],
                     out: Optional[np.ndarray] = None,
                     *, out_dtype=np.float32,
                     out_trailing_shape: tuple = (),
                     granularity: int = 1,
                     tenant: Optional[str] = None,
                     weight: float = 1.0,
                     block: bool = True) -> LaunchHandle:
        """Non-blocking co-execution: returns a :class:`LaunchHandle`.

        Any number of launches may be in flight at once; their packages
        interleave on the engine's units under the configured admission
        policy, and each handle carries its own isolated stats.
        ``handle.result()`` blocks until this launch's whole index space
        is computed and collected.

        Args:
            total: size of the 1-D index space to co-execute.
            kernel: a registered/typed
                :class:`~repro.core.dataplane.CoexecKernel`, or a legacy
                package closure ``fn(offset, *chunks) -> chunk_out``.
            inputs: full host input arrays (moved per the kernel's
                declared per-argument semantics).
            out: output container; allocated when ``None`` (a typed
                kernel's declared output slot wins over ``out_dtype`` /
                ``out_trailing_shape``).
            out_dtype: dtype of the allocated output.
            out_trailing_shape: trailing dims of the allocated output.
            granularity: package alignment; overrides the spec's
                ``scheduler.granularity`` when not 1.
            tenant: fairness flow for WFQ admission (defaults to a
                per-launch tenant).
            weight: relative WFQ share of the tenant.
            block: wait for an admission slot when the engine is at
                ``max_inflight`` capacity, instead of raising.

        Returns:
            The launch's :class:`LaunchHandle` future.

        Raises:
            AdmissionFull: engine at capacity and ``block=False``.
            ValueError: invalid scheduler parameters for this policy.
        """
        engine = self._get_engine()
        n = len(engine.units)
        sched_spec = self._spec.scheduler
        if granularity != 1:
            sched_spec = sched_spec.replace(granularity=granularity)
        sched = sched_spec.build(total, n, speeds=self._spec.speeds_for(n))
        if out is None:
            if isinstance(kernel, CoexecKernel):
                out = kernel.alloc_out(total, inputs)
            else:
                out = np.zeros((total, *out_trailing_shape), dtype=out_dtype)
        return engine.submit(sched, kernel, inputs, out,
                             tenant=tenant, weight=weight, block=block)

    def launch(self, total: int, kernel: Callable,
               inputs: Sequence[np.ndarray],
               out: Optional[np.ndarray] = None,
               *, out_dtype=np.float32,
               out_trailing_shape: tuple = (),
               granularity: int = 1) -> np.ndarray:
        """Blocking co-execution — a thin wrapper over :meth:`launch_async`."""
        handle = self.launch_async(total, kernel, inputs, out,
                                   out_dtype=out_dtype,
                                   out_trailing_shape=out_trailing_shape,
                                   granularity=granularity)
        result = handle.result()
        self.last_stats = handle.stats
        return result
