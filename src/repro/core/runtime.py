"""Public Coexecutor Runtime API (paper §3.3, Listing 1).

Python rendering of the paper's C++ API::

    rt = CoexecutorRuntime(policy="hguided")
    rt.config(units=counits_cpu_gpu(), dist=0.35, memory="usm")
    out = rt.launch(n, kernel, inputs)           # blocking co-execution

    h1 = rt.launch_async(n, kernel_a, inputs_a)  # non-blocking: a Future
    h2 = rt.launch_async(m, kernel_b, inputs_b)  # co-executions interleave
    out_a, out_b = h1.result(), h2.result()

`kernel(offset, *chunks) -> chunk_out` is a pure JAX function over a package
slice — the analogue of the SYCL command-group lambda. The runtime splits the
index space with the configured load balancer, co-executes on all units, and
the results land in the expected host container, exactly as the paper
describes ("the data resulting from the computation will be in the expected
data structures").

Execution is backed by a persistent :class:`~.engine.CoexecEngine` (started
on first launch, reused across launches): many co-executions from
independent callers interleave safely on the same units, each with its own
scheduler and :class:`~.engine.LaunchStats`. ``shutdown()`` (or use as a
context manager) drains the engine and joins its worker threads.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

import jax

from .engine import CoexecEngine, LaunchHandle, LaunchStats
from .memory import MemoryModel
from .scheduler import SPEED_HINT_POLICIES, make_scheduler
from .units import JaxUnit

__all__ = ["CoexecutorRuntime", "LaunchStats", "counits_from_devices"]


def counits_from_devices(devices: Optional[Sequence["jax.Device"]] = None,
                         *, kinds: Optional[Sequence[str]] = None,
                         speed_hints: Optional[Sequence[float]] = None,
                         ) -> list[JaxUnit]:
    """One Coexecution Unit per device (default: all local jax devices).

    On the paper's platform this is [CPU, GPU]; on a TPU host it is the
    local chips; on this CPU-only container it degenerates to one unit
    (co-execution still works — one unit serves all packages).
    """
    devices = list(devices if devices is not None else jax.local_devices())
    units = []
    seen: dict[str, int] = {}
    for i, d in enumerate(devices):
        kind = (kinds[i] if kinds else
                ("tpu" if d.platform == "tpu" else d.platform))
        hint = speed_hints[i] if speed_hints else 1.0
        name = f"{d.platform}:{d.id}"
        # the same device may back several units (the CPU-only container's
        # two-unit setup); names must stay unique or per-unit stats merge
        n = seen.get(name, 0)
        seen[name] = n + 1
        if n:
            name = f"{name}#{n}"
        units.append(JaxUnit(name, d, kind=kind, speed_hint=hint))
    return units


class CoexecutorRuntime:
    """The paper's `coexecutor_runtime<policy>` object."""

    def __init__(self, policy: str = "hguided"):
        self.policy = policy
        self._units: Optional[list[JaxUnit]] = None
        self._memory = MemoryModel.USM
        self._dist: Optional[Sequence[float]] = None
        self._scheduler_kw: dict = {}
        self._engine: Optional[CoexecEngine] = None
        self.last_stats: Optional[LaunchStats] = None

    # -- configuration (paper: runtime.config(CounitSet::CpuGpu, dist(0.35)))
    def config(self, units: Optional[Sequence[JaxUnit]] = None,
               *, dist: Optional[float | Sequence[float]] = None,
               memory: str | MemoryModel = MemoryModel.USM,
               **scheduler_kw) -> "CoexecutorRuntime":
        self._units = list(units) if units is not None else None
        if isinstance(dist, (int, float)):
            # scalar hint = first unit's share, remainder spread evenly
            # (the paper's dist(0.35) gives CPU 35 %, GPU 65 %).
            n = len(self._units) if self._units else 2
            rest = (1.0 - float(dist)) / max(n - 1, 1)
            self._dist = [float(dist)] + [rest] * (n - 1)
        elif dist is not None:
            self._dist = [float(x) for x in dist]
        self._memory = (memory if isinstance(memory, MemoryModel)
                        else MemoryModel(str(memory).lower()))
        self._scheduler_kw = scheduler_kw
        # a reconfigure invalidates the running engine (units/memory change)
        self.shutdown()
        return self

    # -- engine lifecycle ---------------------------------------------------
    @property
    def engine(self) -> Optional[CoexecEngine]:
        """The persistent engine, if one has been started."""
        return self._engine

    def _get_engine(self) -> CoexecEngine:
        if self._engine is None or not self._engine.running:
            if self._units is None:
                self._units = counits_from_devices()
            self._engine = CoexecEngine(self._units,
                                        memory=self._memory).start()
        return self._engine

    def shutdown(self) -> None:
        """Drain in-flight launches and join the engine's workers."""
        if self._engine is not None:
            self._engine.shutdown()
            self._engine = None

    def __enter__(self) -> "CoexecutorRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- launch (paper: runtime.launch(size, lambda)) -----------------------
    def launch_async(self, total: int, kernel: Callable,
                     inputs: Sequence[np.ndarray],
                     out: Optional[np.ndarray] = None,
                     *, out_dtype=np.float32,
                     out_trailing_shape: tuple = (),
                     granularity: int = 1) -> LaunchHandle:
        """Non-blocking co-execution: returns a :class:`LaunchHandle`.

        Any number of launches may be in flight at once; their packages
        interleave on the engine's units, and each handle carries its own
        isolated stats. ``handle.result()`` blocks until this launch's
        whole index space is computed and collected.
        """
        engine = self._get_engine()
        kw = dict(self._scheduler_kw)
        if self.policy.lower().replace("-", "_") in SPEED_HINT_POLICIES \
                and self._dist:
            kw.setdefault("speeds", list(self._dist))
        sched = make_scheduler(self.policy, total, len(engine.units),
                               granularity=granularity, **kw)
        if out is None:
            out = np.zeros((total, *out_trailing_shape), dtype=out_dtype)
        return engine.submit(sched, kernel, inputs, out)

    def launch(self, total: int, kernel: Callable,
               inputs: Sequence[np.ndarray],
               out: Optional[np.ndarray] = None,
               *, out_dtype=np.float32,
               out_trailing_shape: tuple = (),
               granularity: int = 1) -> np.ndarray:
        """Blocking co-execution — a thin wrapper over :meth:`launch_async`."""
        handle = self.launch_async(total, kernel, inputs, out,
                                   out_dtype=out_dtype,
                                   out_trailing_shape=out_trailing_shape,
                                   granularity=granularity)
        result = handle.result()
        self.last_stats = handle.stats
        return result
