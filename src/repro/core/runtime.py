"""Public Coexecutor Runtime API (paper §3.3, Listing 1).

Python rendering of the paper's C++ API::

    rt = CoexecutorRuntime(policy="hguided")
    rt.config(units=counits_cpu_gpu(), dist=0.35, memory="usm")
    out = rt.launch(n, kernel, inputs)           # blocking co-execution

`kernel(offset, *chunks) -> chunk_out` is a pure JAX function over a package
slice — the analogue of the SYCL command-group lambda. The runtime splits the
index space with the configured load balancer, co-executes on all units, and
the results land in the expected host container, exactly as the paper
describes ("the data resulting from the computation will be in the expected
data structures").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

import jax

from .director import Director
from .memory import MemoryModel
from .package import Package
from .scheduler import make_scheduler
from .units import JaxUnit


def counits_from_devices(devices: Optional[Sequence["jax.Device"]] = None,
                         *, kinds: Optional[Sequence[str]] = None,
                         speed_hints: Optional[Sequence[float]] = None,
                         ) -> list[JaxUnit]:
    """One Coexecution Unit per device (default: all local jax devices).

    On the paper's platform this is [CPU, GPU]; on a TPU host it is the
    local chips; on this CPU-only container it degenerates to one unit
    (co-execution still works — one unit serves all packages).
    """
    devices = list(devices if devices is not None else jax.local_devices())
    units = []
    for i, d in enumerate(devices):
        kind = (kinds[i] if kinds else
                ("tpu" if d.platform == "tpu" else d.platform))
        hint = speed_hints[i] if speed_hints else 1.0
        units.append(JaxUnit(f"{d.platform}:{d.id}", d, kind=kind,
                             speed_hint=hint))
    return units


@dataclasses.dataclass
class LaunchStats:
    """Per-launch metrics mirroring the paper's measurements."""

    total_s: float
    packages: list[Package]
    unit_busy_s: dict[str, float]

    @property
    def num_packages(self) -> int:
        return len(self.packages)


class CoexecutorRuntime:
    """The paper's `coexecutor_runtime<policy>` object."""

    def __init__(self, policy: str = "hguided"):
        self.policy = policy
        self._units: Optional[list[JaxUnit]] = None
        self._memory = MemoryModel.USM
        self._dist: Optional[Sequence[float]] = None
        self._scheduler_kw: dict = {}
        self.last_stats: Optional[LaunchStats] = None

    # -- configuration (paper: runtime.config(CounitSet::CpuGpu, dist(0.35)))
    def config(self, units: Optional[Sequence[JaxUnit]] = None,
               *, dist: Optional[float | Sequence[float]] = None,
               memory: str | MemoryModel = MemoryModel.USM,
               **scheduler_kw) -> "CoexecutorRuntime":
        self._units = list(units) if units is not None else None
        if isinstance(dist, (int, float)):
            # scalar hint = first unit's share, remainder spread evenly
            # (the paper's dist(0.35) gives CPU 35 %, GPU 65 %).
            n = len(self._units) if self._units else 2
            rest = (1.0 - float(dist)) / max(n - 1, 1)
            self._dist = [float(dist)] + [rest] * (n - 1)
        elif dist is not None:
            self._dist = [float(x) for x in dist]
        self._memory = (memory if isinstance(memory, MemoryModel)
                        else MemoryModel(str(memory).lower()))
        self._scheduler_kw = scheduler_kw
        return self

    # -- launch (paper: runtime.launch(size, lambda)) -----------------------
    def launch(self, total: int, kernel: Callable,
               inputs: Sequence[np.ndarray],
               out: Optional[np.ndarray] = None,
               *, out_dtype=np.float32,
               out_trailing_shape: tuple = (),
               granularity: int = 1) -> np.ndarray:
        units = self._units if self._units is not None else counits_from_devices()
        kw = dict(self._scheduler_kw)
        if self.policy.lower() in ("static", "hguided") and self._dist:
            kw.setdefault("speeds", list(self._dist))
        sched = make_scheduler(self.policy, total, len(units),
                               granularity=granularity, **kw)
        if out is None:
            out = np.zeros((total, *out_trailing_shape), dtype=out_dtype)
        director = Director(units, memory=self._memory)
        import time as _time
        t0 = _time.perf_counter()
        pkgs = director.launch(sched, kernel, inputs, out)
        total_s = _time.perf_counter() - t0
        self.last_stats = LaunchStats(
            total_s=total_s, packages=pkgs,
            unit_busy_s={u.name: u.busy_s for u in units})
        return out
