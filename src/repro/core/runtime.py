"""Public Coexecutor Runtime API (paper §3.3, Listing 1).

Python rendering of the paper's C++ API::

    rt = CoexecutorRuntime(policy="hguided")
    rt.config(units=counits_cpu_gpu(), dist=0.35, memory="usm")
    out = rt.launch(n, kernel, inputs)           # blocking co-execution

    h1 = rt.launch_async(n, kernel_a, inputs_a)  # non-blocking: a Future
    h2 = rt.launch_async(m, kernel_b, inputs_b)  # co-executions interleave
    out_a, out_b = h1.result(), h2.result()

`kernel(offset, *chunks) -> chunk_out` is a pure JAX function over a package
slice — the analogue of the SYCL command-group lambda. The runtime splits the
index space with the configured load balancer, co-executes on all units, and
the results land in the expected host container, exactly as the paper
describes ("the data resulting from the computation will be in the expected
data structures").

Execution is backed by a persistent :class:`~.engine.CoexecEngine` (started
on first launch, reused across launches): many co-executions from
independent callers interleave safely on the same units, each with its own
scheduler and :class:`~.engine.LaunchStats`. ``shutdown()`` (or use as a
context manager) drains the engine and joins its worker threads.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

import jax

from .admission import AdmissionConfig
from .engine import CoexecEngine, LaunchHandle, LaunchStats
from .memory import MemoryModel
from .scheduler import SPEED_HINT_POLICIES, make_scheduler
from .units import JaxUnit

__all__ = ["CoexecutorRuntime", "LaunchStats", "counits_from_devices"]


def counits_from_devices(devices: Optional[Sequence["jax.Device"]] = None,
                         *, kinds: Optional[Sequence[str]] = None,
                         speed_hints: Optional[Sequence[float]] = None,
                         ) -> list[JaxUnit]:
    """One Coexecution Unit per device (default: all local jax devices).

    On the paper's platform this is [CPU, GPU]; on a TPU host it is the
    local chips; on this CPU-only container it degenerates to one unit
    (co-execution still works — one unit serves all packages).
    """
    devices = list(devices if devices is not None else jax.local_devices())
    units = []
    seen: dict[str, int] = {}
    for i, d in enumerate(devices):
        kind = (kinds[i] if kinds else
                ("tpu" if d.platform == "tpu" else d.platform))
        hint = speed_hints[i] if speed_hints else 1.0
        name = f"{d.platform}:{d.id}"
        # the same device may back several units (the CPU-only container's
        # two-unit setup); names must stay unique or per-unit stats merge
        n = seen.get(name, 0)
        seen[name] = n + 1
        if n:
            name = f"{name}#{n}"
        units.append(JaxUnit(name, d, kind=kind, speed_hint=hint))
    return units


class CoexecutorRuntime:
    """The paper's `coexecutor_runtime<policy>` object."""

    def __init__(self, policy: str = "hguided"):
        self.policy = policy
        self._units: Optional[list[JaxUnit]] = None
        self._memory = MemoryModel.USM
        self._dist: Optional[Sequence[float]] = None
        self._scheduler_kw: dict = {}
        self._admission: "str | AdmissionConfig" = "fifo"
        self._fuse: Optional[bool] = None
        self._max_inflight: Optional[int] = None
        self._engine: Optional[CoexecEngine] = None
        self.last_stats: Optional[LaunchStats] = None

    # -- configuration (paper: runtime.config(CounitSet::CpuGpu, dist(0.35)))
    def config(self, units: Optional[Sequence[JaxUnit]] = None,
               *, dist: Optional[float | Sequence[float]] = None,
               memory: str | MemoryModel = MemoryModel.USM,
               admission: "str | AdmissionConfig" = "fifo",
               fuse: Optional[bool] = None,
               max_inflight: Optional[int] = None,
               **scheduler_kw) -> "CoexecutorRuntime":
        """Configure units, memory model, admission policy and scheduler.

        Args:
            units: Coexecution Units (default: one per local jax device).
            dist: computing-power hint — a scalar is the first unit's
                share (the paper's ``dist(0.35)``), a sequence is per-unit.
            memory: ``"usm"`` or ``"buffers"`` collection semantics.
            admission: cross-launch policy name (``"fifo"`` / ``"wfq"``)
                or a full :class:`~.admission.AdmissionConfig`.
            fuse: coalesce small concurrent same-shaped launches.
            max_inflight: backpressure cap on admitted launches.
            **scheduler_kw: forwarded to :func:`~.scheduler.make_scheduler`.

        Returns:
            The runtime itself, for chaining. Reconfiguring shuts down any
            running engine (its units/memory/admission may have changed).
        """
        self._units = list(units) if units is not None else None
        if isinstance(dist, (int, float)):
            # scalar hint = first unit's share, remainder spread evenly
            # (the paper's dist(0.35) gives CPU 35 %, GPU 65 %).
            n = len(self._units) if self._units else 2
            rest = (1.0 - float(dist)) / max(n - 1, 1)
            self._dist = [float(dist)] + [rest] * (n - 1)
        elif dist is not None:
            self._dist = [float(x) for x in dist]
        self._memory = (memory if isinstance(memory, MemoryModel)
                        else MemoryModel(str(memory).lower()))
        self._admission = admission
        self._fuse = fuse
        self._max_inflight = max_inflight
        self._scheduler_kw = scheduler_kw
        # a reconfigure invalidates the running engine (units/memory change)
        self.shutdown()
        return self

    # -- engine lifecycle ---------------------------------------------------
    @property
    def engine(self) -> Optional[CoexecEngine]:
        """The persistent engine, if one has been started."""
        return self._engine

    def _get_engine(self) -> CoexecEngine:
        if self._engine is None or not self._engine.running:
            if self._units is None:
                self._units = counits_from_devices()
            self._engine = CoexecEngine(
                self._units, memory=self._memory,
                admission=self._admission, fuse=self._fuse,
                max_inflight=self._max_inflight).start()
        return self._engine

    def shutdown(self) -> None:
        """Drain in-flight launches and join the engine's workers."""
        if self._engine is not None:
            self._engine.shutdown()
            self._engine = None

    def __enter__(self) -> "CoexecutorRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- launch (paper: runtime.launch(size, lambda)) -----------------------
    def launch_async(self, total: int, kernel: Callable,
                     inputs: Sequence[np.ndarray],
                     out: Optional[np.ndarray] = None,
                     *, out_dtype=np.float32,
                     out_trailing_shape: tuple = (),
                     granularity: int = 1,
                     tenant: Optional[str] = None,
                     weight: float = 1.0,
                     block: bool = True) -> LaunchHandle:
        """Non-blocking co-execution: returns a :class:`LaunchHandle`.

        Any number of launches may be in flight at once; their packages
        interleave on the engine's units under the configured admission
        policy, and each handle carries its own isolated stats.
        ``handle.result()`` blocks until this launch's whole index space
        is computed and collected.

        Args:
            total: size of the 1-D index space to co-execute.
            kernel: package kernel ``fn(offset, *chunks) -> chunk_out``.
            inputs: full host input arrays (sliced per package).
            out: output container; allocated when ``None``.
            out_dtype: dtype of the allocated output.
            out_trailing_shape: trailing dims of the allocated output.
            granularity: package alignment (local work size).
            tenant: fairness flow for WFQ admission (defaults to a
                per-launch tenant).
            weight: relative WFQ share of the tenant.
            block: wait for an admission slot when the engine is at
                ``max_inflight`` capacity, instead of raising.

        Returns:
            The launch's :class:`LaunchHandle` future.

        Raises:
            AdmissionFull: engine at capacity and ``block=False``.
            ValueError: invalid scheduler parameters for this policy.
        """
        engine = self._get_engine()
        kw = dict(self._scheduler_kw)
        if self.policy.lower().replace("-", "_") in SPEED_HINT_POLICIES \
                and self._dist:
            kw.setdefault("speeds", list(self._dist))
        sched = make_scheduler(self.policy, total, len(engine.units),
                               granularity=granularity, **kw)
        if out is None:
            out = np.zeros((total, *out_trailing_shape), dtype=out_dtype)
        return engine.submit(sched, kernel, inputs, out,
                             tenant=tenant, weight=weight, block=block)

    def launch(self, total: int, kernel: Callable,
               inputs: Sequence[np.ndarray],
               out: Optional[np.ndarray] = None,
               *, out_dtype=np.float32,
               out_trailing_shape: tuple = (),
               granularity: int = 1) -> np.ndarray:
        """Blocking co-execution — a thin wrapper over :meth:`launch_async`."""
        handle = self.launch_async(total, kernel, inputs, out,
                                   out_dtype=out_dtype,
                                   out_trailing_shape=out_trailing_shape,
                                   granularity=granularity)
        result = handle.result()
        self.last_stats = handle.stats
        return result
