"""Elastic cluster tier: a runtime-resizable unit pool with exact recovery.

The paper's Coexecutor Runtime fixes its device set for the life of a
kernel. This module grows past that — the ROADMAP's "elastic scale-out"
item — by treating pool membership as a runtime property of the shared
:class:`~repro.core.exec.ExecutionLoop`:

* :class:`UnitPool` — provisioned Coexecution Unit slots that
  ``grow``/``shrink``/``drain`` at runtime (dormant slots are simply dead
  units that revive cheaply);
* :class:`Autoscaler` — watches admission queue depth and resizes the
  pool with hysteresis (separate up/down thresholds), sustain/idle
  windows and a cooldown, so bursts scale out and lulls scale in without
  thrash;
* :class:`Supervisor` — heartbeat-based failure detection with a grace
  window, straggler flagging against the pool's typical package service
  time, scripted failure injection via :class:`FailurePlan`, and
  speed-share bookkeeping using the renormalizing drop/grant moves the
  dormant ``hetero/rebalance.py`` seed modeled
  (:func:`absorb_share`/:func:`grant_share`);
* :class:`ClusterSimBackend` — DES substrate where failures and joins
  are scripted events on the virtual clock, so a 1000-unit pool is
  deterministically testable;
* :class:`ClusterRealBackend` — the thread-backed twin for small pools,
  driven in lockstep by :func:`replay_cluster_lockstep` for structural
  parity pinning (same style as the traffic lockstep harness).

Recovery is **exact-once** by construction: the loop's per-package
ownership ledger disowns a dead unit's in-flight packages (a zombie
completion is dropped), their exact :class:`~repro.core.package.Range`\\ s
re-emit to survivors, and per-unit scheduler reservations (static
regions, work-stealing deques) are harvested so nothing strands. A
recovered launch's package cover — and therefore its results — is
bitwise-identical to an undisturbed run, and per-launch counters balance
exactly because the lost attempt is never charged.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import json
import pathlib
from typing import Callable, Optional, Sequence

from .admission import AdmissionConfig
from .exec import ExecutionLoop, LaunchState
from .memory import MemoryCosts, MemoryModel
from .package import Package
from .scheduler import DynamicScheduler
from .sim import SimBackend, Workload, _SimLaunchState
from .traffic import (Trace, _percentile_ms, _resolve_config,
                      capacity_items_per_s)
from .units import SimUnit

__all__ = [
    "Autoscaler", "ClusterRealBackend", "ClusterReplay", "ClusterSimBackend",
    "FailurePlan", "InjectedFailure", "PLAN_VERSION", "Supervisor",
    "UnitPool", "absorb_share", "grant_share", "replay_cluster_lockstep",
    "replay_trace_cluster",
]

PLAN_VERSION = 1


class InjectedFailure(RuntimeError):
    """Deterministic failure raised/applied by a :class:`FailurePlan`."""


# ---------------------------------------------------------------------------
# Share bookkeeping (absorbed from the hetero/rebalance.py seed)
# ---------------------------------------------------------------------------

def absorb_share(shares: dict[str, float], name: str) -> dict[str, float]:
    """Remove one member's share and renormalize the survivors.

    The pure form of the dormant seed's ``RebalancePolicy.drop_group``:
    the departed member's share is redistributed proportionally, so the
    survivors keep their relative ratios and the total returns to 1.

    Args:
        shares: normalized share per member name.
        name: the departing member (absent names are a no-op).

    Returns:
        A fresh normalized share dict without ``name``.
    """
    out = {k: float(v) for k, v in shares.items() if k != name}
    tot = sum(out.values())
    if tot > 0:
        out = {k: v / tot for k, v in out.items()}
    return out


def grant_share(shares: dict[str, float], name: str,
                hint_share: float) -> dict[str, float]:
    """Grant a newcomer ``hint_share``, scaling incumbents proportionally.

    The pure form of the seed's ``RebalancePolicy.add_group``: every
    incumbent keeps its relative ratio inside the remaining
    ``1 - hint_share`` of the pool.

    Args:
        shares: normalized share per member name.
        name: the joining member.
        hint_share: the newcomer's share in ``(0, 1]``.

    Returns:
        A fresh normalized share dict including ``name``.
    """
    if not 0.0 < hint_share <= 1.0:
        raise ValueError(f"hint share must be in (0, 1], got {hint_share}")
    scale = 1.0 - hint_share
    out = {k: float(v) * scale for k, v in shares.items()}
    out[name] = float(hint_share)
    return out


# ---------------------------------------------------------------------------
# FailurePlan: reproducible failure scenarios as artifacts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FailurePlan:
    """Scripted failures, as a reproducible JSON artifact.

    Two keyings coexist because two consumers do:

    * ``events`` — *step*-keyed actions for the training supervisor
      (``repro.ft``): ``"crash"`` raises :class:`InjectedFailure` once,
      ``"kill:<group>"`` removes a device group.
    * ``timeline`` — *time*-keyed ``(t_seconds, action)`` pairs for the
      serving cluster: ``"kill:<unit>"`` fails a Coexecution Unit at
      virtual time ``t``, ``"join:<unit>"`` brings one (back) in. The
      unit token is an index or a unit name.

    JSON round trips mirror :class:`~repro.core.traffic.Trace`:
    :meth:`to_json`/:meth:`from_json` are lossless and
    :meth:`save`/:meth:`load` make a scenario a committed artifact.
    """

    events: dict[int, str] = dataclasses.field(default_factory=dict)
    timeline: tuple[tuple[float, str], ...] = ()

    def __post_init__(self) -> None:
        self.timeline = tuple((float(t), str(a)) for t, a in self.timeline)

    def check(self, step: int) -> Optional[str]:
        """The training-loop action scheduled for ``step`` (or None)."""
        return self.events.get(step)

    def validate(self) -> "FailurePlan":
        """Raise ValueError on malformed actions or negative times."""
        for t, action in self.timeline:
            kind, _, token = action.partition(":")
            if t < 0:
                raise ValueError(f"negative plan time {t}")
            if kind not in ("kill", "join") or not token:
                raise ValueError(f"unknown plan action {action!r} "
                                 "(want kill:<unit> or join:<unit>)")
        return self

    # -- JSON round trip (Trace.save/Trace.load style) ----------------------
    def to_dict(self) -> dict:
        """Lossless plain-dict form (versioned, JSON-serializable)."""
        return {
            "version": PLAN_VERSION,
            "events": {str(k): str(v)
                       for k, v in sorted(self.events.items())},
            "timeline": [[t, a] for t, a in self.timeline],
        }

    @classmethod
    def from_dict(cls, data: dict) -> "FailurePlan":
        """Inverse of :meth:`to_dict`; validates version and actions."""
        version = data.get("version")
        if version != PLAN_VERSION:
            raise ValueError(f"unsupported failure-plan version {version!r} "
                             f"(this build reads {PLAN_VERSION})")
        events = {int(k): str(v) for k, v in data.get("events", {}).items()}
        timeline = tuple((float(t), str(a))
                         for t, a in data.get("timeline", []))
        return cls(events=events, timeline=timeline).validate()

    def to_json(self, indent: Optional[int] = None) -> str:
        """Serialize :meth:`to_dict` as stable-key JSON text."""
        return json.dumps(self.to_dict(), sort_keys=True, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "FailurePlan":
        """Inverse of :meth:`to_json`."""
        return cls.from_dict(json.loads(text))

    def save(self, path) -> None:
        """Write the plan as pretty-printed JSON (committed-artifact form)."""
        pathlib.Path(path).write_text(self.to_json(indent=2) + "\n")

    @classmethod
    def load(cls, path) -> "FailurePlan":
        """Read a plan previously written by :meth:`save`.

        Args:
            path: JSON file written by :meth:`save`.

        Returns:
            The validated plan.
        """
        return cls.from_json(pathlib.Path(path).read_text())


def _resolve_unit(token: str, names: Sequence[str]) -> int:
    """A plan's unit token (index or name) → unit index."""
    if token.lstrip("-").isdigit():
        unit = int(token)
    else:
        try:
            unit = list(names).index(token)
        except ValueError:
            raise ValueError(f"unknown unit {token!r} "
                             f"(pool: {list(names)})") from None
    if not 0 <= unit < len(names):
        raise ValueError(f"unit index {unit} outside the provisioned "
                         f"pool of {len(names)}")
    return unit


# ---------------------------------------------------------------------------
# Supervisor: failure detection, straggler flagging, share bookkeeping
# ---------------------------------------------------------------------------

class Supervisor:
    """Failure detector and recovery orchestrator over one execution loop.

    Revives the dormant ``ft/supervisor.py`` seed ideas for serving:
    scripted :class:`FailurePlan` injection, heartbeat-based detection
    (a unit whose last beat is older than ``grace_s`` is declared dead),
    and straggler flagging (a package outstanding for more than
    ``straggler_factor`` times the pool's EWMA package service time).
    Death routes through :meth:`ExecutionLoop.unit_lost`, which performs
    the exact-once package re-issue; the supervisor adds the *policy*
    layer (when to declare death) plus the speed-share bookkeeping the
    ``hetero/rebalance.py`` seed modeled.
    """

    def __init__(self, loop: ExecutionLoop, *, heartbeat_s: float = 0.05,
                 grace_s: float = 0.2, straggler_factor: float = 4.0,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        """Build a supervisor.

        Args:
            loop: the execution loop whose pool this supervises.
            heartbeat_s: expected beat interval (drives check cadence).
            grace_s: silence beyond this declares a unit dead.
            straggler_factor: outstanding-age multiple of the EWMA
                package service time that flags a straggler.
            on_straggler: optional ``(unit, age_s)`` callback per flag.
        """
        if grace_s <= 0 or heartbeat_s <= 0:
            raise ValueError("heartbeat and grace intervals must be positive")
        self.loop = loop
        self.heartbeat_s = float(heartbeat_s)
        self.grace_s = float(grace_s)
        self.straggler_factor = float(straggler_factor)
        self.on_straggler = on_straggler
        self._beats: dict[int, float] = {}  # guarded-by: caller
        self._speed: dict[int, float] = {}  # guarded-by: caller
        self._service_ema: Optional[float] = None  # guarded-by: caller
        self._flagged: set[tuple[int, float]] = set()  # guarded-by: caller
        self.shares: dict[str, float] = {}  # guarded-by: caller
        self.kills: list[tuple[float, int]] = []
        self.joins: list[tuple[float, int]] = []
        self.leaves: list[tuple[float, int]] = []
        self.stragglers: list[tuple[float, int]] = []

    # -- membership ---------------------------------------------------------
    def register(self, unit: int, speed: float = 1.0, *,
                 t: float = 0.0) -> None:
        """Start supervising one live unit (grants it a speed share)."""
        self._beats[unit] = float(t)
        self._speed[unit] = float(speed)
        tot = sum(self._speed.values())
        self.shares = grant_share(self.shares, self.loop.unit_names[unit],
                                  float(speed) / tot)

    def fail_unit(self, unit: int, t: float = 0.0) -> int:
        """Declare one unit dead; its work re-issues to survivors.

        Returns:
            Number of ranges :meth:`ExecutionLoop.unit_lost` queued.
        """
        moved = self.loop.unit_lost(unit)
        self._absorb(unit)
        self.kills.append((float(t), unit))
        return moved

    def retire_unit(self, unit: int, t: float = 0.0) -> None:
        """Gracefully remove a drained unit (scale-in, not a failure)."""
        if self.loop.in_flight_of(unit):
            raise ValueError(f"unit {unit} still owns in-flight packages")
        self.loop.unit_lost(unit)
        self._absorb(unit)
        self.leaves.append((float(t), unit))

    def join_unit(self, unit: int, t: float = 0.0, *,
                  speed: float = 1.0, name: Optional[str] = None) -> None:
        """Bring a unit (back) into the pool and grant it a share."""
        self.loop.unit_joined(unit, name=name, speed=speed)
        self.register(unit, speed, t=t)
        self.joins.append((float(t), unit))

    def _absorb(self, unit: int) -> None:
        self._beats.pop(unit, None)
        self._speed.pop(unit, None)
        self.shares = absorb_share(self.shares, self.loop.unit_names[unit])

    # -- detection ----------------------------------------------------------
    def beat(self, unit: int, t: float) -> None:
        """Record a liveness beat (monotone per unit)."""
        if unit in self._beats:
            self._beats[unit] = max(self._beats[unit], float(t))

    def check(self, t: float) -> list[int]:
        """Declare units silent for longer than ``grace_s`` dead.

        Args:
            t: Current backend time in seconds.

        Returns:
            The unit indices failed by this check, in index order.
        """
        stale = sorted(u for u, b in self._beats.items()
                       if t - b > self.grace_s
                       and u not in self.loop.dead_units)
        for u in stale:
            self.fail_unit(u, t)
        return stale

    def note_service(self, seconds: float) -> None:
        """Feed one package's issue-to-complete time into the EWMA."""
        if seconds <= 0:
            return
        self._service_ema = (seconds if self._service_ema is None
                             else 0.8 * self._service_ema + 0.2 * seconds)

    def flag_stragglers(self, t: float) -> list[int]:
        """Flag units whose oldest in-flight package is suspiciously old.

        A straggler is flagged once per incident (per outstanding issue
        time); it is *not* killed — that stays a policy decision for the
        caller (or the heartbeat check, if the unit also goes silent).
        """
        ref = (self._service_ema if self._service_ema is not None
               else self.grace_s)
        out = []
        for u in sorted(self._beats):
            if u in self.loop.dead_units:
                continue
            t0 = self.loop.oldest_issue(u)
            if t0 is None or (t - t0) <= self.straggler_factor * ref:
                continue
            key = (u, t0)
            if key in self._flagged:
                continue
            self._flagged.add(key)
            self.stragglers.append((float(t), u))
            out.append(u)
            if self.on_straggler is not None:
                self.on_straggler(u, t - t0)
        return out


# ---------------------------------------------------------------------------
# UnitPool + Autoscaler
# ---------------------------------------------------------------------------

class UnitPool:
    """Runtime-resizable set of Coexecution Units over one execution loop.

    The pool is *provisioned* at its maximum size (every slot has a unit
    name, a backend lane and — on the real backend — a worker thread) and
    *activates* a subset: a dormant slot is simply a dead unit index, so
    ``grow`` is a revival and ``shrink``/``drain`` a graceful loss. This
    keeps both backends structurally identical across resizes — no
    arrays ever reallocate mid-run — which is what makes elastic runs
    lockstep-comparable between the DES and the threaded engine.
    """

    def __init__(self, loop: ExecutionLoop, *, min_units: int = 1,
                 max_units: Optional[int] = None,
                 supervisor: Optional[Supervisor] = None,
                 speeds: Optional[Sequence[float]] = None):
        """Provision the pool and park slots above the floor.

        Args:
            loop: the execution loop; must already name ``max_units``
                units (the provisioned slots).
            min_units: slots active at start and the scale-in floor.
            max_units: provisioned ceiling; defaults to the loop's unit
                count and must equal it.
            supervisor: optional supervisor kept in sync on every
                membership change.
            speeds: per-slot relative speed hints (shares, scheduler
                hints for late joiners).
        """
        total = len(loop.unit_names)
        self.max_units = total if max_units is None else int(max_units)
        if self.max_units != total:
            raise ValueError(
                f"pool must be provisioned at max_units: loop has {total} "
                f"unit slots, max_units={self.max_units}")
        self.min_units = int(min_units)
        if not 1 <= self.min_units <= self.max_units:
            raise ValueError(f"need 1 <= min_units <= max_units, got "
                             f"{self.min_units}..{self.max_units}")
        self.loop = loop
        self.supervisor = supervisor
        self.speeds = (list(speeds) if speeds is not None  # guarded-by: caller
                       else [1.0] * total)
        if len(self.speeds) != total:
            raise ValueError("speeds length must match the provisioned pool")
        for u in range(self.min_units, total):
            loop.unit_lost(u)       # dormant: provisioned but not joined
        if supervisor is not None:
            for u in range(self.min_units):
                supervisor.register(u, self.speeds[u])

    @property
    def alive(self) -> list[int]:
        """Active unit indices, ascending."""
        return [i for i in range(self.max_units)
                if i not in self.loop.dead_units]

    @property
    def size(self) -> int:
        """Number of active units."""
        return self.max_units - len(self.loop.dead_units)

    def grow(self, n: int = 1, *, now: float = 0.0) -> list[int]:
        """Activate up to ``n`` dormant slots (lowest indices first).

        Args:
            n: Maximum number of slots to activate.
            now: Backend time stamped on the join events.

        Returns:
            The indices actually activated (may be fewer than ``n``).
        """
        grown = []
        for _ in range(max(n, 0)):
            if self.size >= self.max_units:
                break
            u = min(self.loop.dead_units)
            if self.supervisor is not None:
                self.supervisor.join_unit(u, now, speed=self.speeds[u])
            else:
                self.loop.unit_joined(u, speed=self.speeds[u])
            grown.append(u)
        return grown

    def drain(self, unit: int, *, now: float = 0.0) -> bool:
        """Gracefully retire one idle unit.

        Refuses while the unit still owns in-flight packages — drain is
        for scale-in, where nothing may be lost or re-issued; a unit that
        must leave *now* regardless is a failure
        (:meth:`Supervisor.fail_unit`).

        Args:
            unit: Index of the unit to retire.
            now: Backend time stamped on the leave event.

        Returns:
            ``True`` when the unit left, ``False`` when it still holds
            in-flight work (call again once it drains).
        """
        if unit in self.loop.dead_units:
            return True
        if self.loop.in_flight_of(unit):
            return False
        if self.supervisor is not None:
            self.supervisor.retire_unit(unit, now)
        else:
            self.loop.unit_lost(unit)
        return True

    def shrink(self, n: int = 1, *, now: float = 0.0) -> list[int]:
        """Retire up to ``n`` idle units (highest indices first).

        Respects the ``min_units`` floor and skips units with in-flight
        work, so a shrink can be partial; the autoscaler simply retries
        on a later tick.

        Returns:
            The indices actually retired.
        """
        shrunk = []
        for u in reversed(self.alive):
            if len(shrunk) >= max(n, 0) or self.size <= self.min_units:
                break
            if self.loop.in_flight_of(u):
                continue
            if self.drain(u, now=now):
                shrunk.append(u)
        return shrunk


class Autoscaler:
    """Queue-depth autoscaling with hysteresis, sustain windows, cooldown.

    Scale-out requires the admission depth to sit at or above
    ``scale_up_depth`` for ``sustain_s`` straight; scale-in requires it
    at or below ``scale_down_depth`` for ``idle_s``. The two thresholds
    form the hysteresis band (depths between them hold the pool steady),
    and ``cooldown_s`` separates consecutive resizes so a burst cannot
    thrash the pool.
    """

    def __init__(self, pool: UnitPool, *, scale_up_depth: int = 8,
                 scale_down_depth: int = 1, sustain_s: float = 0.1,
                 idle_s: float = 0.5, cooldown_s: float = 0.25,
                 step: int = 1):
        if scale_down_depth >= scale_up_depth:
            raise ValueError("hysteresis needs scale_down_depth < "
                             "scale_up_depth")
        if step <= 0:
            raise ValueError("step must be positive")
        self.pool = pool
        self.scale_up_depth = int(scale_up_depth)
        self.scale_down_depth = int(scale_down_depth)
        self.sustain_s = float(sustain_s)
        self.idle_s = float(idle_s)
        self.cooldown_s = float(cooldown_s)
        self.step = int(step)
        self._over_since: Optional[float] = None  # guarded-by: caller
        self._under_since: Optional[float] = None  # guarded-by: caller
        self._last_resize: Optional[float] = None  # guarded-by: caller
        self.actions: list[tuple[float, int]] = []   # (t, signed delta)

    def _cooled(self, t: float) -> bool:
        return (self._last_resize is None
                or t - self._last_resize >= self.cooldown_s)

    def observe(self, t: float, depth: int) -> int:
        """Feed one (time, queue-depth) sample; maybe resize the pool.

        Args:
            t: sample time (the caller's clock — virtual or wall).
            depth: admission queue depth (admitted-but-unfinished
                launches).

        Returns:
            The signed unit-count change actually performed (0 mostly).
        """
        if depth >= self.scale_up_depth:
            self._under_since = None
            if self._over_since is None:
                self._over_since = t
            if (t - self._over_since >= self.sustain_s and self._cooled(t)
                    and self.pool.size < self.pool.max_units):
                grown = self.pool.grow(self.step, now=t)
                if grown:
                    self._last_resize = t
                    self._over_since = None
                    self.actions.append((t, len(grown)))
                    return len(grown)
        elif depth <= self.scale_down_depth:
            self._over_since = None
            if self._under_since is None:
                self._under_since = t
            if (t - self._under_since >= self.idle_s and self._cooled(t)
                    and self.pool.size > self.pool.min_units):
                shrunk = self.pool.shrink(self.step, now=t)
                if shrunk:
                    self._last_resize = t
                    self._under_since = None
                    self.actions.append((t, -len(shrunk)))
                    return -len(shrunk)
        else:
            self._over_since = None
            self._under_since = None
        return 0


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ClusterSimBackend(SimBackend):
    """DES substrate for an elastic pool: scripted deaths, deterministic.

    Extends :class:`~repro.core.sim.SimBackend` with a cluster event
    pump (:meth:`run`): a :class:`FailurePlan` timeline injects
    ``kill``/``join`` events on the virtual clock, an optional
    :class:`Autoscaler` resizes the pool on queue depth, and an optional
    :class:`Supervisor` keeps share/liveness bookkeeping.

    Death semantics: a package whose modeled compute would end *after*
    its unit's scripted death is the one in flight when the unit dies.
    It is held un-dispatched (nothing charged — exactly like the real
    backend, where the doomed dispatch never executes) until the kill
    event harvests it through :meth:`ExecutionLoop.unit_lost`, after
    which survivors re-compute the identical range. A package whose
    compute ends before the death completes normally.
    """

    def __init__(self, units: Sequence[SimUnit], memory: MemoryModel,
                 costs: MemoryCosts, *, pipeline_depth: int = 1):
        super().__init__(units, memory, costs,
                         pipeline_depth=pipeline_depth)
        self.kills: list[tuple[float, int]] = []
        self.joins: list[tuple[float, int]] = []
        self.scale_events: list[tuple[float, int]] = []  # (t, new size)
        self._kill_at: dict[int, collections.deque[float]] = {}  # guarded-by: caller
        # packages held in flight on a unit that dies before finishing
        # them — up to pipeline_depth per unit, issue order preserved
        self._doomed: dict[int, list[tuple[_SimLaunchState, Package]]] = {}  # guarded-by: caller

    def _doomed_full(self, unit: int) -> bool:
        """Whether the unit's in-flight pipeline is saturated with doomed
        packages (it must stop pulling until its scripted kill fires)."""
        return len(self._doomed.get(unit, ())) >= self.pipeline_depth

    def run(self, loop: ExecutionLoop,                      # type: ignore[override]
            entries: Sequence[_SimLaunchState], *,
            plan: Optional[FailurePlan] = None,
            supervisor: Optional[Supervisor] = None,
            autoscaler: Optional[Autoscaler] = None) -> None:
        """Advance virtual time until every admitted launch settles.

        Control events (kills/joins) sort before unit pulls at the same
        instant, so a unit declared dead at ``t`` cannot pull at ``t``.

        Args:
            loop: the shared control plane built over this backend.
            entries: launches to admit, each at its ``t_submit``.
            plan: scripted failure timeline (``kill:<u>``/``join:<u>``).
            supervisor: records membership changes and service beats.
            autoscaler: resizes the pool from admission queue depth.
        """
        names = [u.name for u in self.units]
        tie = itertools.count()
        evq: list[tuple[float, int, int, str, int]] = []
        self._kill_at = {}
        self._doomed = {}
        if plan is not None:
            for t, action in sorted(plan.validate().timeline):
                heapq.heappush(evq, (float(t), 0, next(tie), action, -1))
                kind, _, token = action.partition(":")
                if kind == "kill":
                    u = _resolve_unit(token, names)
                    self._kill_at.setdefault(
                        u, collections.deque()).append(float(t))
        pending = collections.deque(sorted(entries,
                                           key=lambda e: e.t_submit))
        parked: set[int] = set()    # units that found no work last pull
        for i, u in enumerate(self.units):
            if i not in loop.dead_units:
                heapq.heappush(evq, (u.setup_s, 1, next(tie), "idle", i))

        def wake_all(t: float) -> None:
            parked.clear()
            for j in range(len(self.units)):
                if j not in loop.dead_units and not self._doomed_full(j):
                    heapq.heappush(evq, (t + 1e-9, 1, next(tie), "idle", j))

        while evq:
            t, _, _, kind, i = heapq.heappop(evq)
            self.t = t
            while pending and pending[0].t_submit <= t + 1e-12:
                entry = pending.popleft()
                if not loop.offer(entry, now=entry.t_submit):
                    self.shed.append(entry)
            if autoscaler is not None:
                if autoscaler.observe(t, loop.admission.in_flight):
                    self.scale_events.append((t, autoscaler.pool.size))
                    wake_all(t)
            if kind != "idle":
                akind, _, token = kind.partition(":")
                u = _resolve_unit(token, names)
                if akind == "kill":
                    if supervisor is not None:
                        supervisor.fail_unit(u, t)
                    else:
                        loop.unit_lost(u)
                    self._doomed.pop(u, None)
                    dq = self._kill_at.get(u)
                    if dq:
                        dq.popleft()
                    self.kills.append((t, u))
                    wake_all(t)
                else:           # join
                    if supervisor is not None:
                        supervisor.join_unit(u, t, speed=self.units[u].speed)
                    else:
                        loop.unit_joined(u, speed=self.units[u].speed)
                    self.joins.append((t, u))
                    heapq.heappush(evq, (t + self.units[u].setup_s, 1,
                                         next(tie), "idle", u))
                if supervisor is not None:
                    supervisor.flag_stragglers(t)
                continue
            if i in loop.dead_units or self._doomed_full(i):
                continue
            parked.discard(i)
            work = loop.pull(i, now=t, force_flush=not pending)
            if work is None:
                # Park, but stay wakeable: the next arrival or fusion
                # ripening re-arms us directly, and *any* completion on
                # another unit notifies the parked set below — the DES
                # equivalent of the engine's condition-variable
                # ``notify_all``, without which the drain phase after the
                # last arrival degrades toward a single serving unit
                # (policies with bounded pull windows return ``None``
                # transiently near each launch boundary).
                parked.add(i)
                wake = pending[0].t_submit if pending else None
                ripen = loop.admission.next_ripen_in(t)
                if ripen is not None:
                    t_r = t + max(ripen, 1e-9)
                    wake = t_r if wake is None else min(wake, t_r)
                if wake is not None:
                    heapq.heappush(evq, (max(wake, t + 1e-9), 1,
                                         next(tie), "idle", i))
                continue
            entry, pkg = work
            kills = self._kill_at.get(i)
            if kills:
                _, _, compute_end = self._model_compute(i, entry, pkg)
                # in-order per-unit completion: once one in-flight
                # package runs past the kill, everything pulled behind
                # it is lost with the unit too
                if i in self._doomed or compute_end >= kills[0] - 1e-12:
                    # dies mid-package: hold the attempt in flight,
                    # uncharged; the kill event harvests it for re-issue
                    self._doomed.setdefault(i, []).append((entry, pkg))
                    if not self._doomed_full(i):
                        # a pipelined unit keeps pulling until its
                        # in-flight window is saturated
                        heapq.heappush(evq, (t + 1e-9, 1, next(tie),
                                             "idle", i))
                    continue
            self.dispatch(i, entry, pkg)
            loop.complete(entry, pkg)
            if supervisor is not None:
                supervisor.beat(i, pkg.t_complete)
                supervisor.note_service(pkg.t_complete - pkg.t_issue)
            # re-arm on the serial clock (busy_until), not the recorded
            # pipelined completion — keeps pull pacing depth-invariant
            heapq.heappush(evq, (self.busy_until[i], 1, next(tie),
                                 "idle", i))
            if parked:
                # a completion may unblock work for parked units (launch
                # finalization frees the policy's pull window)
                for j in sorted(parked):
                    if j not in loop.dead_units and not self._doomed_full(j):
                        heapq.heappush(evq, (self.busy_until[i] + 1e-9, 1,
                                             next(tie), "idle", j))
                parked.clear()

        if not loop.drained():
            raise RuntimeError(
                "cluster simulation wedged: work remains but no live unit "
                "can serve it (did the plan kill the whole pool?)")


class ClusterRealBackend:
    """Thread-backed substrate with pool membership (lazy import shim).

    Defined lazily in :func:`_real_backend_class` so importing the
    cluster module never forces the JAX engine stack; resolving the
    class the first time builds it against
    :class:`~repro.core.engine.RealBackend`.
    """

    def __new__(cls, *args, **kwargs):
        real = _real_backend_class()
        return real(*args, **kwargs)


_REAL_BACKEND_CLS = None


def _real_backend_class():
    """Build (once) the RealBackend subclass that drops dead-unit work."""
    global _REAL_BACKEND_CLS
    if _REAL_BACKEND_CLS is not None:
        return _REAL_BACKEND_CLS
    from .engine import RealBackend

    class _ClusterRealBackend(RealBackend):
        """Thread-backed substrate that drops a dead unit's dispatches.

        A worker thread that pulled a package just before its unit was
        declared dead may still reach ``dispatch``; the package was
        already disowned and its range re-issued, so executing it would
        double-compute. The guard drops the execution and the loop's
        ledger drops the zombie completion — exact-once on both sides.
        The owning engine/harness points ``loop`` at its
        :class:`ExecutionLoop` right after building it.
        """

        loop: Optional[ExecutionLoop] = None  # guarded-by: caller

        def dispatch(self, unit, launch, pkg):
            if self.loop is not None and unit in self.loop.dead_units:
                return
            super().dispatch(unit, launch, pkg)

    _REAL_BACKEND_CLS = _ClusterRealBackend
    return _ClusterRealBackend


# ---------------------------------------------------------------------------
# Replay drivers
# ---------------------------------------------------------------------------

def replay_cluster_lockstep(trace: Trace, loop: ExecutionLoop, make_launch, *,
                            events: Sequence[tuple[int, str]] = (),
                            max_sweeps: int = 1_000_000):
    """Deterministic shared driver for cluster parity tests.

    Replays a trace arrival by arrival on *any* backend with an
    identical pull/kill/join interleaving, so the decision log, package
    sequences and counter totals of the real engine and the DES can be
    compared structurally (the cluster twin of the traffic module's
    ``replay_trace_lockstep``).

    A ``kill`` is applied with work genuinely in flight: the driver
    pulls one package per live unit and *holds* them, declares the
    victim dead (harvesting its held package for re-issue), then
    dispatches only the survivors' held packages. Both backends thus
    agree bit-for-bit on which attempt was lost.

    Args:
        trace: the arrival sequence to replay.
        loop: an :class:`ExecutionLoop` over the backend under test.
        make_launch: ``(arrival, loop) -> LaunchState`` payload factory.
        events: ``(arrival_index, action)`` pairs — the action
            (``kill:<u>``/``leave:<u>``/``join:<u>``) is applied right
            after that arrival is offered.
        max_sweeps: drain-phase safety bound.

    Returns:
        ``(admitted, shed)`` launch lists, in arrival order.
    """
    backend = loop.backend
    names = list(loop.unit_names)
    n = len(names)
    admitted: list[LaunchState] = []
    shed: list[LaunchState] = []
    ev_of: dict[int, list[str]] = {}
    for idx, action in events:
        ev_of.setdefault(int(idx), []).append(str(action))

    def sweep(now: float, force: bool) -> bool:
        progressed = False
        for u in range(n):
            work = loop.pull(u, now=now, force_flush=force)
            if work is None:
                continue
            launch, pkg = work
            backend.dispatch(u, launch, pkg)
            loop.complete(launch, pkg)
            progressed = True
        return progressed

    def apply(action: str, now: float) -> None:
        akind, _, token = action.partition(":")
        u = _resolve_unit(token, names)
        if akind == "kill":
            held = []
            for j in range(n):
                if j in loop.dead_units:
                    continue
                w = loop.pull(j, now=now)
                if w is not None:
                    held.append((j, w))
            loop.unit_lost(u)
            for j, (launch, pkg) in held:
                if j == u:
                    continue    # the lost attempt: harvested, never run
                backend.dispatch(j, launch, pkg)
                loop.complete(launch, pkg)
        elif akind == "leave":
            # graceful scale-in: the unit leaves idle, nothing in flight
            # to disown (only scheduler reservations get harvested)
            loop.unit_lost(u)
        elif akind == "join":
            loop.unit_joined(u)
        else:
            raise ValueError(f"unknown lockstep action {action!r}")

    for idx, a in enumerate(trace.arrivals):
        launch = make_launch(a, loop)
        launch.t_submit = a.t
        if launch.deadline is None and a.slo_ms is not None:
            launch.deadline = a.t + a.slo_ms / 1e3
        if loop.offer(launch, now=a.t):
            admitted.append(launch)
        else:
            shed.append(launch)
        for action in ev_of.get(idx, ()):
            apply(action, a.t)
        sweep(a.t, False)

    t_end = trace.arrivals[-1].t if trace.arrivals else 0.0
    sweeps = 0
    while not loop.drained():
        progressed = sweep(t_end, True)
        sweeps += 1
        if sweeps > max_sweeps or not (progressed or loop.drained()):
            raise AssertionError(
                "cluster lockstep replay wedged: work remains but no live "
                "unit makes progress")
    return admitted, shed


@dataclasses.dataclass
class ClusterReplay:
    """Outcome of replaying one trace through the elastic cluster DES.

    ``lost``/``duplicated`` are the exact-once audit: ``lost`` counts
    arrivals that neither completed nor were shed (or failed cover
    validation), ``duplicated`` counts launches delivered more than
    once. Both must be zero for any plan — that is the tentpole's
    correctness claim, and the cluster benchmark pins it.
    """

    trace: Trace
    min_units: int
    max_units: int
    arrivals: int
    admitted: int
    shed_count: int
    completed: int
    lost: int
    duplicated: int
    reissued: int
    kills: list[tuple[float, int]]
    joins: list[tuple[float, int]]
    scale_events: list[tuple[float, int]]
    latencies_s: list[float]
    launches: list = dataclasses.field(default_factory=list, repr=False)

    def covers(self) -> dict[int, tuple[tuple[int, int], ...]]:
        """Sorted ``(offset, size)`` package cover per delivered launch id.

        The bitwise-identity audit: a run disturbed by kills must produce
        exactly the covers an undisturbed run produces.
        """
        return {e.id: tuple(sorted((p.offset, p.size)
                                   for p in e.stats.packages))
                for e in self.launches if e.stats is not None}

    def data_totals(self) -> dict[int, tuple[int, int, int, int, int]]:
        """Per-launch (dispatches, h2d, h2d_bytes, d2h, d2h_bytes) totals."""
        out = {}
        for e in self.launches:
            if e.stats is None:
                continue
            d = e.stats.data
            out[e.id] = (d.dispatches, d.h2d_copies, int(d.h2d_bytes),
                         d.d2h_copies, int(d.d2h_bytes))
        return out

    def p50_ms(self) -> float:
        """Median completed-launch latency in milliseconds."""
        return _percentile_ms(self.latencies_s, 50)

    def p99_ms(self) -> float:
        """p99 completed-launch latency in milliseconds."""
        return _percentile_ms(self.latencies_s, 99)


def replay_trace_cluster(trace: Trace, units: Sequence[SimUnit], *,
                         admission=None, spec=None, memory=None,
                         plan: Optional[FailurePlan] = None,
                         min_units: Optional[int] = None,
                         autoscale: bool = False,
                         autoscale_opts: Optional[dict] = None,
                         supervise: bool = True,
                         num_packages: int = 8,
                         granularity: int = 1) -> ClusterReplay:
    """Replay a trace through the elastic cluster tier in virtual time.

    The provisioned pool is ``units`` (its length is ``max_units``);
    ``min_units`` of them start active and the rest are dormant slots an
    :class:`Autoscaler` (when ``autoscale``) activates under sustained
    backlog. Scripted deaths/joins come from ``plan``.

    Args:
        trace: the arrival sequence to replay.
        units: provisioned simulated units (length = pool ceiling).
        admission: policy name/config/spec section (``None``: spec's).
        spec: optional ``CoexecSpec`` supplying admission/memory.
        memory: memory model override (default spec's, else USM).
        plan: scripted ``kill``/``join`` timeline.
        min_units: initially active units (default: all of them).
        autoscale: resize between ``min_units`` and the full pool on
            admission queue depth.
        autoscale_opts: :class:`Autoscaler` keyword overrides.
        supervise: keep a :class:`Supervisor` in the loop (share and
            membership bookkeeping; scripted kills route through it).
        num_packages: dynamic-scheduler packages per launch.
        granularity: package alignment in work-items.

    Returns:
        The :class:`ClusterReplay` audit + latency record.
    """
    n = len(units)
    lo = n if min_units is None else int(min_units)
    active = list(units)[:lo]
    cfg = _resolve_config(admission, spec, active)
    if memory is None:
        memory = (spec.memory_model() if spec is not None
                  else MemoryModel.USM)
    depth = int(spec.units.pipeline_depth) if spec is not None else 1
    backend = ClusterSimBackend(units, memory, MemoryCosts(),
                                pipeline_depth=depth)
    loop = ExecutionLoop(backend, [u.name for u in units], cfg)
    supervisor = Supervisor(loop) if supervise else None
    pool = UnitPool(loop, min_units=lo, supervisor=supervisor,
                    speeds=[u.speed for u in units])
    scaler = (Autoscaler(pool, **(autoscale_opts or {}))
              if autoscale else None)

    entries = []
    for a in trace.arrivals:
        wl = Workload("traffic", a.items, 8.0, 8.0, 1e4)
        sched = DynamicScheduler(a.items, n,
                                 num_packages=min(num_packages, a.items),
                                 granularity=granularity)
        entry = _SimLaunchState(loop.next_id(), sched, wl, tenant=a.tenant,
                                weight=a.weight, t_submit=a.t)
        if a.slo_ms is not None:
            entry.deadline = a.t + a.slo_ms / 1e3
        entries.append(entry)

    backend.run(loop, entries, plan=plan, supervisor=supervisor,
                autoscaler=scaler)

    delivered = backend.delivered
    seen: collections.Counter = collections.Counter(e.id for e in delivered)
    duplicated = sum(c - 1 for c in seen.values() if c > 1)
    lost = len(entries) - len(seen) - len(backend.shed)
    return ClusterReplay(
        trace=trace, min_units=lo, max_units=n,
        arrivals=len(entries),
        admitted=len(entries) - len(backend.shed),
        shed_count=len(backend.shed),
        completed=len(delivered),
        lost=lost, duplicated=duplicated,
        reissued=loop.reissued,
        kills=list(backend.kills), joins=list(backend.joins),
        scale_events=list(backend.scale_events),
        latencies_s=[e.stats.total_s for e in delivered
                     if e.stats is not None],
        launches=list(delivered))
