"""Open-loop traffic: seeded trace synthesis, JSON replay, SLO stats.

Every sweep the repo ran before this module was closed-loop — submit a
fixed batch, drain — which hides exactly the tail behavior the paper's
time-constrained setting cares about. This module supplies the missing
open-loop side as *data*, not as another execution path: a
:class:`Trace` is a plain list of timed :class:`Arrival` records that
replays through the one shared :class:`~repro.core.exec.ExecutionLoop`
on either substrate.

Two replay modes cover the two questions asked of a trace:

* :func:`replay_trace_sim` pushes the trace through
  :func:`~repro.core.sim.simulate_multi`'s event pump for *metrics* —
  virtual-time per-tenant p50/p99 latency, deadline-miss rate and shed
  fraction under the calibrated cost model.
* :func:`replay_trace_lockstep` drives any backend (real engine units or
  the DES) with a deterministic trace-timed serve order for *structure*
  — the accept/shed decision sequence and the fusion groupings. Because
  admission decisions depend only on the arrival sequence and the
  config (the shed estimator keeps its own virtual finish horizon; see
  :meth:`~repro.core.admission.AdmissionController.offer`), the same
  trace produces the same decision log on both substrates — the parity
  the trace-replay harness pins.

Synthesis is deterministic and *scale-stable*: unit-rate exponential
gaps are drawn once from the seed and divided by the offered rate, so
the same seed at a higher rate yields the exact same arrival sequence
compressed in time — which is what makes "deadline-miss rate is
monotone in offered load" a well-posed single-seed assertion.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional, Sequence, Union

import numpy as np

from .admission import AdmissionConfig, coerce_admission
from .scheduler import DynamicScheduler
from .sim import LaunchSpec, MultiSimResult, Workload, simulate_multi
from .units import SimUnit

__all__ = [
    "Arrival", "Trace", "TenantRow", "TrafficReplay", "synthesize_trace",
    "capacity_items_per_s", "replay_trace_sim", "replay_trace_lockstep",
    "tenant_rows",
]

TRACE_VERSION = 1

# Modeled bytes moved per work-item for the synthetic serving workload —
# small and uniform so traffic replays stress scheduling, not bandwidth.
_BYTES_PER_ITEM = 8.0
_WORKING_SET = 1e4

# Default derating of raw unit speeds when the shed estimator's
# ``shed_rate`` is not configured: serialized per-package host costs
# (launch + collect) eat a measurable slice of nominal capacity under
# sustained load, and an estimator fed the raw sum admits launches the
# host can never finish on time.
SHED_RATE_MARGIN = 0.8


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: who asks for how much work, when.

    Attributes:
        t: absolute arrival time in seconds from trace start.
        tenant: fairness flow the request belongs to.
        items: launch index-space size (work-items).
        weight: tenant's relative WFQ share.
        slo_ms: relative deadline in milliseconds (``None`` defers to
            the admission config's ``slo_ms`` default, if any).
    """

    t: float
    tenant: str
    items: int
    weight: float = 1.0
    slo_ms: Optional[float] = None

    def deadline(self) -> Optional[float]:
        """Absolute deadline in trace seconds (``None`` without an SLO)."""
        if self.slo_ms is None:
            return None
        return self.t + self.slo_ms / 1e3


@dataclasses.dataclass(frozen=True)
class Trace:
    """An ordered open-loop arrival sequence plus its provenance.

    Traces are artifacts: :meth:`to_json`/:meth:`from_json` round-trip
    losslessly, so a synthesized trace can be committed and replayed
    byte-identically by CI on either backend.
    """

    arrivals: tuple[Arrival, ...]
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.arrivals)

    def tenants(self) -> list[str]:
        """Distinct tenant names in first-arrival order."""
        seen: dict[str, None] = {}
        for a in self.arrivals:
            seen.setdefault(a.tenant)
        return list(seen)

    def duration_s(self) -> float:
        """Last arrival time (0.0 for an empty trace)."""
        return self.arrivals[-1].t if self.arrivals else 0.0

    def offered_rate(self) -> float:
        """Mean offered arrival rate in launches/s over the trace."""
        d = self.duration_s()
        return len(self.arrivals) / d if d > 0 else 0.0

    def scaled(self, factor: float) -> "Trace":
        """The same arrival sequence with time compressed by ``factor``.

        Args:
            factor: load multiplier; every timestamp is divided by it,
                so ``factor > 1`` offers the identical sequence faster.

        Returns:
            A new trace (meta carries the applied factor).
        """
        if factor <= 0:
            raise ValueError("factor must be positive")
        arrivals = tuple(dataclasses.replace(a, t=a.t / factor)
                         for a in self.arrivals)
        meta = dict(self.meta)
        meta["scaled_by"] = meta.get("scaled_by", 1.0) * factor
        return Trace(arrivals, meta)

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-dict form, tagged with a trace schema version."""
        return {
            "version": TRACE_VERSION,
            "meta": dict(self.meta),
            "arrivals": [
                {"t": a.t, "tenant": a.tenant, "items": a.items,
                 "weight": a.weight, "slo_ms": a.slo_ms}
                for a in self.arrivals],
        }

    @classmethod
    def from_dict(cls, data: dict) -> "Trace":
        """Lossless inverse of :meth:`to_dict`.

        Args:
            data: a :meth:`to_dict` result.

        Returns:
            The deserialized trace.

        Raises:
            ValueError: unsupported trace schema version.
        """
        version = data.get("version", TRACE_VERSION)
        if version != TRACE_VERSION:
            raise ValueError(f"unsupported trace version {version!r} "
                             f"(this build reads version {TRACE_VERSION})")
        arrivals = tuple(
            Arrival(t=float(a["t"]), tenant=str(a["tenant"]),
                    items=int(a["items"]),
                    weight=float(a.get("weight", 1.0)),
                    slo_ms=a.get("slo_ms"))
            for a in data.get("arrivals", []))
        return cls(arrivals, dict(data.get("meta", {})))

    def to_json(self, **dumps_kw) -> str:
        """JSON form of :meth:`to_dict` (sorted keys by default)."""
        dumps_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        """Inverse of :meth:`to_json`.

        Args:
            text: a JSON document produced by :meth:`to_json`.

        Returns:
            The deserialized trace.
        """
        return cls.from_dict(json.loads(text))

    def save(self, path: Union[str, pathlib.Path]) -> None:
        """Write the trace as pretty-printed JSON.

        Args:
            path: destination file path.
        """
        pathlib.Path(path).write_text(self.to_json(indent=2) + "\n")

    @classmethod
    def load(cls, path: Union[str, pathlib.Path]) -> "Trace":
        """Read a trace written by :meth:`save`.

        Args:
            path: source file path.

        Returns:
            The deserialized trace.
        """
        return cls.from_json(pathlib.Path(path).read_text())


def synthesize_trace(arrivals: int, rate: float, *,
                     arrival: str = "poisson",
                     tenants: Union[int, Sequence[str]] = 4,
                     mix: Optional[Sequence[float]] = None,
                     tenant_weights: Optional[Sequence[float]] = None,
                     items: int = 1024,
                     item_jitter: float = 0.0,
                     slo_ms: Optional[float] = None,
                     burst: float = 4.0,
                     burst_duty: float = 0.2,
                     burst_cycle: int = 128,
                     seed: int = 0) -> Trace:
    """Deterministically synthesize an open-loop arrival trace.

    ``"poisson"`` draws i.i.d. exponential inter-arrival gaps at
    ``rate``. ``"burst"`` is an on/off modulated Poisson process: during
    the on phase (``burst_duty`` of each cycle) the instantaneous rate
    is ``burst * rate``; the off phase runs at the complementary rate
    ``(1 - burst_duty*burst) / (1 - burst_duty) * rate`` so the
    time-averaged rate stays ``rate``. All randomness comes from
    ``seed``, and gaps are unit-rate samples divided by the phase rate —
    so the same seed at a different ``rate`` produces the identical
    arrival sequence with time rescaled exactly.

    Args:
        arrivals: number of arrivals to generate.
        rate: mean offered rate in launches/s (must be positive).
        arrival: ``"poisson"`` or ``"burst"``.
        tenants: tenant count (named ``t0..tN-1``) or explicit names.
        mix: per-tenant arrival probabilities (default uniform).
        tenant_weights: per-tenant WFQ weights (default all 1.0).
        items: work-items per launch before jitter.
        item_jitter: log2-uniform spread of per-arrival item counts —
            each launch gets ``items * 2**U(-j, +j)`` items (0 = every
            launch identical).
        slo_ms: relative deadline stamped on every arrival (or ``None``).
        burst: on-phase rate multiplier (``arrival="burst"`` only).
        burst_duty: on-phase fraction of each cycle, in (0, 1);
            ``burst * burst_duty`` must stay below 1.
        burst_cycle: expected arrivals per on/off cycle (sets the cycle
            period to ``burst_cycle / rate`` seconds).
        seed: PRNG seed.

    Returns:
        A :class:`Trace` with synthesis parameters recorded in ``meta``.

    Raises:
        ValueError: non-positive counts/rate, unknown arrival process,
            or a burst shape whose off-phase rate is not positive.
    """
    if arrivals < 1:
        raise ValueError("arrivals must be a positive integer")
    if rate <= 0:
        raise ValueError("rate must be positive")
    if arrival not in ("poisson", "burst"):
        raise ValueError(f"unknown arrival process {arrival!r}; "
                         f"choose from ['poisson', 'burst']")
    if arrival == "burst":
        if burst < 1:
            raise ValueError("burst must be >= 1")
        if not 0 < burst_duty < 1:
            raise ValueError("burst_duty must be in (0, 1)")
        if burst * burst_duty >= 1:
            raise ValueError("burst * burst_duty must be < 1 so the "
                             "off-phase rate stays positive")
    names = ([f"t{i}" for i in range(int(tenants))]
             if isinstance(tenants, int) else [str(t) for t in tenants])
    if not names:
        raise ValueError("at least one tenant is required")
    probs = None
    if mix is not None:
        p = np.asarray(mix, dtype=np.float64)
        if len(p) != len(names) or np.any(p < 0) or p.sum() <= 0:
            raise ValueError("mix must be non-negative, one per tenant")
        probs = p / p.sum()
    w_of = {n: 1.0 for n in names}
    if tenant_weights is not None:
        if len(tenant_weights) != len(names):
            raise ValueError("tenant_weights must have one entry per "
                             "tenant")
        w_of = {n: float(w) for n, w in zip(names, tenant_weights)}

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(size=arrivals)          # unit-rate samples
    tenant_idx = rng.integers(0, len(names), size=arrivals) \
        if probs is None else rng.choice(len(names), size=arrivals, p=probs)
    jitter = (np.exp2(rng.uniform(-item_jitter, item_jitter,
                                  size=arrivals))
              if item_jitter > 0 else np.ones(arrivals))

    low = ((1.0 - burst_duty * burst) / (1.0 - burst_duty)
           if arrival == "burst" else 1.0)
    cycle_s = burst_cycle / rate
    on_s = burst_duty * cycle_s
    out: list[Arrival] = []
    t = 0.0
    for i in range(arrivals):
        factor = burst if (arrival == "burst"
                           and t % cycle_s < on_s) else \
            (low if arrival == "burst" else 1.0)
        t += gaps[i] / (rate * factor)
        n_items = max(1, int(round(items * jitter[i])))
        name = names[tenant_idx[i]]
        out.append(Arrival(t=t, tenant=name, items=n_items,
                           weight=w_of[name], slo_ms=slo_ms))
    meta = {"arrival": arrival, "rate": rate, "seed": seed,
            "items": items, "item_jitter": item_jitter,
            "tenants": names, "slo_ms": slo_ms}
    if arrival == "burst":
        meta.update(burst=burst, burst_duty=burst_duty,
                    burst_cycle=burst_cycle)
    return Trace(tuple(out), meta)


def capacity_items_per_s(units: Sequence[SimUnit]) -> float:
    """Aggregate modeled serving capacity of a DES unit set.

    Args:
        units: the simulated Coexecution Units.

    Returns:
        Summed unit speeds in work-items/s — the natural default for
        the shed estimator's ``shed_rate`` and for converting a
        ``--load`` multiple into an arrival rate.
    """
    return float(sum(u.speed for u in units))


@dataclasses.dataclass
class TenantRow:
    """Per-tenant serving outcome of one trace replay."""

    tenant: str
    arrivals: int
    admitted: int
    shed: int
    p50_ms: float
    p99_ms: float
    miss_rate: float


@dataclasses.dataclass
class TrafficReplay:
    """Outcome of replaying one trace through the DES event pump.

    Attributes:
        trace: the replayed trace.
        result: the underlying multi-launch simulation result.
        rows: per-tenant latency/SLO rows (stable tenant order).
    """

    trace: Trace
    result: MultiSimResult
    rows: list[TenantRow]

    @property
    def decisions(self) -> list[tuple[str, str]]:
        """The accept/shed decision sequence, in offer order."""
        return self.result.decisions

    @property
    def fusion_groups(self) -> list[tuple[str, ...]]:
        """Member-tenant tuples of every materialized fused batch."""
        return self.result.fusion_groups

    def admitted_latencies_ms(self) -> list[float]:
        """Latencies of admitted launches in milliseconds."""
        return [r.latency_s * 1e3 for r in self.result.launches]

    def p99_ms(self) -> float:
        """Admitted-launch p99 latency in milliseconds (0 when empty)."""
        lats = self.admitted_latencies_ms()
        return float(np.percentile(lats, 99)) if lats else 0.0

    def p50_ms(self) -> float:
        """Admitted-launch median latency in milliseconds (0 when empty)."""
        lats = self.admitted_latencies_ms()
        return float(np.percentile(lats, 50)) if lats else 0.0

    def miss_rate(self) -> float:
        """Deadline-miss rate over admitted deadline-carrying launches."""
        return self.result.deadline_miss_rate()

    def shed_fraction(self) -> float:
        """Shed launches as a fraction of everything offered."""
        return self.result.shed_fraction()


def _percentile_ms(latencies_s: list[float], q: float) -> float:
    return float(np.percentile([v * 1e3 for v in latencies_s], q)) \
        if latencies_s else 0.0


def tenant_rows(trace: Trace, result: MultiSimResult) -> list[TenantRow]:
    """Fold a replay result into per-tenant latency/SLO rows.

    Args:
        trace: the replayed trace (fixes tenant order).
        result: the simulation result for that trace.

    Returns:
        One :class:`TenantRow` per tenant, in first-arrival order.
    """
    offered: dict[str, int] = {}
    for a in trace.arrivals:
        offered[a.tenant] = offered.get(a.tenant, 0) + 1
    by_tenant: dict[str, list] = {t: [] for t in offered}
    for r in result.launches:
        by_tenant.setdefault(r.tenant, []).append(r)
    shed_of: dict[str, int] = {}
    for s in result.shed:
        shed_of[s.tenant] = shed_of.get(s.tenant, 0) + 1
    rows = []
    for tenant in trace.tenants():
        served = by_tenant.get(tenant, [])
        lats = [r.latency_s for r in served]
        with_slo = [r for r in served if r.deadline is not None]
        miss = (sum(bool(r.deadline_missed) for r in with_slo)
                / len(with_slo)) if with_slo else 0.0
        rows.append(TenantRow(
            tenant=tenant, arrivals=offered.get(tenant, 0),
            admitted=len(served), shed=shed_of.get(tenant, 0),
            p50_ms=_percentile_ms(lats, 50), p99_ms=_percentile_ms(lats, 99),
            miss_rate=miss))
    return rows


def _resolve_config(admission, spec,
                    units: Sequence[SimUnit]) -> AdmissionConfig:
    """Admission config with the shed estimator's rate defaulted.

    The shed predictor needs a service-rate estimate; when shedding is
    on and no explicit ``shed_rate`` was configured, the modeled
    capacity of the unit set is the deterministic default both replay
    modes share — which is what keeps real/sim decisions identical.
    """
    if admission is None and spec is not None:
        cfg = spec.admission_config()
    else:
        cfg = coerce_admission(admission)
    if cfg.shed and cfg.shed_rate is None:
        cfg = dataclasses.replace(
            cfg, shed_rate=SHED_RATE_MARGIN * capacity_items_per_s(units))
    return cfg


def replay_trace_sim(trace: Trace, units: Sequence[SimUnit], *,
                     admission=None, spec=None, memory=None,
                     num_packages: int = 8,
                     granularity: int = 1) -> TrafficReplay:
    """Replay a trace through the DES event pump for latency/SLO stats.

    Each arrival becomes one :class:`~repro.core.sim.LaunchSpec` with a
    uniform synthetic workload sized by the arrival, submitted at its
    trace time; :func:`~repro.core.sim.simulate_multi` then runs the
    shared control plane in virtual time.

    Args:
        trace: the arrival sequence to replay.
        units: simulated Coexecution Units.
        admission: policy name, :class:`~.admission.AdmissionConfig` or
            ``AdmissionSpec`` (``None`` takes the spec's section).
        spec: optional ``CoexecSpec`` supplying admission/memory.
        memory: memory model override (default: spec's, else USM).
        num_packages: packages per launch for the dynamic scheduler.
        granularity: package alignment in work-items.

    Returns:
        A :class:`TrafficReplay` with the sim result and tenant rows.
    """
    cfg = _resolve_config(admission, spec, units)
    n = len(units)
    specs = []
    for a in trace.arrivals:
        wl = Workload("traffic", a.items, _BYTES_PER_ITEM, _BYTES_PER_ITEM,
                      _WORKING_SET)
        sched = DynamicScheduler(a.items, n,
                                 num_packages=min(num_packages, a.items),
                                 granularity=granularity)
        specs.append(LaunchSpec(workload=wl, scheduler=sched,
                                tenant=a.tenant, weight=a.weight,
                                t_submit=a.t,
                                deadline_s=None if a.slo_ms is None
                                else a.slo_ms / 1e3))
    result = simulate_multi(specs, units, admission=cfg,
                            memory=memory, spec=spec)
    return TrafficReplay(trace=trace, result=result,
                         rows=tenant_rows(trace, result))


def replay_trace_lockstep(trace: Trace, loop, make_launch, *,
                          pulls_per_arrival: int = 1,
                          max_sweeps: int = 1_000_000):
    """Drive any backend through a trace with a deterministic serve order.

    The structural twin of :func:`replay_trace_sim`: arrivals are
    offered at their trace times (``loop.offer(..., now=a.t)``), and
    after each arrival every unit is offered ``pulls_per_arrival``
    pulls at the same trace time — then the loop drains with forced
    fusion flushes. Applied to a ``RealBackend`` and a ``SimBackend``
    with the same trace and config, every control-plane decision — the
    accept/shed sequence in ``loop.admission.decision_log`` and the
    fusion groupings in ``loop.admission.fusion_log`` — must come out
    identical, because nothing in the serve order depends on backend
    time.

    Args:
        trace: the arrival sequence to replay.
        loop: an :class:`~repro.core.exec.ExecutionLoop` over either
            backend, configured with the admission config under test
            (set ``shed_rate`` explicitly — see :func:`_resolve_config`).
        make_launch: callable ``(arrival, loop) -> LaunchState`` that
            builds the backend-typed launch (scheduler, payload,
            ``fuse_key``/``fuse_bucket``) for one arrival.
        pulls_per_arrival: serve sweeps interleaved per arrival.
        max_sweeps: drain-phase safety bound.

    Returns:
        ``(admitted, shed)`` lists of the backend-typed launch states,
        in arrival order.

    Raises:
        AssertionError: the drain phase wedged or did not converge.
    """
    backend = loop.backend
    n_units = len(loop.unit_names)
    admitted, shed = [], []

    def sweep(now: float, force_flush: bool) -> bool:
        progressed = False
        for u in range(n_units):
            work = loop.pull(u, now=now, force_flush=force_flush)
            if work is None:
                continue
            launch, pkg = work
            backend.dispatch(u, launch, pkg)
            loop.complete(launch, pkg)
            progressed = True
        return progressed

    for a in trace.arrivals:
        launch = make_launch(a, loop)
        launch.t_submit = a.t
        if a.slo_ms is not None:
            launch.deadline = a.t + a.slo_ms / 1e3
        if not loop.offer(launch, now=a.t):
            shed.append(launch)
            continue
        admitted.append(launch)
        for _ in range(pulls_per_arrival):
            sweep(a.t, False)
    t_end = trace.duration_s()
    for _ in range(max_sweeps):
        if loop.drained():
            return admitted, shed
        if not sweep(t_end, True) and not loop.drained():
            raise AssertionError("lockstep replay wedged with work "
                                 "outstanding")
    raise AssertionError("lockstep replay did not converge")
