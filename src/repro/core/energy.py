"""Energy accounting (paper §5.2) — RAPL replaced by a calibrated model.

The paper measures package energy with RAPL counters split into three
regions: CPU cores, GPU, and uncore+DRAM. This container has no RAPL (and the
TPU target has no RAPL at all), so energy is *modeled* from the execution
timeline produced by the simulator or the real runtime's profiler:

    E_unit  = P_busy * t_busy + P_idle * t_idle          (per unit)
    E_pkg   = P_uncore_dram * T_total                    (shared)
    E_total = sum(E_unit) + E_pkg

Power constants are calibrated to the paper's platform (Intel i5-7500 Kaby
Lake, 4C/4T, HD Graphics 630 GT2) and to TPU v5e for fleet projections.
Energy-Delay Product (EDP) and the paper's efficiency ratio
``EDP_gpu / EDP_coexec`` are computed exactly as in §5.2.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Busy/idle watts per unit class plus the shared uncore+DRAM term.

    Attributes:
        busy_w: active-power watts per unit kind ("cpu"/"gpu"/"tpu").
        idle_w: idle-power watts per unit kind.
        uncore_dram_w: shared uncore + DRAM watts, drawn for the whole
            execution horizon regardless of which units are busy.
    """

    busy_w: Mapping[str, float]
    idle_w: Mapping[str, float]
    uncore_dram_w: float

    def unit_energy(self, kind: str, busy_s: float, idle_s: float) -> float:
        """Joules one unit kind burns over its busy and idle seconds."""
        return self.busy_w[kind] * busy_s + self.idle_w[kind] * idle_s

    def total_energy(self, busy: Mapping[str, float], horizon_s: float) -> float:
        """`busy` maps unit kind → busy seconds; idle = horizon - busy."""
        e = self.uncore_dram_w * horizon_s
        for kind, b in busy.items():
            e += self.unit_energy(kind, b, max(0.0, horizon_s - b))
        return e


# Calibrated to the paper's testbed: i5-7500 + Gen9.5 GT2 iGPU share a 65 W
# package TDP — when both are active the cores DVFS-throttle, and the
# co-executed kernels are largely memory-bound, so the RAPL *cores* domain
# sits near ~20 W busy / ~5 W idle rather than the ~44 W AVX peak; iGPU ~13 W
# busy, uncore+DRAM ~9 W. This calibration jointly reproduces Fig. 6
# ("GPU-only is the minimum-energy option except Taylor/Rap") and Fig. 7
# (EDP favorable to co-execution everywhere, geomean ≈ 1.7x with
# HGuided+USM). Absolute Joules are model outputs, not measurements.
PAPER_POWER = PowerModel(
    busy_w={"cpu": 20.0, "gpu": 13.0},
    idle_w={"cpu": 5.0, "gpu": 1.5},
    uncore_dram_w=9.0,
)

# TPU v5e class: ~170-200 W chip under MXU load, ~60 W HBM-idle; host share
# folded into the uncore term. Used for fleet-level projections only.
TPU_POWER = PowerModel(
    busy_w={"tpu": 185.0, "cpu": 90.0},
    idle_w={"tpu": 60.0, "cpu": 25.0},
    uncore_dram_w=30.0,
)


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Per-region Joules + derived metrics, mirroring Fig. 6/7.

    Attributes:
        per_unit_J: modeled Joules per unit kind (busy + idle share).
        uncore_dram_J: shared uncore/DRAM Joules over the horizon.
        runtime_s: execution horizon the report integrates over.
    """

    per_unit_J: Mapping[str, float]
    uncore_dram_J: float
    runtime_s: float

    @property
    def total_J(self) -> float:
        """Total modeled energy across all regions."""
        return sum(self.per_unit_J.values()) + self.uncore_dram_J

    @property
    def edp(self) -> float:
        """Energy-Delay Product (J·s) — the paper's efficiency metric."""
        return self.total_J * self.runtime_s


def energy_report(power: PowerModel, busy_s: Mapping[str, float],
                  horizon_s: float) -> EnergyReport:
    """Integrate a busy-seconds timeline into an :class:`EnergyReport`."""
    per_unit = {
        kind: power.unit_energy(kind, b, max(0.0, horizon_s - b))
        for kind, b in busy_s.items()
    }
    return EnergyReport(per_unit_J=per_unit,
                        uncore_dram_J=power.uncore_dram_w * horizon_s,
                        runtime_s=horizon_s)


def edp_ratio(baseline: EnergyReport, coexec: EnergyReport) -> float:
    """Paper Fig. 7: EDP_baseline / EDP_coexec; > 1 ⇒ co-execution wins."""
    return baseline.edp / coexec.edp


def geomean(xs: Sequence[float]) -> float:
    """Geometric mean (the paper's cross-benchmark aggregate)."""
    if not xs:
        raise ValueError("geomean of empty sequence")
    prod = 1.0
    for x in xs:
        prod *= x
    return prod ** (1.0 / len(xs))
