"""Discrete-event simulation of the Commander loop (paper Figs. 2a/4).

The simulator replays the exact Commander/Coexecution-Unit protocol:

  unit idle ──request──▶ Scheduler.next_package ──▶ host launches package
  (host is a serial resource: launch + collection costs serialize on it,
  reproducing the paper's "CPU manages the runtime resources as the host,
  increasing the CPU load") ──▶ unit computes ──▶ host collects output
  (cost depends on the memory model: USM ≈ free, Buffers ∝ bytes).

Compute time for a package is ``sum(weight[i]**alpha_u for i in range) /
speed_u`` — `weights` capture data irregularity (Mandelbrot iteration
counts, Ray scene density, Rap row lengths); regular kernels have
weights = 1. While more than one unit is busy and the combined working set
exceeds the shared LLC, a contention factor slows both units (the paper's
MatMul observation in §5.3).

The output timeline feeds the paper's metrics: balance = T_gpu/T_cpu,
speedup = T_fastest_alone / T_coexec, energy via core.energy.

Control-plane decisions are NOT made here. Both :func:`simulate` (one
launch) and :func:`simulate_multi` (concurrent launches) drive the exact
:class:`~repro.core.exec.ExecutionLoop` the real engine's worker threads
drive — admission pulls (FIFO, WFQ, preemptive pull-capping), launch
fusion and its de-mux, finalization and counter attribution all run in
that one shared implementation. This module contributes only the
:class:`SimBackend` substrate: a virtual clock, the calibrated package
cost model, and the event queue that advances time — so fairness, fusion
and preemption behavior measured here is structurally the behavior of
the real engine, in deterministic virtual time.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from .admission import AdmissionConfig, coerce_admission, fusion_bucket
from .dataplane import DataPlaneCounters
from .energy import EnergyReport, PowerModel, energy_report
from .exec import Backend, ExecutionLoop, LaunchState
from .memory import MemoryCosts, MemoryModel
from .package import Package
from .scheduler import DynamicScheduler, Scheduler


@dataclasses.dataclass(frozen=True)
class Workload:
    """A data-parallel problem as the DES sees it.

    weights — per-item relative cost (mean ≈ 1), or None for regular
              kernels. Stored as a float64 array of length `total`.
    """

    name: str
    total: int
    bytes_in_per_item: float
    bytes_out_per_item: float
    working_set_bytes: float
    weights: Optional[np.ndarray] = None
    # LLC sensitivity: 1.0 for kernels with heavy temporal reuse (MatMul —
    # the paper's §5.3 hardware-counter analysis: "the LLC memory suffers
    # constant invalidations between CPU and GPU"); 0.0 for streaming
    # kernels whose working set never profits from the LLC.
    contention_scale: float = 0.0

    def weights_prefix(self) -> Optional[np.ndarray]:
        """Prefix-summed per-item weights (None for regular kernels)."""
        if self.weights is None:
            return None
        p = np.zeros(self.total + 1, dtype=np.float64)
        np.cumsum(self.weights, out=p[1:])
        return p


@dataclasses.dataclass
class SimResult:
    """Timeline + metrics of one simulated co-execution.

    ``data`` mirrors the real engine's per-launch
    :class:`~.dataplane.DataPlaneCounters`, and since both substrates now
    share one control plane it is literally produced by the same
    finalization code (the modeled dispatch count and the staging copies
    the memory model implies: one H2D and one D2H per package under
    BUFFERS, none under USM) — spec-driven real-vs-sim comparisons read
    the same counter surface.
    """

    workload: str
    policy: str
    memory: str
    total_s: float
    unit_finish_s: dict[str, float]      # last compute completion per unit
    unit_busy_s: dict[str, float]        # total compute seconds per unit
    host_busy_s: float                   # serialized launch+collect seconds
    packages: list[Package]
    num_packages: int
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    def balance(self, fast: str = "gpu", slow: str = "cpu") -> float:
        """Paper's balancing efficiency T_fast/T_slow (1.0 = perfect)."""
        num = self.unit_finish_s.get(fast, 0.0)
        den = self.unit_finish_s.get(slow, 0.0)
        return num / den if den > 0 else float("inf")

    def energy(self, power: PowerModel,
               kinds: dict[str, str]) -> EnergyReport:
        """Model this run's energy from its busy timeline (paper §5.2)."""
        busy: dict[str, float] = {}
        for name, b in self.unit_busy_s.items():
            kind = kinds[name]
            busy[kind] = busy.get(kind, 0.0) + b
        # host management burns CPU-core time on top of CPU compute
        busy["cpu"] = busy.get("cpu", 0.0) + self.host_busy_s
        return energy_report(power, busy, self.total_s)


def _count_package(counters: DataPlaneCounters, memory: MemoryModel,
                   in_bytes: float, out_bytes: float) -> None:
    """Model one package's data-plane accounting (mirrors the real planes)."""
    counters.dispatches += 1
    if memory is MemoryModel.BUFFERS:
        counters.h2d_copies += 1
        counters.h2d_bytes += int(in_bytes)
        counters.d2h_copies += 1
        counters.d2h_bytes += int(out_bytes)


def _item_costs(workload: Workload, unit: "SimUnit") -> np.ndarray:
    """Per-item seconds for `unit` (prefix-summed by the caller)."""
    if workload.weights is None:
        return None
    w = workload.weights.astype(np.float64)
    if unit.alpha != 1.0:
        # NOT renormalized: `speed` is the unit's throughput on *uniform*
        # (weight=1) data; alpha>1 genuinely slows the unit on heavy items
        # (branch divergence on the paper's iGPU). This is what makes
        # irregular co-execution speedups exceed the uniform capacity bound
        # 1 + s_cpu/s_gpu, as observed for Ray (1.48) and Rap (2.46).
        w = np.power(w, unit.alpha)
    return np.concatenate([[0.0], np.cumsum(w)])


class _SimLaunchState(LaunchState):
    """Simulator payload of one launch: the modeled workload + counters."""

    __slots__ = ("workload", "counters")

    def __init__(self, launch_id: int, scheduler: Scheduler,
                 workload: Workload, *, tenant: Optional[str] = None,
                 weight: float = 1.0, t_submit: float = 0.0):
        super().__init__(launch_id, scheduler, tenant=tenant, weight=weight,
                         t_submit=t_submit)
        self.workload = workload
        self.counters = DataPlaneCounters()


class SimBackend(Backend):
    """Virtual-clock substrate: models package costs instead of running them.

    Owns the event queue (`(time, tiebreak, unit)` heap), the calibrated
    cost model (launch/collect/contention via :class:`MemoryCosts`), the
    per-unit busy/finish timelines and the tenant service curve. It makes
    no scheduling decisions — :meth:`run` asks the shared
    :class:`~repro.core.exec.ExecutionLoop` for every package exactly as
    an engine worker thread does, just in virtual time.
    """

    def __init__(self, units: Sequence["SimUnit"], memory: MemoryModel,
                 costs: MemoryCosts, *, pipeline_depth: int = 1):
        self.units = list(units)
        self.memory = memory
        self.costs = costs
        # mirrors the real engine's per-unit dispatch pipeline: with
        # depth >= 2 a package that arrives back-to-back with the unit's
        # previous compute was staged *during* that compute, so its
        # launch cost no longer delays the device (the host still pays
        # it). The DES runs a two-clock model: scheduler decisions
        # (pull pacing, contention, kill checks) stay on the *serial*
        # clock — `busy_until` keeps the serial horizon, which is what
        # keeps package covers and counter totals depth-invariant for
        # every policy — while the *recorded* package timeline drops
        # the hidden launch costs. Depth 1 reproduces the serial
        # timeline exactly on both clocks.
        self.pipeline_depth = max(1, int(pipeline_depth))
        n = len(self.units)
        self.t = 0.0
        self.counters = DataPlaneCounters()      # run-wide aggregation
        self.busy_until = [0.0] * n              # serial compute horizon
        self._hidden = [0.0] * n  # launch cost hidden per pipeline chain
        self.collector_free = [0.0] * n          # per-unit collection thread
        self.unit_finish = {u.name: 0.0 for u in self.units}
        self.unit_busy = {u.name: 0.0 for u in self.units}
        self.host_busy = 0.0
        self.last_collect = 0.0
        # (t_complete, tenant, items) per dispatched package
        self.service: list[tuple[float, str, int]] = []
        self.delivered: list[_SimLaunchState] = []
        self.shed: list[_SimLaunchState] = []    # rejected at admission
        self._prefix: dict[tuple[int, str], Optional[np.ndarray]] = {}

    # -- substrate contract -------------------------------------------------
    def now(self) -> float:
        """The virtual clock (seconds since simulation start)."""
        return self.t

    def dispatch(self, unit: int, launch: _SimLaunchState,
                 pkg: Package) -> None:
        """Model one package's launch, compute and collection in virtual time.

        Args:
            unit: index of the serving simulated unit.
            launch: the owning launch (its ``workload`` prices the items).
            pkg: the package; its full timeline
                (``t_launch``/``t_complete``/``t_collected``) is filled.
        """
        wl = launch.workload
        u = self.units[unit]
        in_bytes = pkg.size * wl.bytes_in_per_item
        out_bytes = pkg.size * wl.bytes_out_per_item
        _count_package(self.counters, self.memory, in_bytes, out_bytes)
        _count_package(launch.counters, self.memory, in_bytes, out_bytes)

        launch_cost, t_launch, compute_end = \
            self._model_compute(unit, launch, pkg)
        # pipelined overlap: a package pulled back-to-back with this
        # unit's previous compute was staged *during* that compute, so
        # its launch cost is hidden from the recorded device timeline
        # (the host still pays it). The hidden costs accumulate along
        # one back-to-back chain and reset when the pipeline drains;
        # `busy_until` keeps the serial horizon so every scheduling
        # decision is identical to the depth-1 run.
        prestaged = (self.pipeline_depth > 1
                     and self.busy_until[unit] > 0.0
                     and self.busy_until[unit] >= pkg.t_issue - 1e-12)
        if prestaged:
            self._hidden[unit] += launch_cost
        else:
            self._hidden[unit] = 0.0
        shift = self._hidden[unit]
        self.host_busy += launch_cost
        pkg.t_launch = t_launch - shift
        if prestaged:
            # staged while the previous package computed: the recorded
            # issue coincides with the device picking it up
            pkg.t_issue = pkg.t_launch
        self.busy_until[unit] = compute_end
        self.unit_busy[u.name] += compute_end - t_launch
        self.unit_finish[u.name] = max(self.unit_finish[u.name],
                                       compute_end - shift)
        pkg.t_complete = compute_end - shift

        # collection on the unit's manager thread; overlaps the unit's next
        # compute (paper: "overlapping computation and communication") but
        # collections of one unit serialize among themselves.
        collect_start = max(pkg.t_complete, self.collector_free[unit])
        collect_cost = self.costs.collect_cost(self.memory, int(out_bytes))
        self.collector_free[unit] = collect_start + collect_cost
        self.host_busy += collect_cost
        pkg.t_collected = self.collector_free[unit]
        self.last_collect = max(self.last_collect, pkg.t_collected)

    def wait_next_event(self) -> None:
        """No-op: :meth:`run` advances virtual time through its heap."""

    def _model_compute(self, unit: int, launch: _SimLaunchState,
                       pkg: Package) -> tuple[float, float, float]:
        """Price one package without mutating any state.

        Given the backend's *current* busy horizons and the package's
        stamped ``t_issue``, returns ``(launch_cost, t_launch,
        compute_end)`` — the *serial-clock* timeline :meth:`dispatch`
        prices decisions with (:meth:`dispatch` then subtracts the
        pipeline's hidden launch costs from the recorded stamps, never
        from these). Factored out so the elastic-cluster backend can
        ask "would this package finish before its unit's scripted
        death?" and, when not, model the attempt as lost without ever
        charging its cost.
        """
        wl = launch.workload
        u = self.units[unit]
        in_bytes = pkg.size * wl.bytes_in_per_item
        launch_cost = self.costs.launch_cost(self.memory, int(in_bytes))
        t_launch = pkg.t_issue + launch_cost
        # compute; LLC contention applies while any *other* unit is busy
        pfx = self._prefix_for(wl, u)
        if pfx is None:
            base = pkg.size / u.speed
        else:
            base = float(pfx[pkg.offset + pkg.size] - pfx[pkg.offset]) \
                / u.speed
        others_busy = any(self.busy_until[j] > t_launch
                          for j in range(len(self.units)) if j != unit)
        factor = 1.0
        if others_busy and wl.contention_scale > 0.0:
            pen = self.costs.contention_penalty(wl.working_set_bytes)
            factor = 1.0 + wl.contention_scale * (pen - 1.0)
        return launch_cost, t_launch, t_launch + base * factor

    # -- payload hooks ------------------------------------------------------
    def fuse_payload(self, members: list[_SimLaunchState],
                     launch_id: int) -> _SimLaunchState:
        """Lay member workloads end to end into one fused workload.

        The fused index space is the members' item spaces concatenated
        (weights tiled); its scheduler hands out member-aligned packages,
        one per unit, so a batch of N tiny launches costs ~`num_units`
        dispatches. One scheduler unit is one work-item, so
        ``member_span`` (items per member) drives the shared de-mux.

        Args:
            members: the staged same-shaped launches to coalesce.
            launch_id: id assigned by the loop.

        Returns:
            The fused sim launch (tenant/weight set by the loop).
        """
        base = members[0].workload
        k = len(members)
        # bucketed members pad up to the shared power-of-2 bucket (pad
        # items are modeled at unit weight — the engine really computes
        # them); exact-shape fusion has bucket == total, no padding
        T = members[0].fuse_bucket or max(m.workload.total for m in members)
        if any(m.workload.weights is not None for m in members) \
                or any(m.workload.total != T for m in members):
            weights = np.concatenate([np.concatenate([
                m.workload.weights if m.workload.weights is not None
                else np.ones(m.workload.total),
                np.ones(T - m.workload.total)]) for m in members])
        else:
            weights = None
        wl = Workload(
            name=f"fused:{base.name}x{k}", total=k * T,
            bytes_in_per_item=base.bytes_in_per_item,
            bytes_out_per_item=base.bytes_out_per_item,
            working_set_bytes=max(m.workload.working_set_bytes
                                  for m in members),
            weights=weights, contention_scale=base.contention_scale)
        n_units = len(self.units)
        sched = DynamicScheduler(k * T, n_units,
                                 num_packages=min(k, n_units), granularity=T)
        fused = _SimLaunchState(launch_id, sched, wl)
        fused.member_span = T
        fused.wfq_cost_scale = 1
        fused.fuse_bucket = T
        return fused

    def launch_counters(self, launch: _SimLaunchState) -> DataPlaneCounters:
        """The launch's modeled data-plane accounting."""
        return launch.counters.snapshot()

    def on_package(self, launch: _SimLaunchState, pkg: Package) -> None:
        """Record the tenant service curve (fused work credits members)."""
        if launch.members is None:
            self.service.append((pkg.t_complete, launch.tenant, pkg.size))
        else:
            for m, items in ExecutionLoop.member_spans(launch, pkg):
                self.service.append((pkg.t_complete, m.tenant, items))

    def deliver(self, launch: _SimLaunchState) -> None:
        """Collect a finalized launch (stats already populated)."""
        self.delivered.append(launch)

    # -- the event pump -----------------------------------------------------
    def _prefix_for(self, wl: Workload, u: "SimUnit") -> Optional[np.ndarray]:
        key = (id(wl), u.name)
        if key not in self._prefix:
            self._prefix[key] = _item_costs(wl, u)
        return self._prefix[key]

    def run(self, loop: ExecutionLoop,
            entries: Sequence[_SimLaunchState]) -> None:
        """Advance virtual time until every admitted launch finalizes.

        Each Coexecution Unit has its own management thread (paper Fig.
        2a): launch/collect costs are paid on the unit's own timeline,
        not on a global serial host. Units couple only through the shared
        loop (package order under the admission policy) and the
        shared-LLC contention factor; host-side management seconds are
        accumulated for the energy model (the CPU does double duty as
        host — §5.1). Every scheduling decision — whose package an idle
        unit serves, fusion staging/ripening, finalization — is a call
        into ``loop``, identical to an engine worker thread.

        Args:
            loop: the shared control plane built over this backend.
            entries: launches to admit, each at its ``t_submit``.
        """
        pending = collections.deque(sorted(entries,
                                           key=lambda e: e.t_submit))
        evq: list[tuple[float, int, int]] = []  # (t_idle, tiebreak, unit)
        tie = 0
        for i, u in enumerate(self.units):
            heapq.heappush(evq, (u.setup_s, tie, i))
            tie += 1

        while evq:
            t, _, i = heapq.heappop(evq)
            self.t = t
            while pending and pending[0].t_submit <= t + 1e-12:
                entry = pending.popleft()
                # open-loop arrival: the shed estimator may reject the
                # entry outright (same decision sequence as the engine)
                if not loop.offer(entry, now=entry.t_submit):
                    self.shed.append(entry)
            work = loop.pull(i, now=t, force_flush=not pending)
            if work is None:
                # nothing for this unit *now*: park until the next
                # submission or fusion-window ripening, else retire.
                wake = pending[0].t_submit if pending else None
                ripen = loop.admission.next_ripen_in(t)
                if ripen is not None:
                    t_r = t + max(ripen, 1e-9)
                    wake = t_r if wake is None else min(wake, t_r)
                if wake is not None:
                    heapq.heappush(evq, (max(wake, t + 1e-9), tie, i))
                    tie += 1
                continue
            entry, pkg = work
            self.dispatch(i, entry, pkg)
            loop.complete(entry, pkg)
            # the unit re-arms on the serial clock (busy_until), not the
            # recorded pipelined completion — pull pacing is what keeps
            # scheduler decisions depth-invariant
            heapq.heappush(evq, (self.busy_until[i], tie, i))
            tie += 1


def _run_sim(entries: Sequence[_SimLaunchState], units: Sequence["SimUnit"],
             cfg: AdmissionConfig, memory: MemoryModel, costs: MemoryCosts,
             validate: bool, pipeline_depth: int = 1
             ) -> tuple[SimBackend, ExecutionLoop]:
    """Drive the shared loop over a SimBackend until the entries finish."""
    backend = SimBackend(units, memory, costs,
                         pipeline_depth=pipeline_depth)
    loop = ExecutionLoop(backend, [u.name for u in units], cfg,
                         validate=validate)
    backend.run(loop, entries)
    settled = len(backend.delivered) + len(backend.shed)
    if settled != len(entries):
        shed_set = set(map(id, backend.shed))
        stuck = sorted(e.tenant for e in entries
                       if e.stats is None and not e.failed
                       and id(e) not in shed_set)
        raise RuntimeError(
            f"simulation finished {settled}/{len(entries)} "
            f"launches; admission wedged (undrained tenants: "
            f"{stuck or 'in-controller'}) — this is a scheduling bug, "
            f"not a caller error")
    return backend, loop


def simulate(scheduler: Optional[Scheduler], units: Sequence["SimUnit"],
             workload: Workload, *,
             memory: Optional[MemoryModel] = None,
             costs: MemoryCosts = MemoryCosts(),
             validate: bool = True, spec=None) -> SimResult:
    """Run the Commander loop in virtual time. Deterministic.

    Args:
        scheduler: fresh one-shot load balancer, or ``None`` to build one
            from ``spec`` (its policy/options/dist drive the split, with
            the units' calibrated speeds as the default hint).
        units: the simulated Coexecution Units.
        workload: the data-parallel problem.
        memory: package-movement cost model; ``None`` takes the spec's
            memory section (USM when no spec is given either).
        costs: calibrated data-movement cost parameters.
        validate: assert the packages exactly tile the index space.
        spec: optional :class:`~repro.api.spec.CoexecSpec` — the same
            object that configures the real engine drives the DES, which
            is what keeps real-vs-sim parity spec-driven.

    Returns:
        The run's :class:`SimResult`.

    Raises:
        ValueError: scheduler/unit count mismatch, or ``scheduler=None``
            without a spec.
    """
    n = len(units)
    if memory is None:
        memory = spec.memory_model() if spec is not None else MemoryModel.USM
    if scheduler is None:
        if spec is None:
            raise ValueError("need a scheduler or a spec to build one from")
        speeds = spec.speeds_for(n) or [u.speed for u in units]
        scheduler = spec.scheduler.build(workload.total, n, speeds=speeds)
    if scheduler.num_units != n:
        raise ValueError("scheduler/unit count mismatch")

    entry = _SimLaunchState(0, scheduler, workload,
                            tenant=f"sim:{workload.name}")
    depth = int(spec.units.pipeline_depth) if spec is not None else 1
    backend, _ = _run_sim([entry], units, AdmissionConfig(), memory, costs,
                          validate, pipeline_depth=depth)
    stats = entry.stats
    return SimResult(
        workload=workload.name,
        policy=scheduler.name,
        memory=memory.value,
        total_s=backend.last_collect,
        unit_finish_s=backend.unit_finish,
        unit_busy_s=backend.unit_busy,
        host_busy_s=backend.host_busy,
        packages=stats.packages,
        num_packages=stats.num_packages,
        data=stats.data,
    )


def solo_run(unit: "SimUnit", workload: Workload, *,
             memory: MemoryModel = MemoryModel.USM,
             costs: MemoryCosts = MemoryCosts()) -> SimResult:
    """Baseline: the whole problem on one device, one package."""
    from .scheduler import StaticScheduler

    sched = StaticScheduler(workload.total, 1, speeds=[unit.speed])
    return simulate(sched, [unit], workload, memory=memory, costs=costs)


# ---------------------------------------------------------------------------
# Multi-launch DES: the admission layer in virtual time
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LaunchSpec:
    """One tenant's co-execution request for :func:`simulate_multi`.

    Attributes:
        workload: the data-parallel problem this launch computes.
        scheduler: fresh one-shot intra-launch load balancer.
        tenant: fairness flow (defaults to a unique per-launch tenant).
        weight: relative WFQ share of the tenant.
        t_submit: virtual submission time.
        deadline_s: relative SLO deadline in seconds after ``t_submit``;
            ``None`` falls back to the admission config's ``slo_ms``
            default (when set). Drives EDF urgency and load shedding.
    """

    workload: Workload
    scheduler: Scheduler
    tenant: str = ""
    weight: float = 1.0
    t_submit: float = 0.0
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class LaunchSimResult:
    """Completion record of one launch in a multi-launch simulation."""

    tenant: str
    workload: str
    t_submit: float
    t_finish: float
    items: int
    num_packages: int          # real dispatches that served this launch
    fused: bool = False        # served through a coalesced batch
    deadline: Optional[float] = None   # absolute virtual-time SLO target
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    @property
    def latency_s(self) -> float:
        """Submit-to-last-collection latency in virtual seconds."""
        return self.t_finish - self.t_submit

    @property
    def deadline_missed(self) -> Optional[bool]:
        """Whether the launch finished past its deadline (None = no SLO)."""
        if self.deadline is None:
            return None
        return self.t_finish > self.deadline


@dataclasses.dataclass
class ShedRecord:
    """One launch the admission layer rejected instead of serving."""

    tenant: str
    workload: str
    t_submit: float
    items: int
    deadline: Optional[float] = None


@dataclasses.dataclass
class MultiSimResult:
    """Timeline + per-launch metrics of one multi-tenant simulation.

    ``data`` aggregates the modeled data-plane accounting across every
    dispatched package (same surface as the real engine's per-launch
    counters: staging copies are zero under USM, one H2D + one D2H per
    package under BUFFERS); each :class:`LaunchSimResult` additionally
    carries its own share, produced by the shared loop's finalization —
    for fused batches the remainder-distributed integer split, so
    per-launch ``data`` sums back to the batch totals exactly.
    """

    total_s: float
    launches: list[LaunchSimResult]
    dispatched_packages: int   # real dispatches across all launches
    fused_batches: int
    fused_members: int
    host_busy_s: float
    # (t_complete, tenant, items) per dispatched package — service curve
    service: list[tuple[float, str, int]]
    shed: list[ShedRecord] = dataclasses.field(default_factory=list)
    # ("accept" | "shed", tenant) per offered launch, in offer order
    decisions: list[tuple[str, str]] = dataclasses.field(
        default_factory=list)
    # member-tenant tuples per materialized fused batch
    fusion_groups: list[tuple[str, ...]] = dataclasses.field(
        default_factory=list)
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    def latencies(self) -> list[float]:
        """Per-launch latencies in completion order."""
        return [r.latency_s for r in self.launches]

    def shed_fraction(self) -> float:
        """Rejected launches as a fraction of everything offered."""
        offered = len(self.launches) + len(self.shed)
        return len(self.shed) / offered if offered else 0.0

    def deadline_miss_rate(self) -> float:
        """Admitted launches that finished past their deadline.

        Returns:
            Misses over admitted deadline-carrying launches (0.0 when no
            launch carried a deadline). Shed launches are not counted —
            they never ran; :meth:`shed_fraction` reports them.
        """
        with_slo = [r for r in self.launches if r.deadline is not None]
        if not with_slo:
            return 0.0
        return sum(bool(r.deadline_missed) for r in with_slo) / len(with_slo)

    def tenant_service_until(self, t: float) -> dict[str, int]:
        """Work-items completed per tenant up to virtual time ``t``.

        Args:
            t: inclusive virtual-time horizon.

        Returns:
            Mapping tenant → items whose compute finished by ``t`` (the
            measure the WFQ fairness tests take ratios of).
        """
        served: dict[str, int] = {}
        for tc, tenant, items in self.service:
            if tc <= t:
                served[tenant] = served.get(tenant, 0) + items
        return served

    def fairness_curve(self, *, samples: int = 9) -> list[float]:
        """Time-sampled Jain fairness of per-tenant service.

        Args:
            samples: evenly spaced horizons to sample across the run.

        Returns:
            One Jain index per horizon (see
            :func:`~repro.core.admission.service_fairness_curve`) — the
            curve preemptive pull-capping tightens.
        """
        from .admission import service_fairness_curve

        tenants = sorted({r.tenant for r in self.launches})
        return service_fairness_curve(self.service, tenants,
                                      samples=samples)


def simulate_multi(specs: Sequence[LaunchSpec], units: Sequence["SimUnit"], *,
                   admission=None,
                   memory: Optional[MemoryModel] = None,
                   costs: MemoryCosts = MemoryCosts(),
                   validate: bool = True, spec=None) -> MultiSimResult:
    """Run concurrent co-executions through the shared control plane.

    The exact :class:`~repro.core.exec.ExecutionLoop` the real engine
    uses arbitrates which launch each idle unit serves — so FIFO vs WFQ
    fairness (with or without preemptive pull-capping), launch fusion and
    backpressure-free latency are measured deterministically.

    Args:
        specs: one :class:`LaunchSpec` per launch; schedulers must be
            fresh and built for ``len(units)``.
        admission: policy name, :class:`~.admission.AdmissionConfig`, or
            :class:`~repro.api.spec.AdmissionSpec`; ``None`` takes the
            admission section of ``spec`` (plain FIFO without one).
        memory: USM or BUFFERS package-movement cost model; ``None``
            takes the spec's memory section (USM without one).
        costs: calibrated data-movement cost parameters.
        validate: assert each launch's packages exactly tile its space.
        spec: optional :class:`~repro.api.spec.CoexecSpec` — the same
            object that configures the real engine supplies the admission
            and memory sections here, keeping both substrates in sync.

    Returns:
        A :class:`MultiSimResult` with per-launch latencies, the tenant
        service curve, and dispatch/fusion counters.

    Raises:
        ValueError: on a scheduler/unit-count mismatch or non-positive
            tenant weight.
    """
    n = len(units)
    if memory is None:
        memory = spec.memory_model() if spec is not None else MemoryModel.USM
    if admission is None and spec is not None:
        cfg = spec.admission_config()
    else:
        cfg = coerce_admission(admission)
    for ls in specs:
        if ls.scheduler.num_units != n:
            raise ValueError("scheduler/unit count mismatch in spec")

    def fuse_key(ls: LaunchSpec):
        if not cfg.fuse or ls.workload.total > cfg.fuse_threshold:
            return None
        wl = ls.workload
        if cfg.fuse_buckets:
            return (wl.name, "bucket", fusion_bucket(wl.total),
                    wl.bytes_in_per_item, wl.bytes_out_per_item)
        return (wl.name, wl.total, wl.bytes_in_per_item,
                wl.bytes_out_per_item)

    entries = []
    for i, ls in enumerate(specs):
        entry = _SimLaunchState(i, ls.scheduler, ls.workload,
                                tenant=ls.tenant or f"launch-{i}",
                                weight=ls.weight, t_submit=ls.t_submit)
        entry.fuse_key = fuse_key(ls)
        if entry.fuse_key is not None and cfg.fuse_buckets:
            entry.fuse_bucket = fusion_bucket(ls.workload.total)
        if ls.deadline_s is not None:
            entry.deadline = ls.t_submit + ls.deadline_s
        elif cfg.slo_ms is not None:
            entry.deadline = ls.t_submit + cfg.slo_ms / 1e3
        entries.append(entry)

    depth = int(spec.units.pipeline_depth) if spec is not None else 1
    backend, loop = _run_sim(entries, units, cfg, memory, costs, validate,
                             pipeline_depth=depth)

    results = [LaunchSimResult(
        tenant=e.tenant, workload=e.workload.name, t_submit=e.t_submit,
        t_finish=max(p.t_collected for p in e.stats.packages),
        items=e.scheduler.total, num_packages=e.stats.num_packages,
        fused=e.fused, deadline=e.deadline,
        data=e.stats.data) for e in backend.delivered]

    shed = [ShedRecord(tenant=e.tenant, workload=e.workload.name,
                       t_submit=e.t_submit, items=e.workload.total,
                       deadline=e.deadline) for e in backend.shed]

    return MultiSimResult(
        total_s=backend.last_collect,
        launches=results,
        dispatched_packages=loop.admission.dispatched,
        fused_batches=loop.admission.fused_batches,
        fused_members=loop.admission.fused_members,
        host_busy_s=backend.host_busy,
        service=backend.service,
        shed=shed,
        decisions=list(loop.admission.decision_log),
        fusion_groups=list(loop.admission.fusion_log),
        data=backend.counters,
    )
