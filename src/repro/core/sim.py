"""Discrete-event simulation of the Commander loop (paper Figs. 2a/4).

The simulator replays the exact Commander/Coexecution-Unit protocol:

  unit idle ──request──▶ Scheduler.next_package ──▶ host launches package
  (host is a serial resource: launch + collection costs serialize on it,
  reproducing the paper's "CPU manages the runtime resources as the host,
  increasing the CPU load") ──▶ unit computes ──▶ host collects output
  (cost depends on the memory model: USM ≈ free, Buffers ∝ bytes).

Compute time for a package is ``sum(weight[i]**alpha_u for i in range) /
speed_u`` — `weights` capture data irregularity (Mandelbrot iteration
counts, Ray scene density, Rap row lengths); regular kernels have
weights = 1. While more than one unit is busy and the combined working set
exceeds the shared LLC, a contention factor slows both units (the paper's
MatMul observation in §5.3).

The output timeline feeds the paper's metrics: balance = T_gpu/T_cpu,
speedup = T_fastest_alone / T_coexec, energy via core.energy.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from .energy import EnergyReport, PowerModel, energy_report
from .memory import MemoryCosts, MemoryModel
from .package import Package, validate_cover
from .scheduler import Scheduler
from .units import SimUnit


@dataclasses.dataclass(frozen=True)
class Workload:
    """A data-parallel problem as the DES sees it.

    weights — per-item relative cost (mean ≈ 1), or None for regular
              kernels. Stored as a float64 array of length `total`.
    """

    name: str
    total: int
    bytes_in_per_item: float
    bytes_out_per_item: float
    working_set_bytes: float
    weights: Optional[np.ndarray] = None
    # LLC sensitivity: 1.0 for kernels with heavy temporal reuse (MatMul —
    # the paper's §5.3 hardware-counter analysis: "the LLC memory suffers
    # constant invalidations between CPU and GPU"); 0.0 for streaming
    # kernels whose working set never profits from the LLC.
    contention_scale: float = 0.0

    def weights_prefix(self) -> Optional[np.ndarray]:
        if self.weights is None:
            return None
        p = np.zeros(self.total + 1, dtype=np.float64)
        np.cumsum(self.weights, out=p[1:])
        return p


@dataclasses.dataclass
class SimResult:
    """Timeline + metrics of one simulated co-execution."""

    workload: str
    policy: str
    memory: str
    total_s: float
    unit_finish_s: dict[str, float]      # last compute completion per unit
    unit_busy_s: dict[str, float]        # total compute seconds per unit
    host_busy_s: float                   # serialized launch+collect seconds
    packages: list[Package]
    num_packages: int

    def balance(self, fast: str = "gpu", slow: str = "cpu") -> float:
        """Paper's balancing efficiency T_fast/T_slow (1.0 = perfect)."""
        num = self.unit_finish_s.get(fast, 0.0)
        den = self.unit_finish_s.get(slow, 0.0)
        return num / den if den > 0 else float("inf")

    def energy(self, power: PowerModel,
               kinds: dict[str, str]) -> EnergyReport:
        busy: dict[str, float] = {}
        for name, b in self.unit_busy_s.items():
            kind = kinds[name]
            busy[kind] = busy.get(kind, 0.0) + b
        # host management burns CPU-core time on top of CPU compute
        busy["cpu"] = busy.get("cpu", 0.0) + self.host_busy_s
        return energy_report(power, busy, self.total_s)


def _item_costs(workload: Workload, unit: SimUnit) -> np.ndarray:
    """Per-item seconds for `unit` (prefix-summed by the caller)."""
    if workload.weights is None:
        return None
    w = workload.weights.astype(np.float64)
    if unit.alpha != 1.0:
        # NOT renormalized: `speed` is the unit's throughput on *uniform*
        # (weight=1) data; alpha>1 genuinely slows the unit on heavy items
        # (branch divergence on the paper's iGPU). This is what makes
        # irregular co-execution speedups exceed the uniform capacity bound
        # 1 + s_cpu/s_gpu, as observed for Ray (1.48) and Rap (2.46).
        w = np.power(w, unit.alpha)
    return np.concatenate([[0.0], np.cumsum(w)])


def simulate(scheduler: Scheduler, units: Sequence[SimUnit],
             workload: Workload, *,
             memory: MemoryModel = MemoryModel.USM,
             costs: MemoryCosts = MemoryCosts(),
             validate: bool = True) -> SimResult:
    """Run the Commander loop in virtual time. Deterministic."""
    n = len(units)
    if scheduler.num_units != n:
        raise ValueError("scheduler/unit count mismatch")

    prefix = {u.name: _item_costs(workload, u) for u in units}

    # Each Coexecution Unit has its own management thread (paper Fig. 2a):
    # launch/collect costs are paid on the unit's own timeline, not on a
    # global serial host. Units couple only through the scheduler (on-demand
    # package order) and the shared-LLC contention factor. The host-side
    # management seconds are accumulated for the energy model (the CPU does
    # double duty as host — §5.1).
    evq: list[tuple[float, int, int]] = []  # (t_idle, tiebreak, unit)
    tie = 0
    for i, u in enumerate(units):
        heapq.heappush(evq, (u.setup_s, tie, i))
        tie += 1

    host_busy = 0.0
    busy_until = [0.0] * n            # compute-busy horizon per unit
    collector_free = [0.0] * n        # per-unit collection thread horizon
    unit_finish = {u.name: 0.0 for u in units}
    unit_busy = {u.name: 0.0 for u in units}
    packages: list[Package] = []
    last_collect = 0.0

    while evq:
        t, _, i = heapq.heappop(evq)
        u = units[i]
        pkg = scheduler.next_package(i)
        if pkg is None:
            continue  # unit retires from the Commander loop
        pkg.t_issue = t
        in_bytes = pkg.size * workload.bytes_in_per_item
        out_bytes = pkg.size * workload.bytes_out_per_item

        # package emission on this unit's manager thread
        launch_cost = costs.launch_cost(memory, int(in_bytes))
        host_busy += launch_cost
        pkg.t_launch = t + launch_cost

        # compute; LLC contention applies while any *other* unit is busy
        pfx = prefix[u.name]
        if pfx is None:
            base = pkg.size / u.speed
        else:
            base = float(pfx[pkg.offset + pkg.size] - pfx[pkg.offset]) / u.speed
        others_busy = any(busy_until[j] > pkg.t_launch
                          for j in range(n) if j != i)
        factor = 1.0
        if others_busy and workload.contention_scale > 0.0:
            pen = costs.contention_penalty(workload.working_set_bytes)
            factor = 1.0 + workload.contention_scale * (pen - 1.0)
        compute_end = pkg.t_launch + base * factor
        busy_until[i] = compute_end
        unit_busy[u.name] += compute_end - pkg.t_launch
        unit_finish[u.name] = max(unit_finish[u.name], compute_end)
        pkg.t_complete = compute_end

        # collection on the unit's manager thread; overlaps the unit's next
        # compute (paper: "overlapping computation and communication") but
        # collections of one unit serialize among themselves.
        collect_start = max(compute_end, collector_free[i])
        collect_cost = costs.collect_cost(memory, int(out_bytes))
        collector_free[i] = collect_start + collect_cost
        host_busy += collect_cost
        pkg.t_collected = collector_free[i]
        last_collect = max(last_collect, pkg.t_collected)

        packages.append(pkg)
        # the unit may request its next package as soon as compute ends
        heapq.heappush(evq, (compute_end, tie, i))
        tie += 1

    if validate:
        validate_cover(packages, workload.total)

    return SimResult(
        workload=workload.name,
        policy=scheduler.name,
        memory=memory.value,
        total_s=last_collect,
        unit_finish_s=unit_finish,
        unit_busy_s=unit_busy,
        host_busy_s=host_busy,
        packages=packages,
        num_packages=len(packages),
    )


def solo_run(unit: SimUnit, workload: Workload, *,
             memory: MemoryModel = MemoryModel.USM,
             costs: MemoryCosts = MemoryCosts()) -> SimResult:
    """Baseline: the whole problem on one device, one package."""
    from .scheduler import StaticScheduler

    sched = StaticScheduler(workload.total, 1, speeds=[unit.speed])
    return simulate(sched, [unit], workload, memory=memory, costs=costs)
