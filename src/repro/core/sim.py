"""Discrete-event simulation of the Commander loop (paper Figs. 2a/4).

The simulator replays the exact Commander/Coexecution-Unit protocol:

  unit idle ──request──▶ Scheduler.next_package ──▶ host launches package
  (host is a serial resource: launch + collection costs serialize on it,
  reproducing the paper's "CPU manages the runtime resources as the host,
  increasing the CPU load") ──▶ unit computes ──▶ host collects output
  (cost depends on the memory model: USM ≈ free, Buffers ∝ bytes).

Compute time for a package is ``sum(weight[i]**alpha_u for i in range) /
speed_u`` — `weights` capture data irregularity (Mandelbrot iteration
counts, Ray scene density, Rap row lengths); regular kernels have
weights = 1. While more than one unit is busy and the combined working set
exceeds the shared LLC, a contention factor slows both units (the paper's
MatMul observation in §5.3).

The output timeline feeds the paper's metrics: balance = T_gpu/T_cpu,
speedup = T_fastest_alone / T_coexec, energy via core.energy.

A multi-launch variant, :func:`simulate_multi`, replays *concurrent*
co-executions through the same :class:`~.admission.AdmissionController`
the real engine uses — FIFO vs weighted-fair queueing, launch fusion and
per-launch latency are therefore testable deterministically in virtual
time before they ever touch real threads.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Optional, Sequence

import numpy as np

from .admission import AdmissionController, coerce_admission
from .dataplane import DataPlaneCounters
from .energy import EnergyReport, PowerModel, energy_report
from .memory import MemoryCosts, MemoryModel
from .package import Package, validate_cover
from .scheduler import DynamicScheduler, Scheduler
from .units import SimUnit


@dataclasses.dataclass(frozen=True)
class Workload:
    """A data-parallel problem as the DES sees it.

    weights — per-item relative cost (mean ≈ 1), or None for regular
              kernels. Stored as a float64 array of length `total`.
    """

    name: str
    total: int
    bytes_in_per_item: float
    bytes_out_per_item: float
    working_set_bytes: float
    weights: Optional[np.ndarray] = None
    # LLC sensitivity: 1.0 for kernels with heavy temporal reuse (MatMul —
    # the paper's §5.3 hardware-counter analysis: "the LLC memory suffers
    # constant invalidations between CPU and GPU"); 0.0 for streaming
    # kernels whose working set never profits from the LLC.
    contention_scale: float = 0.0

    def weights_prefix(self) -> Optional[np.ndarray]:
        """Prefix-summed per-item weights (None for regular kernels)."""
        if self.weights is None:
            return None
        p = np.zeros(self.total + 1, dtype=np.float64)
        np.cumsum(self.weights, out=p[1:])
        return p


@dataclasses.dataclass
class SimResult:
    """Timeline + metrics of one simulated co-execution.

    ``data`` mirrors the real engine's per-launch
    :class:`~.dataplane.DataPlaneCounters`: the modeled dispatch count
    and the staging copies the memory model implies (one H2D and one D2H
    per package under BUFFERS, none under USM), so spec-driven
    real-vs-sim comparisons read the same counter surface.
    """

    workload: str
    policy: str
    memory: str
    total_s: float
    unit_finish_s: dict[str, float]      # last compute completion per unit
    unit_busy_s: dict[str, float]        # total compute seconds per unit
    host_busy_s: float                   # serialized launch+collect seconds
    packages: list[Package]
    num_packages: int
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    def balance(self, fast: str = "gpu", slow: str = "cpu") -> float:
        """Paper's balancing efficiency T_fast/T_slow (1.0 = perfect)."""
        num = self.unit_finish_s.get(fast, 0.0)
        den = self.unit_finish_s.get(slow, 0.0)
        return num / den if den > 0 else float("inf")

    def energy(self, power: PowerModel,
               kinds: dict[str, str]) -> EnergyReport:
        """Model this run's energy from its busy timeline (paper §5.2)."""
        busy: dict[str, float] = {}
        for name, b in self.unit_busy_s.items():
            kind = kinds[name]
            busy[kind] = busy.get(kind, 0.0) + b
        # host management burns CPU-core time on top of CPU compute
        busy["cpu"] = busy.get("cpu", 0.0) + self.host_busy_s
        return energy_report(power, busy, self.total_s)


def _count_package(counters: DataPlaneCounters, memory: MemoryModel,
                   in_bytes: float, out_bytes: float) -> None:
    """Model one package's data-plane accounting (mirrors the real planes)."""
    counters.dispatches += 1
    if memory is MemoryModel.BUFFERS:
        counters.h2d_copies += 1
        counters.h2d_bytes += int(in_bytes)
        counters.d2h_copies += 1
        counters.d2h_bytes += int(out_bytes)


def _item_costs(workload: Workload, unit: SimUnit) -> np.ndarray:
    """Per-item seconds for `unit` (prefix-summed by the caller)."""
    if workload.weights is None:
        return None
    w = workload.weights.astype(np.float64)
    if unit.alpha != 1.0:
        # NOT renormalized: `speed` is the unit's throughput on *uniform*
        # (weight=1) data; alpha>1 genuinely slows the unit on heavy items
        # (branch divergence on the paper's iGPU). This is what makes
        # irregular co-execution speedups exceed the uniform capacity bound
        # 1 + s_cpu/s_gpu, as observed for Ray (1.48) and Rap (2.46).
        w = np.power(w, unit.alpha)
    return np.concatenate([[0.0], np.cumsum(w)])


def simulate(scheduler: Optional[Scheduler], units: Sequence[SimUnit],
             workload: Workload, *,
             memory: Optional[MemoryModel] = None,
             costs: MemoryCosts = MemoryCosts(),
             validate: bool = True, spec=None) -> SimResult:
    """Run the Commander loop in virtual time. Deterministic.

    Args:
        scheduler: fresh one-shot load balancer, or ``None`` to build one
            from ``spec`` (its policy/options/dist drive the split, with
            the units' calibrated speeds as the default hint).
        units: the simulated Coexecution Units.
        workload: the data-parallel problem.
        memory: package-movement cost model; ``None`` takes the spec's
            memory section (USM when no spec is given either).
        costs: calibrated data-movement cost parameters.
        validate: assert the packages exactly tile the index space.
        spec: optional :class:`~repro.api.spec.CoexecSpec` — the same
            object that configures the real engine drives the DES, which
            is what keeps real-vs-sim parity spec-driven.

    Returns:
        The run's :class:`SimResult`.

    Raises:
        ValueError: scheduler/unit count mismatch, or ``scheduler=None``
            without a spec.
    """
    n = len(units)
    if memory is None:
        memory = spec.memory_model() if spec is not None else MemoryModel.USM
    if scheduler is None:
        if spec is None:
            raise ValueError("need a scheduler or a spec to build one from")
        speeds = spec.speeds_for(n) or [u.speed for u in units]
        scheduler = spec.scheduler.build(workload.total, n, speeds=speeds)
    if scheduler.num_units != n:
        raise ValueError("scheduler/unit count mismatch")

    prefix = {u.name: _item_costs(workload, u) for u in units}

    # Each Coexecution Unit has its own management thread (paper Fig. 2a):
    # launch/collect costs are paid on the unit's own timeline, not on a
    # global serial host. Units couple only through the scheduler (on-demand
    # package order) and the shared-LLC contention factor. The host-side
    # management seconds are accumulated for the energy model (the CPU does
    # double duty as host — §5.1).
    evq: list[tuple[float, int, int]] = []  # (t_idle, tiebreak, unit)
    tie = 0
    for i, u in enumerate(units):
        heapq.heappush(evq, (u.setup_s, tie, i))
        tie += 1

    host_busy = 0.0
    counters = DataPlaneCounters()
    busy_until = [0.0] * n            # compute-busy horizon per unit
    collector_free = [0.0] * n        # per-unit collection thread horizon
    unit_finish = {u.name: 0.0 for u in units}
    unit_busy = {u.name: 0.0 for u in units}
    packages: list[Package] = []
    last_collect = 0.0

    while evq:
        t, _, i = heapq.heappop(evq)
        u = units[i]
        pkg = scheduler.next_package(i)
        if pkg is None:
            continue  # unit retires from the Commander loop
        pkg.t_issue = t
        in_bytes = pkg.size * workload.bytes_in_per_item
        out_bytes = pkg.size * workload.bytes_out_per_item
        _count_package(counters, memory, in_bytes, out_bytes)

        # package emission on this unit's manager thread
        launch_cost = costs.launch_cost(memory, int(in_bytes))
        host_busy += launch_cost
        pkg.t_launch = t + launch_cost

        # compute; LLC contention applies while any *other* unit is busy
        pfx = prefix[u.name]
        if pfx is None:
            base = pkg.size / u.speed
        else:
            base = float(pfx[pkg.offset + pkg.size] - pfx[pkg.offset]) / u.speed
        others_busy = any(busy_until[j] > pkg.t_launch
                          for j in range(n) if j != i)
        factor = 1.0
        if others_busy and workload.contention_scale > 0.0:
            pen = costs.contention_penalty(workload.working_set_bytes)
            factor = 1.0 + workload.contention_scale * (pen - 1.0)
        compute_end = pkg.t_launch + base * factor
        busy_until[i] = compute_end
        unit_busy[u.name] += compute_end - pkg.t_launch
        unit_finish[u.name] = max(unit_finish[u.name], compute_end)
        pkg.t_complete = compute_end

        # collection on the unit's manager thread; overlaps the unit's next
        # compute (paper: "overlapping computation and communication") but
        # collections of one unit serialize among themselves.
        collect_start = max(compute_end, collector_free[i])
        collect_cost = costs.collect_cost(memory, int(out_bytes))
        collector_free[i] = collect_start + collect_cost
        host_busy += collect_cost
        pkg.t_collected = collector_free[i]
        last_collect = max(last_collect, pkg.t_collected)

        packages.append(pkg)
        # the unit may request its next package as soon as compute ends
        heapq.heappush(evq, (compute_end, tie, i))
        tie += 1

    if validate:
        validate_cover(packages, workload.total)

    return SimResult(
        workload=workload.name,
        policy=scheduler.name,
        memory=memory.value,
        total_s=last_collect,
        unit_finish_s=unit_finish,
        unit_busy_s=unit_busy,
        host_busy_s=host_busy,
        packages=packages,
        num_packages=len(packages),
        data=counters,
    )


def solo_run(unit: SimUnit, workload: Workload, *,
             memory: MemoryModel = MemoryModel.USM,
             costs: MemoryCosts = MemoryCosts()) -> SimResult:
    """Baseline: the whole problem on one device, one package."""
    from .scheduler import StaticScheduler

    sched = StaticScheduler(workload.total, 1, speeds=[unit.speed])
    return simulate(sched, [unit], workload, memory=memory, costs=costs)


# ---------------------------------------------------------------------------
# Multi-launch DES: the admission layer in virtual time
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LaunchSpec:
    """One tenant's co-execution request for :func:`simulate_multi`.

    Attributes:
        workload: the data-parallel problem this launch computes.
        scheduler: fresh one-shot intra-launch load balancer.
        tenant: fairness flow (defaults to a unique per-launch tenant).
        weight: relative WFQ share of the tenant.
        t_submit: virtual submission time.
    """

    workload: Workload
    scheduler: Scheduler
    tenant: str = ""
    weight: float = 1.0
    t_submit: float = 0.0


@dataclasses.dataclass
class LaunchSimResult:
    """Completion record of one launch in a multi-launch simulation."""

    tenant: str
    workload: str
    t_submit: float
    t_finish: float
    items: int
    num_packages: int          # real dispatches that served this launch
    fused: bool = False        # served through a coalesced batch

    @property
    def latency_s(self) -> float:
        """Submit-to-last-collection latency in virtual seconds."""
        return self.t_finish - self.t_submit


@dataclasses.dataclass
class MultiSimResult:
    """Timeline + per-launch metrics of one multi-tenant simulation.

    ``data`` aggregates the modeled data-plane accounting across every
    dispatched package (same surface as the real engine's per-launch
    counters: staging copies are zero under USM, one H2D + one D2H per
    package under BUFFERS).
    """

    total_s: float
    launches: list[LaunchSimResult]
    dispatched_packages: int   # real dispatches across all launches
    fused_batches: int
    fused_members: int
    host_busy_s: float
    # (t_complete, tenant, items) per dispatched package — service curve
    service: list[tuple[float, str, int]]
    data: DataPlaneCounters = dataclasses.field(
        default_factory=DataPlaneCounters)

    def latencies(self) -> list[float]:
        """Per-launch latencies in completion order."""
        return [r.latency_s for r in self.launches]

    def tenant_service_until(self, t: float) -> dict[str, int]:
        """Work-items completed per tenant up to virtual time ``t``.

        Args:
            t: inclusive virtual-time horizon.

        Returns:
            Mapping tenant → items whose compute finished by ``t`` (the
            measure the WFQ fairness tests take ratios of).
        """
        served: dict[str, int] = {}
        for tc, tenant, items in self.service:
            if tc <= t:
                served[tenant] = served.get(tenant, 0) + items
        return served


class _SimLaunch:
    """Controller-facing entry for one simulated launch (or fused batch)."""

    __slots__ = ("workload", "scheduler", "tenant", "weight", "t_submit",
                 "fuse_key", "slots", "members", "done_pkgs", "failed")

    def __init__(self, workload: Workload, scheduler: Scheduler,
                 tenant: str, weight: float, t_submit: float, fuse_key):
        self.workload = workload
        self.scheduler = scheduler
        self.tenant = tenant
        self.weight = weight
        self.t_submit = t_submit
        self.fuse_key = fuse_key
        self.slots = 1
        self.members: Optional[list["_SimLaunch"]] = None
        self.done_pkgs: list[Package] = []
        self.failed = False


def _fuse_sim_launches(members: list[_SimLaunch],
                       num_units: int) -> _SimLaunch:
    """Coalesce member sim-launches into one batch entry.

    The fused workload is the members' index spaces laid end to end
    (weights tiled); its scheduler hands out member-aligned packages, one
    per unit, so a batch of N tiny launches costs ~`num_units` dispatches.
    """
    base = members[0].workload
    k, T = len(members), base.total
    if any(m.workload.weights is not None for m in members):
        weights = np.concatenate(
            [m.workload.weights if m.workload.weights is not None
             else np.ones(T) for m in members])
    else:
        weights = None
    wl = Workload(
        name=f"fused:{base.name}x{k}", total=k * T,
        bytes_in_per_item=base.bytes_in_per_item,
        bytes_out_per_item=base.bytes_out_per_item,
        working_set_bytes=max(m.workload.working_set_bytes for m in members),
        weights=weights, contention_scale=base.contention_scale)
    sched = DynamicScheduler(k * T, num_units,
                             num_packages=min(k, num_units), granularity=T)
    fused = _SimLaunch(wl, sched, tenant=f"fused:{base.name}",
                       weight=sum(m.weight for m in members),
                       t_submit=min(m.t_submit for m in members),
                       fuse_key=None)
    fused.members = members
    return fused


def simulate_multi(specs: Sequence[LaunchSpec], units: Sequence[SimUnit], *,
                   admission=None,
                   memory: Optional[MemoryModel] = None,
                   costs: MemoryCosts = MemoryCosts(),
                   validate: bool = True, spec=None) -> MultiSimResult:
    """Run concurrent co-executions through the admission layer.

    The exact :class:`~.admission.AdmissionController` the real engine
    uses arbitrates which launch each idle unit serves — so FIFO vs WFQ
    fairness, launch fusion and backpressure-free latency are measured
    deterministically.

    Args:
        specs: one :class:`LaunchSpec` per launch; schedulers must be
            fresh and built for ``len(units)``.
        admission: policy name, :class:`~.admission.AdmissionConfig`, or
            :class:`~repro.api.spec.AdmissionSpec`; ``None`` takes the
            admission section of ``spec`` (plain FIFO without one).
        memory: USM or BUFFERS package-movement cost model; ``None``
            takes the spec's memory section (USM without one).
        costs: calibrated data-movement cost parameters.
        validate: assert each launch's packages exactly tile its space.
        spec: optional :class:`~repro.api.spec.CoexecSpec` — the same
            object that configures the real engine supplies the admission
            and memory sections here, keeping both substrates in sync.

    Returns:
        A :class:`MultiSimResult` with per-launch latencies, the tenant
        service curve, and dispatch/fusion counters.

    Raises:
        ValueError: on a scheduler/unit-count mismatch.
    """
    n = len(units)
    if memory is None:
        memory = spec.memory_model() if spec is not None else MemoryModel.USM
    if admission is None and spec is not None:
        cfg = spec.admission_config()
    else:
        cfg = coerce_admission(admission)
    for ls in specs:
        if ls.scheduler.num_units != n:
            raise ValueError("scheduler/unit count mismatch in spec")

    def fuse_key(ls: LaunchSpec):
        if not cfg.fuse or ls.workload.total > cfg.fuse_threshold:
            return None
        wl = ls.workload
        return (wl.name, wl.total, wl.bytes_in_per_item,
                wl.bytes_out_per_item)

    controller = AdmissionController(
        n, cfg, fuse_materialize=lambda ms: _fuse_sim_launches(ms, n))
    pending = collections.deque(sorted(
        (_SimLaunch(s.workload, s.scheduler,
                    s.tenant or f"launch-{i}", s.weight, s.t_submit,
                    fuse_key(s))
         for i, s in enumerate(specs)),
        key=lambda e: e.t_submit))

    prefix: dict[tuple[int, str], Optional[np.ndarray]] = {}

    def prefix_for(wl: Workload, u: SimUnit) -> Optional[np.ndarray]:
        key = (id(wl), u.name)
        if key not in prefix:
            prefix[key] = _item_costs(wl, u)
        return prefix[key]

    evq: list[tuple[float, int, int]] = []
    tie = 0
    for i, u in enumerate(units):
        heapq.heappush(evq, (u.setup_s, tie, i))
        tie += 1

    host_busy = 0.0
    counters = DataPlaneCounters()
    busy_until = [0.0] * n
    collector_free = [0.0] * n
    service: list[tuple[float, str, int]] = []
    results: list[LaunchSimResult] = []
    last_collect = 0.0

    def finalize(entry: _SimLaunch) -> None:
        controller.discard(entry)
        if validate:
            validate_cover(entry.done_pkgs, entry.scheduler.total)
        if entry.members is None:
            results.append(LaunchSimResult(
                tenant=entry.tenant, workload=entry.workload.name,
                t_submit=entry.t_submit,
                t_finish=max(p.t_collected for p in entry.done_pkgs),
                items=entry.scheduler.total,
                num_packages=len(entry.done_pkgs)))
            return
        # de-multiplex a fused batch: member i occupies [i*T, (i+1)*T)
        T = entry.members[0].workload.total
        for i, m in enumerate(entry.members):
            overl = [p for p in entry.done_pkgs
                     if p.offset < (i + 1) * T and p.offset + p.size > i * T]
            results.append(LaunchSimResult(
                tenant=m.tenant, workload=m.workload.name,
                t_submit=m.t_submit,
                t_finish=max(p.t_collected for p in overl),
                items=T, num_packages=len(overl), fused=True))

    while evq:
        t, _, i = heapq.heappop(evq)
        while pending and pending[0].t_submit <= t + 1e-12:
            entry = pending.popleft()
            controller.admit(entry, now=entry.t_submit)
        controller.flush(t, force=not pending)
        got = controller.next_work(i)
        if got is None:
            # nothing for this unit *now*: park until the next submission
            # or fusion-window ripening, else retire from the loop.
            wake = pending[0].t_submit if pending else None
            ripen = controller.next_ripen_in(t)
            if ripen is not None:
                t_r = t + max(ripen, 1e-9)
                wake = t_r if wake is None else min(wake, t_r)
            if wake is not None:
                heapq.heappush(evq, (max(wake, t + 1e-9), tie, i))
                tie += 1
            continue
        entry, pkg = got
        wl = entry.workload
        u = units[i]
        pkg.t_issue = t
        in_bytes = pkg.size * wl.bytes_in_per_item
        out_bytes = pkg.size * wl.bytes_out_per_item
        _count_package(counters, memory, in_bytes, out_bytes)

        launch_cost = costs.launch_cost(memory, int(in_bytes))
        host_busy += launch_cost
        pkg.t_launch = t + launch_cost

        pfx = prefix_for(wl, u)
        if pfx is None:
            base = pkg.size / u.speed
        else:
            base = float(pfx[pkg.offset + pkg.size] - pfx[pkg.offset]) / u.speed
        others_busy = any(busy_until[j] > pkg.t_launch
                          for j in range(n) if j != i)
        factor = 1.0
        if others_busy and wl.contention_scale > 0.0:
            pen = costs.contention_penalty(wl.working_set_bytes)
            factor = 1.0 + wl.contention_scale * (pen - 1.0)
        compute_end = pkg.t_launch + base * factor
        busy_until[i] = compute_end
        pkg.t_complete = compute_end

        collect_start = max(compute_end, collector_free[i])
        collect_cost = costs.collect_cost(memory, int(out_bytes))
        collector_free[i] = collect_start + collect_cost
        host_busy += collect_cost
        pkg.t_collected = collector_free[i]
        last_collect = max(last_collect, pkg.t_collected)

        entry.done_pkgs.append(pkg)
        if entry.members is None:
            service.append((pkg.t_complete, entry.tenant, pkg.size))
        else:
            # attribute a fused package's items to the member tenants it
            # covers, so tenant_service_until keeps per-tenant meaning
            mt = entry.members[0].workload.total
            for mi in range(pkg.offset // mt,
                            -(-(pkg.offset + pkg.size) // mt)):
                lo = max(pkg.offset, mi * mt)
                hi = min(pkg.offset + pkg.size, (mi + 1) * mt)
                if hi > lo:
                    service.append((pkg.t_complete,
                                    entry.members[mi].tenant, hi - lo))
        if entry.scheduler.done():
            # every package of this entry has times assigned already (the
            # DES schedules compute at issue), so it can finalize now.
            finalize(entry)
        heapq.heappush(evq, (compute_end, tie, i))
        tie += 1

    expected_launches = len(specs)
    if len(results) != expected_launches:
        stuck = [e.tenant for e in pending]
        raise RuntimeError(
            f"simulate_multi finished {len(results)}/{expected_launches} "
            f"launches; admission wedged (undrained tenants: "
            f"{stuck or 'in-controller'}) — this is a scheduling bug, "
            f"not a caller error")

    return MultiSimResult(
        total_s=last_collect,
        launches=results,
        dispatched_packages=controller.dispatched,
        fused_batches=controller.fused_batches,
        fused_members=controller.fused_members,
        host_busy_s=host_busy,
        service=service,
        data=counters,
    )
